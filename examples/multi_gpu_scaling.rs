//! Multi-GPU data parallelism demo (paper Fig 13): the same epoch's work
//! split across 1/2/4 worker pipelines on the 8×K80 machine, with gradient
//! synchronization over the shared PCIe link.
//!
//!     cargo run --release --example multi_gpu_scaling

fn main() {
    print!("{}", gnndrive::experiments::fig13(true));
}
