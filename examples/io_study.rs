//! I/O study (paper Appendix B): sync-vs-async, buffered-vs-direct reads on
//! the simulated SSD — the measurements motivating GNNDrive's asynchronous
//! direct-I/O extraction.
//!
//!     cargo run --release --example io_study [-- --full]

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    print!("{}", gnndrive::experiments::figb1(!full));
}
