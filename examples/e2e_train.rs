//! End-to-end driver: the full three-layer system on a REAL small workload.
//!
//! * datasets are materialized to real files on disk (`gen-data` layout);
//! * the GNNDrive pipeline (Rust, L3) samples/extracts against the
//!   simulated SSD holding those real bytes;
//! * the train stage executes the AOT artifact — GraphSAGE forward/backward
//!   written in JAX, aggregation as a Pallas kernel (L2/L1) — on the PJRT
//!   CPU client, logging a genuine loss curve.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use gnndrive::config::{Machine, MachineConfig, TrainConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::pipeline::{GnnDrive, Variant};
use gnndrive::runtime::{ArtifactMeta, TrainHandle};
use gnndrive::sim::Clock;
use gnndrive::train::convergence::ConvergenceTrace;

fn main() -> anyhow::Result<()> {
    let artifacts = ArtifactMeta::default_dir();
    if !artifacts.join("sage_mini.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // 1. Real files on disk (written once, reused).
    let data_dir = std::path::PathBuf::from(
        std::env::var("GNNDRIVE_DATA").unwrap_or_else(|_| "data/papers-tiny".into()),
    );
    if !data_dir.join("meta.toml").exists() {
        println!("materializing papers-tiny to {data_dir:?} …");
        Dataset::write_dir(&DatasetSpec::papers_tiny(), &data_dir)?;
    }
    let machine = Machine::new(MachineConfig::paper(), Clock::from_env());
    let ds = Dataset::load_dir(&data_dir, &machine)?;
    println!(
        "loaded {}: {} nodes, dim {}, {} train seeds (real files)",
        ds.spec.name,
        ds.spec.nodes,
        ds.spec.dim,
        ds.train_ids.len()
    );

    // 2. The PJRT train service: loads sage_mini.hlo.txt + params, compiles
    //    once, then serves training steps to the pipeline's trainer thread.
    let handle = TrainHandle::spawn(artifacts, "sage_mini".into())?;
    println!(
        "artifact sage_mini: caps {:?}, fanouts {:?} (fixed AOT shapes)",
        gnndrive::train::TrainStep::caps(&handle),
        gnndrive::train::TrainStep::fanouts(&handle),
    );

    // 3. GNNDrive pipeline matching the artifact's shapes.
    let cfg = TrainConfig {
        batch_size: 64,
        fanouts: vec![5, 5],
        batches_per_epoch: Some(40),
        samplers: 2,
        extractors: 2,
        io_depth: 64,
        ..TrainConfig::default()
    };
    let engine = GnnDrive::new(&machine, &ds, cfg, Variant::Gpu, Box::new(handle))?;

    // 4. Train several epochs; log the loss curve.
    let epochs: usize = std::env::var("GNNDRIVE_EPOCHS")
        .ok()
        .and_then(|e| e.parse().ok())
        .unwrap_or(5);
    let mut trace = ConvergenceTrace::default();
    let t0 = machine.clock.now();
    println!("\nepoch  time_s   loss    accuracy  (real PJRT numerics)");
    for e in 0..epochs {
        let st = engine.run_epoch(e as u64);
        let t = machine.clock.now().saturating_sub(t0);
        trace.record(t, e, st.train.mean_loss(), st.train.accuracy());
        println!(
            "{e:>5}  {:>6.2}  {:.4}  {:.4}    ({} steps, sample {:.2}s extract {:.2}s)",
            t.as_secs_f64(),
            st.train.mean_loss(),
            st.train.accuracy(),
            st.train.steps,
            st.sample_time.as_secs_f64(),
            st.extract_time.as_secs_f64(),
        );
    }
    let first = trace.points.first().unwrap();
    let last = trace.points.last().unwrap();
    println!(
        "\nloss {:.4} -> {:.4}; accuracy {:.3} -> {:.3}; best {:.3}",
        first.loss,
        last.loss,
        first.accuracy,
        last.accuracy,
        trace.best_accuracy()
    );
    anyhow::ensure!(last.loss < first.loss, "training did not reduce the loss");
    println!("e2e OK: all three layers composed on a real workload");
    Ok(())
}
