//! Quickstart: build a dataset analog, run GNNDrive for one epoch, and show
//! what the pipeline did.
//!
//!     cargo run --release --example quickstart

use gnndrive::baselines::{build_system, SystemKind};
use gnndrive::config::{Machine, MachineConfig, TrainConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::runtime::simcompute::ModelKind;
use gnndrive::sim::Clock;
use gnndrive::util::units::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // 1. A machine: the paper's testbed at 1/256 memory scale — one
    //    simulated PM883 SSD, 128 MiB host budget, two RTX-3090-class GPUs.
    let machine = Machine::new(MachineConfig::paper(), Clock::from_env());
    println!(
        "machine: {} | host {} | device {} x{} | SSD {:.0} MB/s, {} IOPS",
        machine.cfg.name,
        fmt_bytes(machine.cfg.host_mem),
        fmt_bytes(machine.cfg.dev_mem),
        machine.cfg.gpus,
        machine.cfg.ssd.read_bw / 1e6,
        machine.cfg.ssd.iops,
    );

    // 2. A dataset: the Papers100M analog (Table 1 row 1). Topology goes to
    //    the simulated SSD; features are served on demand; labels/splits are
    //    deterministic.
    let ds = Dataset::materialize(&DatasetSpec::papers100m_mini(), &machine)?;
    println!(
        "dataset: {} | {} nodes | {} edges | topo {} | features {}",
        ds.spec.name,
        ds.spec.nodes,
        ds.graph.edges(),
        fmt_bytes(ds.graph.topo_bytes()),
        fmt_bytes(ds.features.total_bytes()),
    );

    // 3. The paper's workload: batch 1000, 3-hop (10,10,10) sampling.
    let cfg = TrainConfig {
        batches_per_epoch: Some(4), // a short demo epoch
        ..TrainConfig::default()
    };

    // 4. Run GNNDrive (GPU variant, simulated train stage): one warm-up
    //    epoch, then the measured one (the paper averages warm epochs).
    let mut sys = build_system(SystemKind::GnnDriveGpu, &machine, &ds, cfg, ModelKind::GraphSage)?;
    sys.run_epoch(0)?;
    let stats = sys.run_epoch(1)?;
    println!("\nGNNDrive epoch (warm):\n  {}", stats.summary());
    println!(
        "  SSD read: {} | out-of-order completions (inversions): {}",
        fmt_bytes(stats.ssd_read_bytes),
        stats.reorder_inversions,
    );

    // 5. Compare against PyG+ on the same machine.
    drop(sys);
    machine.storage.cache.drop_all();
    let cfg = TrainConfig { batches_per_epoch: Some(4), ..TrainConfig::default() };
    let mut pyg = build_system(SystemKind::PygPlus, &machine, &ds, cfg, ModelKind::GraphSage)?;
    pyg.run_epoch(0)?;
    let pstats = pyg.run_epoch(1)?;
    println!("\nPyG+ epoch (warm):\n  {}", pstats.summary());
    println!(
        "\nGNNDrive vs PyG+ epoch time: {:.2}x",
        pstats.epoch_time.as_secs_f64() / stats.epoch_time.as_secs_f64()
    );
    Ok(())
}
