#!/usr/bin/env bash
# Tier-1 verification: release build + tests + bench bit-rot check, plus
# fmt/clippy when available, plus a real-file (--backend os) smoke run so
# the non-simulated I/O path cannot bit-rot. Run from anywhere; operates on
# the rust/ crate (vendored deps, offline).
set -euo pipefail
cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
  echo "SKIP: no cargo toolchain"
  exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --benches =="
# Benches are not compiled by plain `cargo build`/`cargo test` (autobenches
# is off and micro_hotpath has harness = false), so build them explicitly:
# bench bit-rot fails tier-1 instead of the next perf investigation.
cargo build --benches

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --check
else
  echo "== rustfmt unavailable; skipping format check =="
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== clippy unavailable; skipping lint =="
fi

echo "== smoke: gnndrive train --backend os (real files in a tempdir) =="
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
./target/release/gnndrive gen-data --dataset papers-tiny --out "$SMOKE_DIR/ds"
./target/release/gnndrive train --system gnndrive --backend os \
  --data "$SMOKE_DIR/ds" --batches 2 --epochs 1
# The sim backend must still be the default and keep working end to end.
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 1

echo "== smoke: gnndrive serve (sim + os backends) =="
# Serving frontend end to end: closed-loop inference on the sim backend …
./target/release/gnndrive serve --backend sim --dataset unit-test \
  --requests 60 --clients 3 --tenants 2 --serve-workers 2 \
  --serve-batch 8 --fanouts 4,4
# … and over real files in the tempdir (same dataset the os train smoke used).
./target/release/gnndrive serve --backend os --data "$SMOKE_DIR/ds" \
  --requests 30 --clients 2 --tenants 2 --serve-workers 1 \
  --serve-batch 4 --fanouts 4,4

echo "== smoke: fault injection (typed errors, retries, graceful degradation) =="
# A 1% transient storm must ride on the engine retry policy and complete on
# both backends (io_failures stays 0 — the ISSUE-6 chaos gate) …
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 1 \
  --fault-rate 0.01 --io-retries 3 --on-io-error retry
./target/release/gnndrive train --system gnndrive --backend os \
  --data "$SMOKE_DIR/ds" --batches 2 --epochs 1 \
  --fault-rate 0.01 --io-retries 3 --on-io-error retry
# … drop-rows degrades gracefully under a permanent bad range …
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 1 \
  --fault-bad-range 0:1MiB --on-io-error drop-rows
# … and fail-fast must terminate with a typed error — promptly, never a
# hang. Exit 1 is the typed-error abort; 0 means the storm was silently
# swallowed and 124 means it hung until timeout — both fail tier-1.
fail_rc=0
timeout 120 ./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 1 \
  --fault-rate 1 --io-retries 0 --on-io-error fail || fail_rc=$?
if [ "$fail_rc" -ne 1 ]; then
  echo "fault smoke: expected typed-error abort (exit 1), got exit $fail_rc" >&2
  exit 1
fi
# Serving converts exhausted-retry batches into per-request error responses
# (shed != error != ok) instead of wedging the admission queue.
./target/release/gnndrive serve --backend sim --dataset unit-test \
  --requests 30 --clients 2 --tenants 2 --serve-workers 1 \
  --serve-batch 4 --fanouts 4,4 \
  --fault-rate 0.01 --io-retries 4

echo "== smoke: striped storage (--devices 3, sim + os backends) =="
# gen-data writes one member file per device; train must reassemble them
# behind the unchanged backend seam (the geometry handshake rejects a
# mismatched --devices/--stripe-bytes at load time).
./target/release/gnndrive gen-data --dataset papers-tiny --out "$SMOKE_DIR/ds3" \
  --devices 3 --stripe-bytes 64KiB
./target/release/gnndrive train --system gnndrive --backend os \
  --data "$SMOKE_DIR/ds3" --devices 3 --stripe-bytes 64KiB --batches 2 --epochs 1
./target/release/gnndrive train --system gnndrive --backend sim \
  --data "$SMOKE_DIR/ds3" --devices 3 --stripe-bytes 64KiB --batches 2 --epochs 1
# A permanently dead stripe member (--fault-device) must degrade only its
# own rows: drop-rows rides out the storm and the epoch completes.
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --devices 3 --stripe-bytes 4KiB --batches 2 --epochs 1 \
  --fault-bad-range 0:4GiB --fault-device 1 --on-io-error drop-rows

echo "== smoke: io_uring backend (--backend uring, probe-gated) =="
# The uring engine needs kernel support; `gnndrive uring-probe` exits 0 when
# a ring can be set up. Without it the train smokes downgrade to the
# documented fallback path (--backend uring warns once and runs on the
# pread pool), which must also keep working.
if ./target/release/gnndrive uring-probe; then
  ./target/release/gnndrive train --system gnndrive --backend uring \
    --data "$SMOKE_DIR/ds" --batches 2 --epochs 1
  ./target/release/gnndrive train --system gnndrive --backend uring \
    --data "$SMOKE_DIR/ds3" --devices 3 --stripe-bytes 64KiB --batches 2 --epochs 1
else
  echo "SKIP: no io_uring (uring train smokes run the os-fallback path only)"
  ./target/release/gnndrive train --system gnndrive --backend uring \
    --data "$SMOKE_DIR/ds" --batches 2 --epochs 1
fi
# --backend uring is an asynchronous engine: combining it with the
# synchronous-extraction ablation must be rejected at parse time (exit 2),
# kernel support or not.
uring_rc=0
./target/release/gnndrive train --system gnndrive --backend uring \
  --data "$SMOKE_DIR/ds" --batches 2 --epochs 1 --sync-extract || uring_rc=$?
if [ "$uring_rc" -ne 2 ]; then
  echo "uring smoke: expected --backend uring --sync-extract rejection (exit 2), got exit $uring_rc" >&2
  exit 1
fi

echo "== smoke: packed layout (pack -> train --packed, sim + os) =="
# Offline pre-sample + pack, then replay the identical schedule from the
# packed layout. seed/batch-size/fanouts must match between pack and train
# (the meta.toml handshake refuses a mismatch at load time).
./target/release/gnndrive pack --data "$SMOKE_DIR/ds" \
  --batch-size 500 --fanouts 5,5 --batches 2 --seed 17 --pack-hot-thresh 2
./target/release/gnndrive train --system gnndrive --backend sim --packed \
  --data "$SMOKE_DIR/ds" --batch-size 500 --fanouts 5,5 --batches 2 \
  --epochs 1 --seed 17
./target/release/gnndrive train --system gnndrive --backend os --packed \
  --data "$SMOKE_DIR/ds" --batch-size 500 --fanouts 5,5 --batches 2 \
  --epochs 1 --seed 17
# Packed + striped: the pack inherits ds3's 3-device geometry (chunk-aligned
# run starts) and the packed replay runs on the striped array.
./target/release/gnndrive pack --data "$SMOKE_DIR/ds3" \
  --devices 3 --stripe-bytes 64KiB \
  --batch-size 500 --fanouts 5,5 --batches 2 --seed 17
./target/release/gnndrive train --system gnndrive --backend sim --packed \
  --data "$SMOKE_DIR/ds3" --devices 3 --stripe-bytes 64KiB \
  --batch-size 500 --fanouts 5,5 --batches 2 --epochs 1 --seed 17

echo "== smoke: tiered feature placement (--tier gpu, sim + os) =="
# The GPU hot tier must train end to end on both backends (promotions,
# background demotion, PCIe-charged transfers) …
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 2 --tier gpu --gpu-mem 1MiB
./target/release/gnndrive train --system gnndrive --backend os \
  --data "$SMOKE_DIR/ds" --batches 2 --epochs 2 --tier gpu --gpu-mem 1MiB
# … serve a skewed hot head (the workload the tier exists for) …
./target/release/gnndrive serve --backend sim --dataset unit-test \
  --requests 60 --clients 3 --tenants 2 --serve-workers 2 \
  --serve-batch 8 --fanouts 4,4 --hot-nodes 200 \
  --tier gpu --gpu-mem 1MiB
# … run the oversubscription ablation …
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 2 \
  --tier gpu --gpu-mem 64KiB --gpu-oversub
# … and keep the default charge-identical: --tier host is the pre-tier
# single-buffer path (the bench asserts exact parity; this asserts it runs).
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 1 --tier host
# Parse-time validation: a GPU tier without a device budget, an
# oversubscription flag without a GPU tier, and a per-tenant-buffer serve
# with a GPU tier must all be rejected at exit 2 with the flag named.
tier_rc=0
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 1 --tier gpu || tier_rc=$?
if [ "$tier_rc" -ne 2 ]; then
  echo "tier smoke: expected --tier gpu without --gpu-mem rejection (exit 2), got exit $tier_rc" >&2
  exit 1
fi
tier_rc=0
./target/release/gnndrive train --system gnndrive --backend sim \
  --dataset unit-test --batches 2 --epochs 1 --gpu-oversub || tier_rc=$?
if [ "$tier_rc" -ne 2 ]; then
  echo "tier smoke: expected --gpu-oversub without --tier gpu rejection (exit 2), got exit $tier_rc" >&2
  exit 1
fi
tier_rc=0
./target/release/gnndrive serve --backend sim --dataset unit-test \
  --requests 30 --clients 2 --tenants 2 --serve-workers 1 \
  --per-tenant-buffer --tier gpu --gpu-mem 1MiB || tier_rc=$?
if [ "$tier_rc" -ne 2 ]; then
  echo "tier smoke: expected --per-tenant-buffer with --tier gpu rejection (exit 2), got exit $tier_rc" >&2
  exit 1
fi

echo "== bench: extract_coalesce (coalesced segment I/O trajectory) =="
# Runs the extraction bench (release) and appends to BENCH_extract.json; the
# bench itself asserts the ISSUE-4 acceptance gate (>= 2x fewer charged
# requests on the GraphSAGE workload with coalescing on).
cargo bench --bench extract_coalesce

echo "== bench: serve_latency (serving throughput + tail latency) =="
# Runs the serving bench and appends to BENCH_serve.json; the bench asserts
# the ISSUE-5 acceptance gates (shared buffer beats the per-tenant ablation
# on p99 extract latency and charged SSD requests at the same offered load;
# the bounded admission queue sheds rather than queues past saturation).
cargo bench --bench serve_latency

echo "== bench: fault_tolerance (fault-rate sweep, retry vs fail-fast) =="
# Runs the fault-tolerance bench and appends to BENCH_faults.json; the bench
# asserts the ISSUE-6 gates (retry completes 0.1%/1% storms with zero
# surfaced failures; fail-fast aborts with a typed error, never a hang).
cargo bench --bench fault_tolerance

echo "== bench: stripe_scaling (multi-device striped storage gates) =="
# Runs the striping bench and appends to BENCH_stripe.json; the bench asserts
# the ISSUE-7 gates (devices=4 charged epoch I/O time >= 2.5x lower than
# devices=1 at the same offered load on the sim backend; devices=1 charges
# exactly match the pre-striping flat stack — same requests, same bytes).
cargo bench --bench stripe_scaling

echo "== bench: layout_pack (packed per-batch feature layout gates) =="
# Runs the packed-layout bench and appends to BENCH_layout.json; the bench
# asserts the ISSUE-8 gates on both backends (packed extraction charges
# >= 4x fewer SSD requests and strictly lower align_overhead_bytes than the
# online coalesced plan at the same workload, and the pipeline replays the
# pre-sampled schedule bit-identically — every batch served packed).
cargo bench --bench layout_pack

echo "== bench: uring_engine (engine parity, governor, hedging gates) =="
# Runs the io_uring/governor/hedging bench and appends to BENCH_uring.json;
# the bench asserts the ISSUE-9 gates (uring charged-I/O accounting exactly
# equals the pread pool while submit+harvest wall-clock is strictly lower at
# depth >= 8 — self-skipping with "SKIP: no io_uring" on unsupported
# kernels; the adaptive governor stays within 1.10x of the best static
# coalesce config's charged requests; hedged reissue under a seeded stall
# storm strictly lowers p99 time-to-publish with hedge_wins > 0 and zero
# duplicate scatters).
cargo bench --bench uring_engine

echo "== bench: tier_placement (GPU hot-tier placement gates) =="
# Runs the tiered-placement bench and appends to BENCH_tier.json; the bench
# asserts the ISSUE-10 gates (a warm GPU tier serves >= 80% of hits on the
# cubic-skew serve workload; tiered p99 extract latency strictly beats the
# single-tier host buffer at the same load; explicit promote/demote charges
# strictly fewer PCIe bytes than the --gpu-oversub ablation; --tier host
# charges exactly equal to the pre-tier stack).
cargo bench --bench tier_placement

if [ -f BENCH_extract.json ]; then
  echo "== last BENCH_extract.json record =="
  tail -n 1 BENCH_extract.json
fi

if [ -f BENCH_serve.json ]; then
  echo "== last BENCH_serve.json record =="
  tail -n 1 BENCH_serve.json
fi

if [ -f BENCH_hotpath.json ]; then
  echo "== last BENCH_hotpath.json record =="
  tail -n 1 BENCH_hotpath.json
fi

if [ -f BENCH_faults.json ]; then
  echo "== last BENCH_faults.json record =="
  tail -n 1 BENCH_faults.json
fi

if [ -f BENCH_stripe.json ]; then
  echo "== last BENCH_stripe.json record =="
  tail -n 1 BENCH_stripe.json
fi

if [ -f BENCH_layout.json ]; then
  echo "== last BENCH_layout.json record =="
  tail -n 1 BENCH_layout.json
fi

if [ -f BENCH_uring.json ]; then
  echo "== last BENCH_uring.json record =="
  tail -n 1 BENCH_uring.json
fi

if [ -f BENCH_tier.json ]; then
  echo "== last BENCH_tier.json record =="
  tail -n 1 BENCH_tier.json
fi

echo "tier-1 OK"
