#!/usr/bin/env bash
# Tier-1 verification: release build + tests, plus clippy when available.
# Run from anywhere; operates on the rust/ crate (vendored deps, offline).
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== clippy unavailable; skipping lint =="
fi

echo "tier-1 OK"
