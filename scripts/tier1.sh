#!/usr/bin/env bash
# Tier-1 verification: release build + tests + bench bit-rot check, plus
# clippy when available. Run from anywhere; operates on the rust/ crate
# (vendored deps, offline).
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --benches =="
# Benches are not compiled by plain `cargo build`/`cargo test` (autobenches
# is off and micro_hotpath has harness = false), so build them explicitly:
# bench bit-rot fails tier-1 instead of the next perf investigation.
cargo build --benches

echo "== cargo test -q =="
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== clippy unavailable; skipping lint =="
fi

if [ -f BENCH_hotpath.json ]; then
  echo "== last BENCH_hotpath.json record =="
  tail -n 1 BENCH_hotpath.json
fi

echo "tier-1 OK"
