"""AOT lowering: JAX/Pallas train & eval steps → HLO *text* artifacts the
Rust runtime loads via PJRT.

HLO text (NOT ``lowered.compiler_ir().serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Each artifact `<name>` ships three files under ``artifacts/``:

* ``<name>.hlo.txt``    — the lowered module (inputs: params… feats idx…
  labels; output: a tuple, see meta);
* ``<name>.meta.json``  — shapes/dtypes/param layout/hyperparams, consumed
  by ``rust/src/runtime/artifacts.rs``;
* ``<name>.params.bin`` — the initial parameters as concatenated f32
  little-endian arrays in meta order (Rust loads these instead of
  re-implementing the initializer).

Python runs only here, at build time (`make artifacts`); it is never on the
training path.
"""

import argparse
import json
import os
import sys

import numpy as np

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: M.ModelConfig, kind: str):
    """kind: 'train' or 'eval'."""
    fn = M.make_train_step(cfg) if kind == "train" else M.make_eval_step(cfg)
    params, feats, idxs, labels = M.example_args(cfg)
    specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    specs.append(jax.ShapeDtypeStruct(feats.shape, feats.dtype))
    specs += [jax.ShapeDtypeStruct(i.shape, i.dtype) for i in idxs]
    specs.append(jax.ShapeDtypeStruct(labels.shape, labels.dtype))
    return jax.jit(fn).lower(*specs)


def meta_dict(cfg: M.ModelConfig, kind: str):
    pspecs = M.param_specs(cfg)
    inputs = [{"name": n, "shape": list(s), "dtype": "f32"} for n, s in pspecs]
    inputs.append(
        {"name": "feats", "shape": [cfg.caps[-1], cfg.dim], "dtype": "f32"}
    )
    for i, f in enumerate(cfg.fanouts):
        inputs.append(
            {"name": f"idx_{i}", "shape": [cfg.caps[i], f], "dtype": "i32"}
        )
    inputs.append({"name": "labels", "shape": [cfg.caps[0]], "dtype": "i32"})
    if kind == "train":
        outputs = [{"name": n, "shape": list(s), "dtype": "f32"} for n, s in pspecs]
        outputs += [
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "correct", "shape": [], "dtype": "f32"},
        ]
    else:
        outputs = [
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "correct", "shape": [], "dtype": "f32"},
        ]
    return {
        "name": cfg.name,
        "kind": kind,
        "model": cfg.model,
        "caps": list(cfg.caps),
        "fanouts": list(cfg.fanouts),
        "dim": cfg.dim,
        "hidden": cfg.hidden,
        "classes": cfg.classes,
        "lr": cfg.lr,
        "n_params": len(pspecs),
        "inputs": inputs,
        "outputs": outputs,
    }


def write_params_bin(cfg: M.ModelConfig, path: str, seed: int = 0):
    params = M.init_params(cfg, seed)
    with open(path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())


def build(cfg: M.ModelConfig, out_dir: str, kinds=("train", "eval"), verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    write_params_bin(cfg, os.path.join(out_dir, f"{cfg.name}.params.bin"))
    for kind in kinds:
        name = cfg.name if kind == "train" else f"{cfg.name}_eval"
        lowered = lower_config(cfg, kind)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        meta = meta_dict(cfg, kind)
        meta["artifact"] = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        if verbose:
            print(f"wrote {name}: {len(text)} chars of HLO")


DEFAULT_CONFIGS = [
    M.mini("graphsage"),
    M.mini("gcn", name="gcn_mini"),
    M.mini("gat", name="gat_mini"),
]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact names to build"
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    for cfg in DEFAULT_CONFIGS:
        if only and cfg.name not in only:
            continue
        build(cfg, args.out)
    print("artifacts complete", file=sys.stderr)


if __name__ == "__main__":
    main()
