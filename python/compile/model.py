"""Layer-2 JAX models: GraphSAGE / GCN / GAT training steps over fixed
padded mini-batch shapes (the paper's three evaluation models, §5).

A mini-batch arrives from the Rust coordinator as:

* ``feats``  — ``[caps[L], dim]`` f32, gathered from GNNDrive's feature
  buffer by node alias (padding rows are zero);
* ``idx_i``  — ``[caps[i], fanouts[i]]`` int32 adjacency per level, local
  indices into the ``caps[i+1]`` prefix, ``-1`` = padding;
* ``labels`` — ``[caps[0]]`` int32, ``-1`` = padded seed.

``train_step`` runs forward + cross-entropy + backward + SGD in one pure
function (lowered once to HLO text by :mod:`compile.aot`; Python never runs
at training time). Neighbor aggregation is the L1 Pallas kernel
(:mod:`compile.kernels.aggregate`).
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import aggregate


@dataclass(frozen=True)
class ModelConfig:
    """Static shape/hyperparameter bundle — one AOT artifact per config."""

    name: str
    model: str  # "graphsage" | "gcn" | "gat"
    caps: tuple  # node prefix caps per level, seeds first: (c0, ..., cL)
    fanouts: tuple  # per-level fanout, len == L
    dim: int
    hidden: int
    classes: int
    lr: float = 0.05
    leaky_slope: float = 0.2  # GAT attention nonlinearity

    @property
    def levels(self):
        return len(self.fanouts)

    def layer_dims(self):
        """(d_in, d_out) per GNN step, deepest level first."""
        dims = []
        for step in range(self.levels):
            level = self.levels - 1 - step  # consume adjacency L-1 … 0
            d_in = self.dim if step == 0 else self.hidden
            d_out = self.classes if level == 0 else self.hidden
            dims.append((d_in, d_out))
        return dims


def mini(model="graphsage", **kw):
    """The small e2e/Fig-14 config: batch 64, fanouts (5,5), caps to 2048."""
    cfg = dict(
        name=f"{'sage' if model == 'graphsage' else model}_mini",
        model=model,
        caps=(64, 384, 2048),
        fanouts=(5, 5),
        dim=64,
        hidden=64,
        classes=16,
        lr=0.05,
    )
    cfg.update(kw)
    return ModelConfig(**cfg)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the contract shared with Rust via the
    meta sidecar and the params.bin dump."""
    specs = []
    for step, (d_in, d_out) in enumerate(cfg.layer_dims()):
        if cfg.model in ("graphsage",):
            specs.append((f"l{step}_w_self", (d_in, d_out)))
            specs.append((f"l{step}_w_neigh", (d_in, d_out)))
            specs.append((f"l{step}_b", (d_out,)))
        elif cfg.model == "gcn":
            specs.append((f"l{step}_w", (d_in, d_out)))
            specs.append((f"l{step}_b", (d_out,)))
        elif cfg.model == "gat":
            specs.append((f"l{step}_w", (d_in, d_out)))
            specs.append((f"l{step}_a_dst", (d_out,)))
            specs.append((f"l{step}_a_src", (d_out,)))
            specs.append((f"l{step}_b", (d_out,)))
        else:
            raise ValueError(cfg.model)
    return specs


def init_params(cfg: ModelConfig, seed=0):
    """Glorot-uniform weights / zero biases, deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            limit = float(np.sqrt(6.0 / (shape[0] + shape[1])))
            params.append(jax.random.uniform(sub, shape, jnp.float32, -limit, limit))
        elif name.endswith(("a_dst", "a_src")):
            limit = float(np.sqrt(3.0 / shape[0]))
            params.append(jax.random.uniform(sub, shape, jnp.float32, -limit, limit))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _layer(cfg, params_slice, h, idx, step, is_last):
    """One GNN step: dst prefix = idx.shape[0], src = current h."""
    dst = idx.shape[0]
    h_dst = h[:dst]
    if cfg.model == "graphsage":
        w_self, w_neigh, b = params_slice
        agg = aggregate.gather_mean(h, idx)
        out = h_dst @ w_self + agg @ w_neigh + b
    elif cfg.model == "gcn":
        (w, b) = params_slice
        # Mean over {self} ∪ sampled neighbors (degree-normalized mean of
        # the sampled adjacency, the standard sampled-GCN estimator).
        mask = (idx >= 0).astype(h.dtype)
        cnt = mask.sum(axis=-1, keepdims=True)
        agg = aggregate.gather_sum(h, idx)
        out = ((h_dst + agg) / (cnt + 1.0)) @ w + b
    elif cfg.model == "gat":
        w, a_dst, a_src, b = params_slice
        wh = h @ w  # [src, d_out]
        wh_dst = wh[:dst]
        rows = aggregate.gather_rows(wh, idx)  # [dst, F, d_out]
        e = jnp.einsum("d,md->m", a_dst, wh_dst)[:, None] + jnp.einsum(
            "d,mfd->mf", a_src, rows
        )
        e = jax.nn.leaky_relu(e, cfg.leaky_slope)
        neg = jnp.finfo(h.dtype).min
        e = jnp.where(idx >= 0, e, neg)
        att = jax.nn.softmax(e, axis=-1)
        att = jnp.where(idx >= 0, att, 0.0)  # all-invalid rows -> zeros
        out = jnp.einsum("mf,mfd->md", att, rows) + wh_dst + b
    else:
        raise ValueError(cfg.model)
    if not is_last:
        out = jax.nn.relu(out)
    return out


def _split_params(cfg, params):
    per = {"graphsage": 3, "gcn": 2, "gat": 4}[cfg.model]
    return [params[i * per : (i + 1) * per] for i in range(cfg.levels)]


def forward(cfg: ModelConfig, params, feats, idxs):
    """Logits for the seed prefix. `idxs` are level adjacencies 0..L-1."""
    h = feats
    slices = _split_params(cfg, params)
    for step in range(cfg.levels):
        level = cfg.levels - 1 - step
        h = _layer(cfg, slices[step], h, idxs[level], step, is_last=(level == 0))
    return h  # [caps[0], classes]


def _loss_and_acc(cfg, logits, labels):
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[:, None], axis=-1)[:, 0]
    nll = jnp.where(valid, nll, 0.0)
    n = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / n.astype(jnp.float32)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.where(valid, (pred == safe).astype(jnp.int32), 0).sum()
    return loss, correct


def make_train_step(cfg: ModelConfig):
    """Pure SGD step: (*params, feats, idx_0.., labels) →
    (*new_params, loss, correct)."""

    n_params = len(param_specs(cfg))

    def train_step(*args):
        params = list(args[:n_params])
        feats = args[n_params]
        idxs = list(args[n_params + 1 : n_params + 1 + cfg.levels])
        labels = args[n_params + 1 + cfg.levels]

        def loss_fn(ps):
            logits = forward(cfg, ps, feats, idxs)
            loss, correct = _loss_and_acc(cfg, logits, labels)
            return loss, correct

        (loss, correct), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params = [p - cfg.lr * g for p, g in zip(params, grads)]
        return tuple(new_params) + (loss, correct.astype(jnp.float32))

    return train_step


def make_eval_step(cfg: ModelConfig):
    """Inference: (*params, feats, idx_0.., labels) → (loss, correct)."""

    n_params = len(param_specs(cfg))

    def eval_step(*args):
        params = list(args[:n_params])
        feats = args[n_params]
        idxs = list(args[n_params + 1 : n_params + 1 + cfg.levels])
        labels = args[n_params + 1 + cfg.levels]
        logits = forward(cfg, params, feats, idxs)
        loss, correct = _loss_and_acc(cfg, logits, labels)
        return (loss, correct.astype(jnp.float32))

    return eval_step


def example_args(cfg: ModelConfig, seed=0):
    """Concrete example inputs (shapes only matter for lowering; also used
    by tests)."""
    rng = np.random.default_rng(seed)
    params = init_params(cfg, seed)
    feats = jnp.asarray(rng.normal(size=(cfg.caps[-1], cfg.dim)).astype(np.float32))
    idxs = []
    for i, f in enumerate(cfg.fanouts):
        hi = cfg.caps[i + 1]
        idx = rng.integers(-1, hi, size=(cfg.caps[i], f)).astype(np.int32)
        idxs.append(jnp.asarray(idx))
    labels = jnp.asarray(
        rng.integers(0, cfg.classes, size=(cfg.caps[0],)).astype(np.int32)
    )
    return params, feats, idxs, labels


def flat_args(cfg: ModelConfig, params, feats, idxs, labels):
    return tuple(params) + (feats,) + tuple(idxs) + (labels,)
