"""Pure-jnp oracles for the Pallas kernels.

These are the correctness reference (the paper's L1 hot-spot is neighbor
feature aggregation): every Pallas kernel in this package must match its
oracle to float tolerance under pytest + hypothesis sweeps.
"""

import jax.numpy as jnp


def gather_mean(x, idx):
    """Masked mean of gathered rows.

    x:   [N, D] float
    idx: [M, F] int32, entries in [0, N) or -1 for padding
    out: [M, D] -- mean over valid entries; all-invalid rows are zero.
    """
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    rows = jnp.take(x, safe, axis=0)  # [M, F, D]
    rows = rows * mask[..., None].astype(x.dtype)
    cnt = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1).astype(x.dtype)
    return rows.sum(axis=1) / cnt


def gather_sum(x, idx):
    """Masked sum of gathered rows (same contract as gather_mean)."""
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    rows = jnp.take(x, safe, axis=0)
    rows = rows * mask[..., None].astype(x.dtype)
    return rows.sum(axis=1)


def gather_rows(x, idx):
    """Masked gather without reduction.

    out: [M, F, D]; invalid entries produce zero rows.
    """
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    rows = jnp.take(x, safe, axis=0)
    return rows * mask[..., None].astype(x.dtype)
