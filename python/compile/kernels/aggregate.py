"""Pallas kernels for neighbor-feature aggregation — the compute hot-spot of
sample-based GNN training (layer-2 models call these; they lower into the
same AOT HLO the Rust runtime executes).

Hardware adaptation (DESIGN.md §4): the paper's testbed aggregates on CUDA
GPUs; restated for an MXU/VMEM machine, the gather-reduce is blocked over
(dst-rows × feature-dim) tiles via `BlockSpec` so each tile's output and its
gathered source rows fit VMEM, with the HBM↔VMEM schedule expressed by the
Pallas grid instead of CUDA threadblocks. `interpret=True` everywhere: the
CPU PJRT plugin cannot run Mosaic custom-calls, and correctness (not
wallclock) is what the CPU path validates — real-TPU tiling estimates live
in DESIGN.md §Perf.

VMEM budget at the default tile (bm=128, bd=128, F≤16, fp32):
  out tile 128×128×4 = 64 KiB, idx tile 128×16×4 = 8 KiB, gathered rows
  128×16×128×4 = 1 MiB → ≈1.1 MiB/tile, comfortably inside the ~16 MiB VMEM
  of a TPUv4 core with double-buffering headroom.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (MXU-friendly multiples of the 8×128 lane layout).
BLOCK_M = 128
BLOCK_D = 128


def _mean_kernel(idx_ref, x_ref, o_ref):
    """One (bm × bd) output tile: masked mean over F gathered rows."""
    idx = idx_ref[...]  # [bm, F] int32
    x = x_ref[...]  # [N, bd] — full source rows, this dim-tile only
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    rows = jnp.take(x, safe.reshape(-1), axis=0)  # [bm*F, bd]
    rows = rows.reshape(idx.shape + (x.shape[-1],))  # [bm, F, bd]
    rows = rows * mask[..., None].astype(x.dtype)
    cnt = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1).astype(x.dtype)
    o_ref[...] = rows.sum(axis=1) / cnt


def _sum_kernel(idx_ref, x_ref, o_ref):
    idx = idx_ref[...]
    x = x_ref[...]
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    rows = jnp.take(x, safe.reshape(-1), axis=0)
    rows = rows.reshape(idx.shape + (x.shape[-1],))
    rows = rows * mask[..., None].astype(x.dtype)
    o_ref[...] = rows.sum(axis=1)


def _rows_kernel(idx_ref, x_ref, o_ref):
    """Gather tile without reduction: output [bm, F, bd]."""
    idx = idx_ref[...]
    x = x_ref[...]
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    rows = jnp.take(x, safe.reshape(-1), axis=0)
    rows = rows.reshape(idx.shape + (x.shape[-1],))
    o_ref[...] = rows * mask[..., None].astype(x.dtype)


def _tiles(n, block):
    """Grid size and effective block for a dimension (handles n < block)."""
    b = min(block, n)
    return pl.cdiv(n, b), b


def pallas_gather_mean(x, idx, block_m=BLOCK_M, block_d=BLOCK_D):
    """Raw Pallas call (no vjp) — exported for tests/tuning."""
    m, f = idx.shape
    n, d = x.shape
    gm, bm = _tiles(m, block_m)
    gd, bd = _tiles(d, block_d)
    return pl.pallas_call(
        _mean_kernel,
        grid=(gm, gd),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(idx, x)


def pallas_gather_sum(x, idx, block_m=BLOCK_M, block_d=BLOCK_D):
    """Raw Pallas call (no vjp) — exported for tests/tuning."""
    m, f = idx.shape
    n, d = x.shape
    gm, bm = _tiles(m, block_m)
    gd, bd = _tiles(d, block_d)
    return pl.pallas_call(
        _sum_kernel,
        grid=(gm, gd),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(idx, x)


def pallas_gather_rows(x, idx, block_m=BLOCK_M, block_d=BLOCK_D):
    """Raw Pallas call (no vjp) — exported for tests/tuning."""
    m, f = idx.shape
    n, d = x.shape
    gm, bm = _tiles(m, block_m)
    gd, bd = _tiles(d, block_d)
    return pl.pallas_call(
        _rows_kernel,
        grid=(gm, gd),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, f, bd), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((m, f, d), x.dtype),
        interpret=True,
    )(idx, x)


# --------------------------------------------------------------------------
# Autodiff wrappers.
#
# Pallas (interpret mode included) has no reverse-mode rule, so each kernel
# carries a custom VJP: the forward pass runs the Pallas kernel; the
# backward pass is the mathematically exact scatter-add, expressed with
# XLA's native scatter (`.at[].add`). This mirrors how real systems pair a
# hand-written forward gather kernel with a scatter-based gradient; both
# lower into the single AOT HLO module the Rust runtime executes.
# --------------------------------------------------------------------------


@jax.custom_vjp
def gather_mean(x, idx):
    """Masked mean aggregation (Pallas forward). See ref.gather_mean."""
    return pallas_gather_mean(x, idx)


def _mean_fwd(x, idx):
    # Residuals must be JAX values: an empty [N, 0] array carries x's row
    # count and dtype without retaining its data.
    return pallas_gather_mean(x, idx), (x[:, :0], idx)


def _mean_bwd(res, g):
    (xproto, idx) = res
    xshape = (xproto.shape[0], g.shape[-1])
    xdtype = xproto.dtype
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    cnt = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1).astype(g.dtype)
    contrib = (g / cnt)[:, None, :] * mask[..., None].astype(g.dtype)  # [M,F,D]
    dx = jnp.zeros(xshape, xdtype).at[safe.reshape(-1)].add(
        contrib.reshape(-1, xshape[-1])
    )
    return dx, None


gather_mean.defvjp(_mean_fwd, _mean_bwd)


@jax.custom_vjp
def gather_sum(x, idx):
    """Masked sum aggregation (Pallas forward). See ref.gather_sum."""
    return pallas_gather_sum(x, idx)


def _sum_fwd(x, idx):
    return pallas_gather_sum(x, idx), (x[:, :0], idx)


def _sum_bwd(res, g):
    (xproto, idx) = res
    xshape = (xproto.shape[0], g.shape[-1])
    xdtype = xproto.dtype
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    contrib = g[:, None, :] * mask[..., None].astype(g.dtype)
    dx = jnp.zeros(xshape, xdtype).at[safe.reshape(-1)].add(
        contrib.reshape(-1, xshape[-1])
    )
    return dx, None


gather_sum.defvjp(_sum_fwd, _sum_bwd)


@jax.custom_vjp
def gather_rows(x, idx):
    """Masked gather, no reduction (Pallas forward). See ref.gather_rows."""
    return pallas_gather_rows(x, idx)


def _rows_fwd(x, idx):
    return pallas_gather_rows(x, idx), (x[:, :0], idx)


def _rows_bwd(res, g):
    (xproto, idx) = res
    xshape = (xproto.shape[0], g.shape[-1])
    xdtype = xproto.dtype
    mask = idx >= 0
    safe = jnp.where(mask, idx, 0)
    contrib = g * mask[..., None].astype(g.dtype)  # [M,F,D]
    dx = jnp.zeros(xshape, xdtype).at[safe.reshape(-1)].add(
        contrib.reshape(-1, xshape[-1])
    )
    return dx, None


gather_rows.defvjp(_rows_fwd, _rows_bwd)
