"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute layer — hypothesis
sweeps shapes, dtypes, fanouts, padding densities and block sizes, and every
kernel output must match ``ref.py`` to float tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import aggregate, ref

KERNELS = {
    "mean": (aggregate.gather_mean, ref.gather_mean),
    "sum": (aggregate.gather_sum, ref.gather_sum),
    "rows": (aggregate.gather_rows, ref.gather_rows),
}


def make_case(seed, n, d, m, f, invalid_frac, dtype):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(dtype)
    idx = rng.integers(0, n, size=(m, f)).astype(np.int32)
    mask = rng.random(size=(m, f)) < invalid_frac
    idx[mask] = -1
    return jnp.asarray(x), jnp.asarray(idx)


@pytest.mark.parametrize("kernel", KERNELS.keys())
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 300),
    d=st.integers(1, 160),
    m=st.integers(1, 200),
    f=st.integers(1, 12),
    invalid_frac=st.floats(0.0, 1.0),
)
def test_kernels_match_reference(kernel, seed, n, d, m, f, invalid_frac):
    k, r = KERNELS[kernel]
    x, idx = make_case(seed, n, d, m, f, invalid_frac, np.float32)
    got = np.asarray(k(x, idx))
    want = np.asarray(r(x, idx))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kernel", KERNELS.keys())
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_kernels_dtypes(kernel, dtype):
    k, r = KERNELS[kernel]
    x, idx = make_case(7, 64, 32, 48, 5, 0.3, dtype)
    got = np.asarray(k(x, idx))
    want = np.asarray(r(x, idx))
    assert got.dtype == want.dtype
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_all_invalid_rows_are_zero():
    x = jnp.ones((10, 8), jnp.float32)
    idx = jnp.full((4, 3), -1, jnp.int32)
    for name, (k, _) in KERNELS.items():
        out = np.asarray(k(x, idx))
        assert np.all(out == 0.0), name


def test_single_valid_entry_mean_equals_row():
    x = jnp.asarray(np.arange(40, dtype=np.float32).reshape(5, 8))
    idx = jnp.asarray(np.array([[3, -1, -1]], dtype=np.int32))
    out = np.asarray(aggregate.gather_mean(x, idx))
    np.testing.assert_allclose(out[0], np.asarray(x[3]))


@pytest.mark.parametrize("bm,bd", [(8, 8), (32, 128), (128, 16), (256, 256)])
def test_block_shape_invariance(bm, bd):
    """Tiling must never change the numbers (Pallas grid correctness)."""
    x, idx = make_case(3, 200, 96, 150, 7, 0.25, np.float32)
    base = np.asarray(aggregate.pallas_gather_mean(x, idx))
    tiled = np.asarray(aggregate.pallas_gather_mean(x, idx, block_m=bm, block_d=bd))
    np.testing.assert_allclose(tiled, base, rtol=1e-6, atol=1e-6)


def test_gradients_flow_through_custom_vjp():
    import jax

    x, idx = make_case(11, 50, 16, 30, 4, 0.3, np.float32)

    def loss_k(x):
        return (aggregate.gather_mean(x, idx) ** 2).sum()

    def loss_r(x):
        return (ref.gather_mean(x, idx) ** 2).sum()

    gk = np.asarray(jax.grad(loss_k)(x))
    gr = np.asarray(jax.grad(loss_r)(x))
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)

    def loss_k_rows(x):
        return (aggregate.gather_rows(x, idx) ** 2).sum()

    def loss_r_rows(x):
        return (ref.gather_rows(x, idx) ** 2).sum()

    gk = np.asarray(jax.grad(loss_k_rows)(x))
    gr = np.asarray(jax.grad(loss_r_rows)(x))
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)
