"""L2 correctness: model shapes, masking semantics, gradient flow, and a
planted-signal learnability check for each of the paper's three models."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M

SMALL = dict(caps=(16, 48, 128), fanouts=(3, 3), dim=8, hidden=8, classes=4)


@pytest.mark.parametrize("kind", ["graphsage", "gcn", "gat"])
def test_forward_shapes(kind):
    cfg = M.mini(kind, **SMALL)
    params, feats, idxs, labels = M.example_args(cfg)
    logits = M.forward(cfg, params, feats, idxs)
    assert logits.shape == (cfg.caps[0], cfg.classes)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("kind", ["graphsage", "gcn", "gat"])
def test_train_step_signature_and_loss_decreases(kind):
    cfg = M.mini(kind, **SMALL)
    params, feats, idxs, labels = M.example_args(cfg)
    step = M.make_train_step(cfg)
    out = step(*M.flat_args(cfg, params, feats, idxs, labels))
    n_params = len(M.param_specs(cfg))
    assert len(out) == n_params + 2
    loss0 = float(out[-2])
    ps = list(out[:n_params])
    for _ in range(15):
        out = step(*M.flat_args(cfg, ps, feats, idxs, labels))
        ps = list(out[:n_params])
    assert float(out[-2]) < loss0, f"{kind}: loss did not decrease"
    # Parameter shapes preserved.
    for p, (name, shape) in zip(ps, M.param_specs(cfg)):
        assert tuple(p.shape) == tuple(shape), name


def test_padded_labels_are_masked():
    cfg = M.mini("graphsage", **SMALL)
    params, feats, idxs, labels = M.example_args(cfg)
    ev = M.make_eval_step(cfg)
    # All seeds padded except two: loss/correct must count only those two.
    labels = np.full((cfg.caps[0],), -1, np.int32)
    labels[0], labels[1] = 1, 2
    l_masked, c_masked = ev(*M.flat_args(cfg, params, feats, idxs, jnp.asarray(labels)))
    assert np.isfinite(float(l_masked))
    assert 0 <= float(c_masked) <= 2


def test_eval_matches_train_forward():
    cfg = M.mini("graphsage", **SMALL)
    params, feats, idxs, labels = M.example_args(cfg)
    step = M.make_train_step(cfg)
    ev = M.make_eval_step(cfg)
    out = step(*M.flat_args(cfg, params, feats, idxs, labels))
    l_train = float(out[-2])
    l_eval, _ = ev(*M.flat_args(cfg, params, feats, idxs, labels))
    # Train-step loss is computed on the *pre-update* params: identical.
    np.testing.assert_allclose(l_train, float(l_eval), rtol=1e-5)


def test_init_params_deterministic():
    cfg = M.mini("graphsage", **SMALL)
    a = M.init_params(cfg, seed=3)
    b = M.init_params(cfg, seed=3)
    c = M.init_params(cfg, seed=4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert any(
        not np.array_equal(np.asarray(x), np.asarray(z)) for x, z in zip(a, c)
    )


@pytest.mark.parametrize("kind", ["graphsage", "gcn"])
def test_learns_planted_signal(kind):
    """Features = class centroid + noise, homophilous neighbors: accuracy
    should exceed chance after a few dozen steps (the Fig 14 mechanism)."""
    rng = np.random.default_rng(0)
    cfg = M.mini(kind, caps=(32, 96, 256), fanouts=(3, 3), dim=8, hidden=16, classes=4, lr=0.1)
    centroids = rng.normal(size=(4, 8)).astype(np.float32) * 2.0
    node_labels = rng.integers(0, 4, size=(cfg.caps[-1],))
    feats = centroids[node_labels] + 0.3 * rng.normal(size=(cfg.caps[-1], 8)).astype(
        np.float32
    )
    # Homophilous adjacency: neighbors share the dst's label.
    idxs = []
    for i, f in enumerate(cfg.fanouts):
        hi = cfg.caps[i + 1]
        idx = np.zeros((cfg.caps[i], f), np.int32)
        for d in range(cfg.caps[i]):
            same = np.flatnonzero(node_labels[:hi] == node_labels[d])
            idx[d] = rng.choice(same, size=f)
        idxs.append(jnp.asarray(idx))
    labels = jnp.asarray(node_labels[: cfg.caps[0]].astype(np.int32))
    feats = jnp.asarray(feats)

    step = M.make_train_step(cfg)
    ps = M.init_params(cfg, 0)
    for _ in range(60):
        out = step(*M.flat_args(cfg, ps, feats, idxs, labels))
        ps = list(out[:-2])
    acc = float(out[-1]) / cfg.caps[0]
    assert acc > 0.6, f"{kind}: planted-signal accuracy {acc}"
