"""AOT path: lowering produces parseable HLO text + a consistent meta
sidecar + a params dump of the right size."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M

TINY = M.mini("graphsage", name="aot_test", caps=(8, 24, 64), fanouts=(3, 3), dim=8, hidden=8, classes=4)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(TINY, str(out), verbose=False)
    return str(out)


def test_hlo_text_shape(built):
    text = open(os.path.join(built, "aot_test.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # 8 params + feats + 2 idx + labels = 12 inputs; all appear as
    # parameters of the entry computation.
    assert text.count("parameter(") >= 12


def test_meta_consistency(built):
    meta = json.load(open(os.path.join(built, "aot_test.meta.json")))
    assert meta["name"] == "aot_test"
    assert meta["caps"] == [8, 24, 64]
    assert meta["fanouts"] == [3, 3]
    n_params = meta["n_params"]
    assert len(meta["inputs"]) == n_params + 1 + 2 + 1
    assert meta["inputs"][n_params]["name"] == "feats"
    assert meta["inputs"][n_params]["shape"] == [64, 8]
    assert meta["outputs"][-2]["name"] == "loss"
    # Eval variant exists and has only loss+correct outputs.
    emeta = json.load(open(os.path.join(built, "aot_test_eval.meta.json")))
    assert len(emeta["outputs"]) == 2


def test_params_bin_size(built):
    specs = M.param_specs(TINY)
    want = sum(int(np.prod(s)) for _, s in specs) * 4
    got = os.path.getsize(os.path.join(built, "aot_test.params.bin"))
    assert got == want


def test_lowered_matches_eager(built):
    """The lowered computation (via jax compile+run of the same lowering)
    must match the eager step numerically."""
    import jax

    cfg = TINY
    params, feats, idxs, labels = M.example_args(cfg, seed=5)
    eager = M.make_train_step(cfg)(*M.flat_args(cfg, params, feats, idxs, labels))
    lowered = aot.lower_config(cfg, "train")
    compiled = lowered.compile()
    loweredout = compiled(*M.flat_args(cfg, params, feats, idxs, labels))
    for a, b in zip(eager, loweredout):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
