//! `cargo bench --bench serve_latency` — serving-frontend benchmark
//! (ISSUE 5): throughput and per-stage tail latency of the multi-tenant
//! online-inference path on the sim backend, with the two acceptance gates:
//!
//! * **Shared tenancy wins.** At the same offered load (identical
//!   closed-loop config and request budget, identical caps, equal batch
//!   fill) and measured on a *warm* engine, the shared-buffer configuration
//!   must achieve strictly lower p99 extract latency *and* strictly fewer
//!   charged SSD read requests than the per-tenant-buffer ablation — a hot
//!   row loads once for everyone instead of once per tenant, even though
//!   the ablation is granted the same slot count per buffer (tenants× the
//!   total memory).
//! * **Overload sheds.** An open-loop run offered far beyond service
//!   capacity against a small admission bound must shed (not queue) the
//!   excess: most offers are shed, every admitted request completes, and
//!   the report's admission tail reflects only the bounded queue.
//!
//! The ablation intentionally uses `--serve-batch 4` with four closed-loop
//! clients per tenant: shared and per-tenant modes then form batches of the
//! same size (≈4 requests), so the extract-latency comparison isolates
//! buffer residency + request charging + device congestion rather than
//! batch-size effects.
//!
//! Machine-readable results append to `BENCH_serve.json` (one JSON array
//! per run, JSONL); `scripts/tier1.sh` runs this bench and prints the last
//! record.

use gnndrive::config::{Machine, MachineConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::serve::{BatchSpec, ServeConfig, ServeEngine, ServeReport};
use gnndrive::sim::Clock;
use gnndrive::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

fn record(label: &str, r: &ServeReport) -> Json {
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    let mut m = BTreeMap::new();
    m.insert("bench".into(), Json::Str("serve_latency".into()));
    m.insert("config".into(), Json::Str(label.into()));
    m.insert("offered".into(), Json::Num(r.counts.offered as f64));
    m.insert("admitted".into(), Json::Num(r.counts.admitted as f64));
    m.insert("shed".into(), Json::Num(r.counts.shed as f64));
    m.insert("completed".into(), Json::Num(r.completed as f64));
    m.insert("batches".into(), Json::Num(r.batches as f64));
    m.insert("wall_ms_sim".into(), Json::Num(ms(r.wall)));
    m.insert("throughput_rps".into(), Json::Num(r.throughput_rps()));
    m.insert("e2e_p50_ms".into(), Json::Num(ms(r.stages.total.p50())));
    m.insert("e2e_p95_ms".into(), Json::Num(ms(r.stages.total.p95())));
    m.insert("e2e_p99_ms".into(), Json::Num(ms(r.stages.total.p99())));
    m.insert("extract_p50_ms".into(), Json::Num(ms(r.stages.extract.p50())));
    m.insert("extract_p99_ms".into(), Json::Num(ms(r.stages.extract.p99())));
    m.insert("admission_p99_ms".into(), Json::Num(ms(r.stages.admission.p99())));
    m.insert("ssd_requests".into(), Json::Num(r.ssd_read_requests as f64));
    m.insert("ssd_bytes".into(), Json::Num(r.ssd_read_bytes as f64));
    m.insert("buffer_hits".into(), Json::Num(r.buffer_hits as f64));
    m.insert("buffer_loads".into(), Json::Num(r.buffer_loads as f64));
    Json::Obj(m)
}

fn row(label: &str, r: &ServeReport) -> String {
    format!("{label:<18} {}", r.summary())
}

/// The ablation config: one-hop inference (latency-realistic), tiny batches
/// with matched fill across tenancy modes, a residency-sized buffer, and
/// requests concentrated on a hot head (online traffic) whose neighborhoods
/// fit the buffer — so the tenancy split, not raw capacity, decides hits.
fn ablation_cfg() -> ServeConfig {
    ServeConfig {
        tenants: 4,
        workers: 4,
        requests: 600,
        clients: 16, // four per tenant → batch fill ≈ 4 in BOTH tenancy modes
        admit_cap: 256,
        batch: BatchSpec { max_requests: 4, max_wait: Duration::from_millis(1) },
        fanouts: vec![10],
        io_depth: 16, // ≥ one coalesced batch's segments; bounds ring workers
        buffer_mult: 48,
        hot_nodes: 2000,
        seed: 23,
        ..ServeConfig::default()
    }
}

/// Warm the engine with one full epoch, then measure the second: serving is
/// a long-lived process and the gates compare steady-state tails, not the
/// shared cold start.
fn warm_then_measure(engine: &ServeEngine) -> ServeReport {
    engine.run(0).expect("warm-up epoch");
    engine.run(1).expect("measured epoch")
}

fn main() {
    // Mildly compressed sim time (0.5, not the extraction bench's 0.02):
    // tail latencies mix device sleeps with real CPU work (sampling,
    // planning), and aggressive compression would inflate the CPU share of
    // every stage. Charged-request counts are clock-independent.
    let machine = Arc::new(Machine::new(
        MachineConfig::paper().with_host_mem(1 << 30),
        Clock::new(0.5),
    ));
    println!("materializing papers100m-mini …");
    let ds = Arc::new(
        Dataset::materialize(&DatasetSpec::papers100m_mini(), &machine)
            .expect("materialize papers100m-mini"),
    );

    let mut records = Vec::new();

    // ---- ablation: shared buffer vs per-tenant buffers, same load ----
    let shared = ServeEngine::new(&machine, &ds, ablation_cfg()).expect("shared engine");
    let split = ServeEngine::new(
        &machine,
        &ds,
        ServeConfig { per_tenant_buffer: true, ..ablation_cfg() },
    )
    .expect("per-tenant engine");
    assert_eq!(shared.caps(), split.caps(), "ablation must compare identical caps");

    let r_shared = warm_then_measure(&shared);
    println!("{}", row("shared-buffer", &r_shared));
    let r_split = warm_then_measure(&split);
    println!("{}", row("per-tenant-buffer", &r_split));

    assert_eq!(r_shared.completed, ablation_cfg().requests, "shared run must complete");
    assert_eq!(r_split.completed, ablation_cfg().requests, "split run must complete");

    let p99_shared = r_shared.stages.extract.p99();
    let p99_split = r_split.stages.extract.p99();
    println!(
        "  -> extract p99 {:.3}ms (shared) vs {:.3}ms (per-tenant); ssd reqs {} vs {}; loads {} vs {}",
        p99_shared.as_secs_f64() * 1e3,
        p99_split.as_secs_f64() * 1e3,
        r_shared.ssd_read_requests,
        r_split.ssd_read_requests,
        r_shared.buffer_loads,
        r_split.buffer_loads,
    );
    // Acceptance gate 1: shared tenancy strictly wins on tail extract
    // latency and charged request count at the same offered load.
    assert!(
        p99_shared < p99_split,
        "acceptance: shared-buffer p99 extract {p99_shared:?} must beat per-tenant {p99_split:?}"
    );
    assert!(
        r_shared.ssd_read_requests < r_split.ssd_read_requests,
        "acceptance: shared buffer must charge fewer SSD requests ({} vs {})",
        r_shared.ssd_read_requests,
        r_split.ssd_read_requests
    );
    records.push(record("shared-buffer", &r_shared));
    records.push(record("per-tenant-buffer", &r_split));

    // ---- overload: open loop far past capacity, small admission bound ----
    let overload_cfg = ServeConfig {
        requests: 600,
        rps: 500_000.0, // effectively an instantaneous burst
        admit_cap: 32,
        workers: 2,
        ..ablation_cfg()
    };
    let overload = ServeEngine::new(&machine, &ds, overload_cfg).expect("overload engine");
    let r_over = overload.run(2).expect("overload run");
    println!("{}", row("overload-shed", &r_over));
    // Acceptance gate 2: the bounded admission queue sheds rather than
    // queues — past saturation most offers are dropped at the door, every
    // admitted request still completes, and nothing is silently lost.
    assert!(
        r_over.counts.shed > r_over.counts.offered / 2,
        "acceptance: far past saturation most offers must shed ({} of {})",
        r_over.counts.shed,
        r_over.counts.offered
    );
    assert_eq!(
        r_over.counts.admitted + r_over.counts.shed,
        r_over.counts.offered,
        "every offer admits or sheds"
    );
    assert_eq!(r_over.completed, r_over.counts.admitted, "admitted requests all complete");
    records.push(record("overload-shed", &r_over));

    println!(
        "acceptance: shared buffer beats per-tenant (p99 extract {:.3}ms < {:.3}ms, \
         {} < {} ssd reqs); overload shed {} of {}",
        p99_shared.as_secs_f64() * 1e3,
        p99_split.as_secs_f64() * 1e3,
        r_shared.ssd_read_requests,
        r_split.ssd_read_requests,
        r_over.counts.shed,
        r_over.counts.offered,
    );

    let line = Json::Arr(records).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_serve.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended 3 records to BENCH_serve.json"),
        Err(e) => eprintln!("could not append to BENCH_serve.json: {e}"),
    }
}
