//! `cargo bench --bench fig02_sampling_contention` — regenerates paper Fig 2 (memory contention: sampling time -only vs -all).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig02(quick));
}
