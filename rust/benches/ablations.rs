//! `cargo bench --bench ablations` — GNNDrive with each mechanism disabled
//! individually: async extraction, direct I/O, mini-batch reordering
//! (the design-choice ablations called out in DESIGN.md §10).
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::ablation(quick));
}
