//! ISSUE 9 acceptance bench: the true io_uring engine, the adaptive
//! coalescing governor, and hedged straggler reissue.
//!
//! Three gated parts:
//!
//! 1. **Engine parity + wall-clock** (needs a kernel with io_uring;
//!    self-skips with a printed reason otherwise): the uring engine must
//!    charge *exactly* the same I/O accounting as the pread pool for an
//!    identical request stream, while completing the submit+harvest loop in
//!    strictly less wall-clock time at depth ≥ 8.
//! 2. **Governor no-regression** (sim, always runs): over three workload
//!    shapes the governor's effective config must stay within 1.10× of the
//!    best static `--coalesce-gap` candidate's charged request count. The
//!    monotone ratchet can only move under congestion signals; this gates
//!    that it never *walks off* into a pessimal config (adapt.rs unit tests
//!    pin the movement directions themselves).
//! 3. **Hedging p99** (sim + seeded stall storm, always runs): with a
//!    deterministic stall plan, hedged reissue must strictly lower the
//!    per-batch p99 *time-to-publish* — the simulated time until every row
//!    of the batch is scattered into the feature buffer, which is what a
//!    concurrently-training consumer waits on — vs the same run unhedged,
//!    win at least once (`hedge_wins > 0`), and publish every row exactly
//!    once (zero duplicate scatters).
//!
//! Machine-readable results append to `BENCH_uring.json` (JSONL);
//! `scripts/tier1.sh` runs this bench and tails the file.

use gnndrive::extract::{
    CoalesceConfig, CoalesceGovernor, DeviceIoObservation, ExtractOptions, ExtractTarget,
    Extractor, HedgeConfig,
};
use gnndrive::graph::{FeatureGen, FeatureTable};
use gnndrive::membuf::{FeatureBuffer, SlotRef, StagingArena, StagingBuffer};
use gnndrive::sim::{Clock, Stopwatch};
use gnndrive::storage::{
    probe_uring, BackendKind, DataKind, FaultInjectBackend, FaultPlan, FileBacking, FileId,
    HostMemory, IoBackend, IoMode, OsFileBackend, PageCache, RetryPolicy, SimFile, Sqe,
    SsdConfig, SsdSim, Storage, StripeSpec,
};
use gnndrive::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn record(m: BTreeMap<String, Json>) -> Json {
    let mut full = BTreeMap::new();
    full.insert("bench".into(), Json::Str("uring_engine".into()));
    full.extend(m);
    Json::Obj(full)
}

// ---------------------------------------------------------------------------
// Part 1: uring vs pread — accounting parity, wall-clock at depth ≥ 8
// ---------------------------------------------------------------------------

const PARITY_REQS: usize = 2048;
const PARITY_LEN: usize = 4096;
const PARITY_DEPTH: usize = 8;
const PARITY_TRIALS: usize = 3;

fn parity_file() -> SimFile {
    let dir = std::env::temp_dir().join("gnndrive_uring_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("parity_{}.bin", std::process::id()));
    let bytes: Vec<u8> = (0..PARITY_REQS * PARITY_LEN).map(|i| (i % 251) as u8).collect();
    std::fs::write(&path, &bytes).unwrap();
    SimFile::new(FileId::new(31, DataKind::Features), Arc::new(FileBacking::open(&path).unwrap()))
}

/// One full submit+harvest pass of `PARITY_REQS` aligned 4 KiB reads in
/// waves of `depth`; returns (wall-clock, charged reads, charged bytes,
/// useful, aligned).
fn drive_engine(io: &Arc<dyn IoBackend>, file: &SimFile, depth: usize) -> (Duration, u64, u64, u64, u64) {
    io.reset_io_stats();
    let engine = io.clone().async_engine(depth);
    let arena = StagingArena::new(depth, PARITY_LEN);
    let t0 = std::time::Instant::now();
    for wave in 0..PARITY_REQS / depth {
        let sqes: Vec<Sqe> = (0..depth)
            .map(|i| {
                let n = wave * depth + i;
                Sqe {
                    file: file.clone(),
                    offset: (n * PARITY_LEN) as u64,
                    len: PARITY_LEN,
                    useful: PARITY_LEN,
                    dst: SlotRef::new(arena.clone(), i),
                    dst_off: 0,
                    user_data: i as u64,
                    mode: IoMode::Direct,
                }
            })
            .collect();
        engine.submit_batch(sqes);
        let cqes = engine.wait_cqes(depth);
        assert_eq!(cqes.len(), depth, "{}: lost CQEs in wave {wave}", io.name());
        for c in &cqes {
            assert!(c.result.is_ok(), "{}: wave {wave} errored: {:?}", io.name(), c.result);
        }
    }
    let took = t0.elapsed();
    let (useful, aligned) = io.direct_stats().snapshot();
    (
        took,
        io.io_counters().reads.load(Ordering::Relaxed),
        io.io_counters().read_bytes.load(Ordering::Relaxed),
        useful,
        aligned,
    )
}

fn part_parity(records: &mut Vec<Json>) {
    if let Err(e) = probe_uring() {
        println!("SKIP: no io_uring ({e}); engine parity + wall-clock gates not run");
        let mut m = BTreeMap::new();
        m.insert("part".into(), Json::Str("engine_parity".into()));
        m.insert("skipped".into(), Json::Bool(true));
        m.insert("reason".into(), Json::Str(format!("no io_uring: {e}")));
        records.push(record(m));
        return;
    }
    let file = parity_file();
    let pread: Arc<dyn IoBackend> = Arc::new(OsFileBackend::new(512));
    let uring: Arc<dyn IoBackend> =
        Arc::new(OsFileBackend::with_stripe_uring(512, 8, StripeSpec::single()));

    // Best-of-N wall-clock per engine; accounting from the last trial (it is
    // identical across trials — the stream is deterministic).
    let mut best_pread = Duration::MAX;
    let mut best_uring = Duration::MAX;
    let mut acct_pread = (0, 0, 0, 0);
    let mut acct_uring = (0, 0, 0, 0);
    for _ in 0..PARITY_TRIALS {
        let (t, r, b, u, a) = drive_engine(&pread, &file, PARITY_DEPTH);
        best_pread = best_pread.min(t);
        acct_pread = (r, b, u, a);
        let (t, r, b, u, a) = drive_engine(&uring, &file, PARITY_DEPTH);
        best_uring = best_uring.min(t);
        acct_uring = (r, b, u, a);
    }
    println!(
        "engine parity: {} reads × {} B, depth {}  pread {:>9.3?}  uring {:>9.3?}",
        PARITY_REQS, PARITY_LEN, PARITY_DEPTH, best_pread, best_uring,
    );
    assert_eq!(
        acct_uring, acct_pread,
        "uring charged-I/O accounting must equal the pread pool exactly"
    );
    assert_eq!(acct_uring.0, PARITY_REQS as u64, "one charged read per request");
    assert_eq!(acct_uring.1, (PARITY_REQS * PARITY_LEN) as u64, "charged volume");
    assert!(
        best_uring < best_pread,
        "uring submit+harvest must beat the pread pool at depth {PARITY_DEPTH}: \
         uring {best_uring:?} vs pread {best_pread:?}"
    );
    let mut m = BTreeMap::new();
    m.insert("part".into(), Json::Str("engine_parity".into()));
    m.insert("skipped".into(), Json::Bool(false));
    m.insert("depth".into(), Json::Num(PARITY_DEPTH as f64));
    m.insert("requests".into(), Json::Num(PARITY_REQS as f64));
    m.insert("pread_us".into(), Json::Num(best_pread.as_secs_f64() * 1e6));
    m.insert("uring_us".into(), Json::Num(best_uring.as_secs_f64() * 1e6));
    m.insert(
        "speedup".into(),
        Json::Num(best_pread.as_secs_f64() / best_uring.as_secs_f64().max(1e-12)),
    );
    records.push(record(m));
}

// ---------------------------------------------------------------------------
// Part 2: governor vs best static gap over three workload shapes
// ---------------------------------------------------------------------------

const GOV_DIM: usize = 64; // 256 B rows
const GOV_EPOCHS: usize = 6;
const GOV_TABLE_NODES: u64 = 16_000_000; // procedural: no materialization

struct GovWorkload {
    name: &'static str,
    /// Node ids for epoch `e` — disjoint regions, identical shape, so the
    /// charged request count of a fixed config is epoch-invariant.
    nodes: fn(usize) -> Vec<u32>,
}

const GOV_WORKLOADS: [GovWorkload; 3] = [
    // Dense run: every config beyond `disabled` merges maximally.
    GovWorkload { name: "dense", nodes: |e| ((e as u32 * 40_000)..(e as u32 * 40_000 + 2048)).collect() },
    // Moderate stride: small intra-segment gaps, still mergeable at base.
    GovWorkload {
        name: "stride4",
        nodes: |e| (0..512u32).map(|i| e as u32 * 40_000 + i * 4).collect(),
    },
    // Ultra-sparse: gaps far beyond 8× the base gap — nothing merges under
    // any reachable config.
    GovWorkload {
        name: "sparse",
        nodes: |e| (0..256u32).map(|i| e as u32 * 1_600_000 + i * 600).collect(),
    },
];

fn gov_setup() -> (Arc<dyn IoBackend>, Clock) {
    let clock = Clock::new(0.05);
    let cache = Arc::new(PageCache::new(HostMemory::new(1 << 22)));
    let io: Arc<dyn IoBackend> =
        Arc::new(Storage::new(SsdSim::new(SsdConfig::pm883(), clock.clone()), cache));
    (io, clock)
}

fn gov_extractor(io: &Arc<dyn IoBackend>, coalesce: CoalesceConfig) -> (Extractor, Arc<FeatureBuffer>) {
    let labels = Arc::new(vec![0u16; 1]);
    let gen = FeatureGen::new(0x90E, GOV_DIM, 1, 0.3, labels);
    let features =
        FeatureTable::procedural(FileId::new(41, DataKind::Features), GOV_TABLE_NODES, gen);
    let host = HostMemory::new(1 << 22);
    let fb = Arc::new(FeatureBuffer::in_host(&host, 4096, GOV_DIM).unwrap());
    let staging = StagingBuffer::new(&host, 1024, GOV_DIM * 4).unwrap();
    let ex = Extractor::with_options(
        io.clone(),
        64,
        staging,
        fb.clone(),
        features,
        ExtractTarget::Host,
        ExtractOptions { coalesce, ..Default::default() },
    );
    (ex, fb)
}

/// Charged requests for one epoch-shaped extraction under a fixed config.
fn static_requests(w: &GovWorkload, coalesce: CoalesceConfig) -> u64 {
    let (io, _clock) = gov_setup();
    let (ex, fb) = gov_extractor(&io, coalesce);
    io.reset_io_stats();
    let aliases = ex.extract(&(w.nodes)(0));
    fb.release_aliases(&aliases);
    io.io_counters().reads.load(Ordering::Relaxed)
}

/// Run the governed loop: extract one epoch, fold the observed charge rates
/// into the governor, push the retuned configs, repeat. Returns the final
/// epoch's charged request count.
fn governed_requests(w: &GovWorkload) -> u64 {
    let (io, clock) = gov_setup();
    let base = CoalesceConfig::default();
    let (ex, fb) = gov_extractor(&io, base);
    let mut gov = CoalesceGovernor::new(base, 1, false);
    let mut last = 0;
    for e in 0..GOV_EPOCHS {
        let r0 = io.io_counters().reads.load(Ordering::Relaxed);
        let b0 = io.io_counters().read_bytes.load(Ordering::Relaxed);
        let sw = Stopwatch::start(&clock);
        let aliases = ex.extract(&(w.nodes)(e));
        let secs = sw.elapsed().as_secs_f64();
        fb.release_aliases(&aliases);
        let reads = io.io_counters().reads.load(Ordering::Relaxed) - r0;
        let bytes = io.io_counters().read_bytes.load(Ordering::Relaxed) - b0;
        let hw = ex.queue_highwater().first().copied().unwrap_or(0);
        gov.observe_epoch(&[DeviceIoObservation::from_charges(
            reads, bytes, secs, 97_000.0, 520e6, hw, 64,
        )]);
        ex.set_coalesce_configs(gov.configs());
        last = reads;
    }
    last
}

fn part_governor(records: &mut Vec<Json>) {
    for w in &GOV_WORKLOADS {
        let base = CoalesceConfig::default();
        // Static candidates: the governor's reachable set (1×..8× base, the
        // MAX_WIDEN cap) plus the per-row ablation.
        let mut best = u64::MAX;
        let mut best_name = String::new();
        for mult in [1usize, 2, 4, 8] {
            let cfg = CoalesceConfig {
                max_bytes: base.max_bytes * mult,
                gap_bytes: base.gap_bytes * mult,
            };
            let r = static_requests(w, cfg);
            if r < best {
                best = r;
                best_name = format!("{mult}x");
            }
        }
        let r = static_requests(w, CoalesceConfig::disabled());
        if r < best {
            best = r;
            best_name = "disabled".into();
        }
        let gov = governed_requests(w);
        println!(
            "governor[{}]: governed {gov} req  best static {best} req ({best_name})  ratio {:.3}",
            w.name,
            gov as f64 / best as f64,
        );
        assert!(
            gov as f64 <= best as f64 * 1.10,
            "{}: governed request count {gov} exceeds 1.10× best static {best}",
            w.name
        );
        let mut m = BTreeMap::new();
        m.insert("part".into(), Json::Str("governor".into()));
        m.insert("workload".into(), Json::Str(w.name.into()));
        m.insert("governed_requests".into(), Json::Num(gov as f64));
        m.insert("best_static_requests".into(), Json::Num(best as f64));
        m.insert("best_static".into(), Json::Str(best_name));
        m.insert("ratio".into(), Json::Num(gov as f64 / best as f64));
        records.push(record(m));
    }
}

// ---------------------------------------------------------------------------
// Part 3: hedged reissue under a seeded stall storm — p99 strictly lower
// ---------------------------------------------------------------------------

const HEDGE_DIM: usize = 128; // 512 B rows → sector-aligned per-row offsets
const HEDGE_BATCHES: usize = 100;
const HEDGE_BATCH: usize = 64;
const STALL_US: u64 = 50_000;
const STALL_RATE: f64 = 0.01;

fn hedge_plan(seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        transient_rate: 0.0,
        short_rate: 0.0,
        stall_rate: STALL_RATE,
        stall_us: STALL_US,
        bad_ranges: Vec::new(),
        device: None,
    }
}

/// Pick a seed where, over the exact per-row offsets this run issues:
/// every stalled original's hedge draw is clean (no double-stall — the
/// hedged run's p99 win is then deterministic, not probabilistic), and the
/// storm still stalls at least a handful of originals.
fn find_hedge_seed() -> u64 {
    'seed: for seed in 0..20_000u64 {
        let plan = hedge_plan(seed);
        let mut stalled = 0;
        for n in 0..(HEDGE_BATCHES * HEDGE_BATCH) as u64 {
            let off = n * (HEDGE_DIM as u64 * 4);
            if plan.stall_verdict(off, 0) {
                if plan.stall_verdict(off, 1) {
                    continue 'seed; // double-stall: hedge can't rescue
                }
                stalled += 1;
            }
        }
        if stalled >= 5 {
            return seed;
        }
    }
    panic!("no hedge seed found in 20k candidates");
}

/// Run the batched extraction under the stall plan; returns (per-batch sim
/// time-to-publish, hedges, hedge_wins, loads).
///
/// The wave protocol never returns from `extract` while a hedged pair's
/// loser is still in flight (its staging bytes stay request-owned until the
/// CQE is harvested), so `extract`'s own wall-clock still includes the full
/// stall. What hedging buys is *early publication*: the rescued rows land
/// in the feature buffer at roughly the hedge threshold instead of the
/// stall. That is the latency a pipelined consumer actually sees, and it is
/// what we time here — extraction runs on a worker thread while this thread
/// watches the buffer's atomic `loads` counter (nodes are unique across
/// batches and duplicate completions never double-publish, so batch `b` is
/// fully published exactly when `loads == (b+1) × batch`).
fn hedge_run(seed: u64, hedge: HedgeConfig) -> (Vec<Duration>, u64, u64, u64) {
    let clock = Clock::new(0.05);
    let cache = Arc::new(PageCache::new(HostMemory::new(1 << 22)));
    let storage: Arc<dyn IoBackend> =
        Arc::new(Storage::new(SsdSim::new(SsdConfig::pm883(), clock.clone()), cache));
    let io: Arc<dyn IoBackend> = Arc::new(FaultInjectBackend::new(
        storage,
        BackendKind::Sim,
        hedge_plan(seed),
        RetryPolicy::default(),
        clock.clone(),
    ));
    let labels = Arc::new(vec![0u16; 1]);
    let gen = FeatureGen::new(0x4ED6E, HEDGE_DIM, 1, 0.3, labels);
    let features = FeatureTable::procedural(
        FileId::new(51, DataKind::Features),
        (HEDGE_BATCHES * HEDGE_BATCH) as u64,
        gen,
    );
    let host = HostMemory::new(1 << 22);
    let fb = Arc::new(FeatureBuffer::in_host(&host, 256, HEDGE_DIM).unwrap());
    // Staging must hold a full wave (one segment per row — coalescing is
    // off) *plus* its hedge duplicates, or `arena_full` silences hedging.
    let staging = StagingBuffer::new(&host, 160, HEDGE_DIM * 4).unwrap();
    let ex = Extractor::with_options(
        io.clone(),
        64,
        staging,
        fb.clone(),
        features,
        ExtractTarget::Host,
        ExtractOptions { coalesce: CoalesceConfig::disabled(), hedge, ..Default::default() },
    );
    let (batch_tx, batch_rx) = std::sync::mpsc::channel::<Vec<u32>>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<Vec<i32>>();
    let worker = std::thread::spawn(move || {
        while let Ok(nodes) = batch_rx.recv() {
            let aliases = ex.extract(&nodes);
            if done_tx.send(aliases).is_err() {
                break;
            }
        }
    });
    let tick = Duration::from_micros(2000);
    let mut lats = Vec::with_capacity(HEDGE_BATCHES);
    for b in 0..HEDGE_BATCHES {
        let nodes: Vec<u32> = (b as u32 * HEDGE_BATCH as u32
            ..(b as u32 + 1) * HEDGE_BATCH as u32)
            .collect();
        let target = ((b + 1) * HEDGE_BATCH) as u64;
        let sw = Stopwatch::start(&clock);
        batch_tx.send(nodes).unwrap();
        while fb.stats().3 < target {
            clock.sleep(tick);
        }
        lats.push(sw.elapsed());
        // Only now block on extract's return (it still harvests hedge
        // losers) so batches never queue behind each other.
        let aliases = done_rx.recv().unwrap();
        fb.release_aliases(&aliases);
    }
    drop(batch_tx);
    worker.join().unwrap();
    fb.check_invariants().unwrap();
    let (hedges, wins) = io.direct_stats().hedge_snapshot();
    let (_, _, _, loads) = fb.stats();
    (lats, hedges, wins, loads)
}

fn p99(lats: &[Duration]) -> Duration {
    let mut v = lats.to_vec();
    v.sort_unstable();
    v[(v.len() * 99 / 100).min(v.len() - 1)]
}

fn part_hedge(records: &mut Vec<Json>) {
    let seed = find_hedge_seed();
    let (base_lats, h0, w0, loads0) = hedge_run(seed, HedgeConfig::disabled());
    let (hedged_lats, h1, w1, loads1) = hedge_run(seed, HedgeConfig::pinned(500));
    let (p_base, p_hedged) = (p99(&base_lats), p99(&hedged_lats));
    println!(
        "hedge storm (seed {seed}): p99 time-to-publish unhedged {:?} → hedged {:?}  \
         ({} hedge(s), {} win(s))",
        p_base, p_hedged, h1, w1,
    );
    assert_eq!((h0, w0), (0, 0), "unhedged run must not hedge");
    assert!(h1 > 0, "the storm must have triggered hedges");
    assert!(w1 > 0, "at least one hedge must beat its stalled original");
    assert!(w1 <= h1, "wins cannot exceed hedges");
    assert!(
        p_hedged < p_base,
        "hedging must strictly lower p99 under the stall storm: {p_hedged:?} vs {p_base:?}"
    );
    let total = (HEDGE_BATCHES * HEDGE_BATCH) as u64;
    assert_eq!(loads0, total, "unhedged: every row published exactly once");
    assert_eq!(loads1, total, "hedged: duplicate completions must never double-scatter");
    let mut m = BTreeMap::new();
    m.insert("part".into(), Json::Str("hedge".into()));
    m.insert("seed".into(), Json::Num(seed as f64));
    m.insert("p99_unhedged_us".into(), Json::Num(p_base.as_secs_f64() * 1e6));
    m.insert("p99_hedged_us".into(), Json::Num(p_hedged.as_secs_f64() * 1e6));
    m.insert("hedges".into(), Json::Num(h1 as f64));
    m.insert("hedge_wins".into(), Json::Num(w1 as f64));
    records.push(record(m));
}

fn main() {
    let mut records = Vec::new();
    part_parity(&mut records);
    part_governor(&mut records);
    part_hedge(&mut records);
    println!(
        "acceptance: accounting parity + faster harvest (or SKIP), governor ≤1.10× best \
         static, hedged p99 strictly lower with wins > 0 and zero duplicate scatters"
    );
    let line = Json::Arr(records).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_uring.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended records to BENCH_uring.json"),
        Err(e) => eprintln!("could not append to BENCH_uring.json: {e}"),
    }
}
