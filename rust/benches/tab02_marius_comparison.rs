//! `cargo bench --bench tab02_marius_comparison` — regenerates paper Table 2 (MariusGNN vs GNNDrive).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::tab02(quick));
}
