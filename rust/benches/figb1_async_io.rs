//! `cargo bench --bench figb1_async_io` — regenerates paper Fig B.1 (sync vs async I/O microbenchmark).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::figb1(quick));
}
