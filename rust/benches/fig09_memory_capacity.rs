//! `cargo bench --bench fig09_memory_capacity` — regenerates paper Fig 9 (epoch time vs host memory).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig09(quick));
}
