//! `cargo bench --bench fig03_fig11_utilization` — regenerates paper Figs 3 & 11 (CPU/GPU utilization + iowait timelines).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig03_fig11(quick));
}
