//! `cargo bench --bench layout_pack` — packed per-batch layout vs. online
//! coalesced extraction (ISSUE 8): pre-sample one epoch of papers-tiny,
//! pack it (`layout::pack_dataset`), then replay the identical batch
//! sequence through an online-coalesced extractor and a packed extractor on
//! both backends (sim + os).
//!
//! Acceptance gates, per backend:
//! * **requests** — packed extraction must charge ≥ 4× fewer SSD read
//!   requests than the online coalesced plan at the same workload (a pack
//!   run is ~one staging-capacity-bounded sequential segment per batch;
//!   the online plan pays one request per ≤256 KiB span of scattered rows).
//! * **alignment** — packed `align_overhead_bytes` must be *strictly*
//!   lower: run starts are pre-aligned by the packer, so packed segments
//!   bridge only already-resident holes, while online segments bridge every
//!   inter-row gap under `--coalesce-gap`.
//! * **replay** — the offline pre-sampler, an independent replay of the
//!   `ScheduleSpec`, and the live pipeline engine must all derive
//!   bit-identical batch node sets: two independent replays are compared
//!   directly, every replayed batch must be fully placeable by the pack
//!   index, and a full `GnnDrive` epoch with the layout attached must serve
//!   *every* batch packed (`EpochStats::packed_batches == batches` — one
//!   diverging node set would force that batch online).
//!
//! Charged counters are deterministic → the gates are noise-free.
//! Machine-readable results append to `BENCH_layout.json` (JSONL);
//! `scripts/tier1.sh` runs this bench and prints the last record.

use gnndrive::baselines::sim_trainer;
use gnndrive::config::{Machine, MachineConfig, TrainConfig};
use gnndrive::extract::{ExtractOptions, ExtractTarget, Extractor};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::layout::{pack_dataset, pin_hot, PackedLayout};
use gnndrive::membuf::{FeatureBuffer, StagingBuffer};
use gnndrive::pipeline::{GnnDrive, Variant};
use gnndrive::runtime::simcompute::ModelKind;
use gnndrive::sample::ScheduleSpec;
use gnndrive::sim::Clock;
use gnndrive::storage::{BackendKind, EpochIoSnapshot};
use gnndrive::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;

const BATCH: usize = 500;
const BATCHES: usize = 4;
const SEED: u64 = 17;
const HOT_THRESH: u32 = 2;
const FB_SLOTS: usize = 80_000; // > papers-tiny node count: everything fits

fn schedule() -> ScheduleSpec {
    ScheduleSpec {
        seed: SEED,
        batch_size: BATCH,
        fanouts: vec![5, 5],
        batches_per_epoch: Some(BATCHES),
    }
}

fn machine_for(kind: BackendKind) -> Machine {
    // Host budget above paper scale only so one feature buffer holds every
    // extracted row; SSD model and staging bound stay paper.
    Machine::new(
        MachineConfig::paper().with_backend(kind).with_host_mem(1 << 30),
        Clock::new(0.05),
    )
}

/// Replay the schedule's batch node sets (deterministic in the spec).
fn replay(schedule: &ScheduleSpec, ds: &Dataset, machine: &Machine) -> Vec<Vec<u32>> {
    let plan = schedule.plan(&ds.train_ids, 0);
    let sampler = schedule.sampler(0);
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); plan.len()];
    while let Some((bid, seeds)) = plan.claim() {
        out[bid as usize] = sampler.sample_batch(ds, machine.backend.as_ref(), bid, seeds).nodes;
    }
    out
}

struct Run {
    backend: &'static str,
    mode: &'static str,
    reads: u64,
    read_bytes: u64,
    align_overhead: u64,
    pinned: usize,
}

impl Run {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".into(), Json::Str("layout_pack".into()));
        m.insert("backend".into(), Json::Str(self.backend.into()));
        m.insert("mode".into(), Json::Str(self.mode.into()));
        m.insert("batches".into(), Json::Num(BATCHES as f64));
        m.insert("charged_requests".into(), Json::Num(self.reads as f64));
        m.insert("charged_bytes".into(), Json::Num(self.read_bytes as f64));
        m.insert("align_overhead_bytes".into(), Json::Num(self.align_overhead as f64));
        m.insert("hot_pinned".into(), Json::Num(self.pinned as f64));
        Json::Obj(m)
    }

    fn row(&self) -> String {
        format!(
            "{:<4} {:<7} reqs {:>5}  charged {:>10}B  align+ {:>10}B  pinned {:>5}",
            self.backend, self.mode, self.reads, self.read_bytes, self.align_overhead, self.pinned,
        )
    }
}

/// Extract the epoch's batches on a fresh feature buffer; `layout` switches
/// the packed path on (with the hot tier pinned first, outside the
/// measured window — the pin is a one-time setup cost, not per-epoch I/O).
fn run_epoch(
    machine: &Machine,
    ds: &Dataset,
    batches: &[Vec<u32>],
    layout: Option<&Arc<PackedLayout>>,
    backend: &'static str,
) -> Run {
    let fb = Arc::new(FeatureBuffer::in_host(&machine.host, FB_SLOTS, ds.spec.dim).unwrap());
    let staging =
        StagingBuffer::new(&machine.host, 4096, ds.features.row_bytes() as usize).unwrap();
    let mut ex = Extractor::with_options(
        machine.backend.clone(),
        128,
        staging,
        fb.clone(),
        ds.features.clone(),
        ExtractTarget::Host,
        ExtractOptions::default(),
    );
    let mut pinned = 0;
    if let Some(l) = layout {
        ex.set_layout(l.clone());
        pinned = pin_hot(&fb, l, machine.backend.as_ref(), FB_SLOTS / 2);
    }
    let snap = EpochIoSnapshot::start(machine.backend.as_ref());
    for (bid, nodes) in batches.iter().enumerate() {
        let aliases = ex.try_extract_at(nodes, Some((0, bid as u64))).unwrap();
        fb.release_aliases(&aliases);
    }
    let io = snap.totals(machine.backend.as_ref());
    Run {
        backend,
        mode: if layout.is_some() { "packed" } else { "online" },
        reads: io.reads,
        read_bytes: io.read_bytes,
        align_overhead: io.align_overhead_bytes,
        pinned,
    }
}

fn main() {
    let dir = std::env::temp_dir().join(format!("gnndrive_layout_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec = DatasetSpec::by_name("papers-tiny").expect("papers-tiny registered");
    println!("writing papers-tiny to {dir:?} …");
    Dataset::write_dir(&spec, &dir).unwrap();

    // Pack once (offline step; sim machine drives the pre-sampler).
    let sched = schedule();
    {
        let machine = machine_for(BackendKind::Sim);
        let ds = Dataset::load_dir(&dir, &machine).unwrap();
        let st = pack_dataset(&machine, &ds, &dir, &sched, 1, HOT_THRESH).unwrap();
        println!(
            "packed: {} batch(es), {} hot row(s), {} cold row(s), {} pack bytes ({} pad)",
            st.batches_per_epoch, st.hot_rows, st.cold_rows, st.pack_bytes, st.pad_bytes,
        );
    }

    let mut records = Vec::new();
    for (kind, name) in [(BackendKind::Sim, "sim"), (BackendKind::Os, "os")] {
        let machine = machine_for(kind);
        let ds = Dataset::load_dir(&dir, &machine).unwrap();

        // ---- replay gate: independent replays are bit-identical ----------
        let batches = replay(&sched, &ds, &machine);
        assert_eq!(
            batches,
            replay(&sched, &ds, &machine),
            "{name}: schedule replay must be deterministic"
        );
        let layout = Arc::new(PackedLayout::load_dir(&dir, &machine).unwrap());
        layout.verify_schedule(&sched).unwrap();
        for (bid, nodes) in batches.iter().enumerate() {
            let to_load: Vec<(u32, u32)> = nodes.iter().map(|&n| (n, 0)).collect();
            let pp = layout
                .plan_batch(0, bid as u64, &to_load)
                .unwrap_or_else(|| panic!("{name}: batch {bid} not covered by the pack"));
            assert_eq!(
                pp.pack_rows.len() + pp.hot_rows.len(),
                nodes.len(),
                "{name}: batch {bid} pack row table must place every sampled node"
            );
        }

        // ---- request + alignment gates ----------------------------------
        let online = run_epoch(&machine, &ds, &batches, None, name);
        println!("{}", online.row());
        let packed = run_epoch(&machine, &ds, &batches, Some(&layout), name);
        println!("{}", packed.row());
        let ratio = online.reads as f64 / packed.reads.max(1) as f64;
        println!("  -> {name}: {ratio:.1}x fewer charged requests packed");
        assert!(
            packed.reads * 4 <= online.reads,
            "acceptance ({name}): packed charged {} requests vs online {} (>= 4x fewer required)",
            packed.reads,
            online.reads,
        );
        assert!(
            packed.align_overhead < online.align_overhead,
            "acceptance ({name}): packed align overhead {} must be strictly below online {}",
            packed.align_overhead,
            online.align_overhead,
        );
        records.push(online);
        records.push(packed);
    }

    // ---- end-to-end replay gate: the live pipeline serves every batch
    // packed (a single diverging node set would force that batch online). --
    {
        let machine = Arc::new(machine_for(BackendKind::Sim));
        let ds = Arc::new(Dataset::load_dir(&dir, &machine).unwrap());
        let cfg = TrainConfig {
            batch_size: BATCH,
            fanouts: vec![5, 5],
            batches_per_epoch: Some(BATCHES),
            seed: SEED,
            ..TrainConfig::default()
        };
        let trainer = sim_trainer(&machine, &ds, &cfg, ModelKind::GraphSage, Variant::Gpu, 256);
        let mut engine = GnnDrive::new(&machine, &ds, cfg, Variant::Gpu, trainer).unwrap();
        let layout = Arc::new(PackedLayout::load_dir(&dir, &machine).unwrap());
        let pinned = engine.attach_layout(layout).unwrap();
        let stats = engine.try_run_epoch(0).unwrap();
        println!(
            "pipeline: {} batches, {} packed, {} hot hits, {} pinned",
            stats.batches, stats.packed_batches, stats.hot_hits, pinned,
        );
        assert_eq!(stats.batches, BATCHES);
        assert_eq!(
            stats.packed_batches, BATCHES,
            "acceptance: the pipeline must replay the pre-sampled schedule bit-identically \
             (every batch served from its pack run)"
        );
    }
    println!("acceptance: all layout_pack gates hold (requests, alignment, replay)");

    let line = Json::Arr(records.iter().map(Run::json).collect()).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_layout.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended {} records to BENCH_layout.json", records.len()),
        Err(e) => eprintln!("could not append to BENCH_layout.json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
