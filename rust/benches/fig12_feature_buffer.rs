//! `cargo bench --bench fig12_feature_buffer` — regenerates paper Fig 12 (feature buffer size sweep).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig12(quick));
}
