//! `cargo bench --bench fig10_minibatch_sizes` — regenerates paper Fig 10 (epoch time vs mini-batch size).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig10(quick));
}
