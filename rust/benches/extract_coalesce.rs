//! `cargo bench --bench extract_coalesce` — extraction-focused benchmark of
//! the segment-coalescing I/O planner (ISSUE 4): charged request counts,
//! useful/aligned byte accounting and wall time, with coalescing on vs off,
//! across three node-id distributions on the paper machine (sim backend):
//!
//! * `graphsage` — real sampled mini-batches (papers100m-mini, batch 1000,
//!   fanouts 10/10/10): the paper's main workload and the acceptance gate —
//!   coalescing must cut charged read requests ≥ 2× at identical useful
//!   bytes.
//! * `sequential` — a contiguous node range (best case: long merged runs).
//! * `skewed` — power-law-ish draws (hubs cluster, tail stays sparse).
//!
//! Machine-readable results append to `BENCH_extract.json` (one JSON array
//! per run, JSONL) so future PRs can track the I/O trajectory;
//! `scripts/tier1.sh` runs this bench and prints the last record.

use gnndrive::config::{Machine, MachineConfig};
use gnndrive::extract::{CoalesceConfig, ExtractOptions, ExtractTarget, Extractor};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::membuf::{FeatureBuffer, StagingBuffer};
use gnndrive::pipeline::derive_caps;
use gnndrive::sample::{EpochPlan, Sampler};
use gnndrive::sim::Clock;
use gnndrive::storage::IoBackend as _;
use gnndrive::util::json::Json;
use gnndrive::util::rng::Pcg;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

const BATCH: usize = 1000;
const FANOUTS: [usize; 3] = [10, 10, 10];
const BATCHES: usize = 4;

struct Run {
    workload: &'static str,
    coalesce: CoalesceConfig,
    rows: u64,
    reads: u64,
    read_bytes: u64,
    useful: u64,
    aligned: u64,
    wall_ms: f64,
}

impl Run {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".into(), Json::Str("extract_coalesce".into()));
        m.insert("workload".into(), Json::Str(self.workload.into()));
        m.insert("coalesce_bytes".into(), Json::Num(self.coalesce.max_bytes as f64));
        m.insert("coalesce_gap".into(), Json::Num(self.coalesce.gap_bytes as f64));
        m.insert("rows".into(), Json::Num(self.rows as f64));
        m.insert("charged_requests".into(), Json::Num(self.reads as f64));
        m.insert("charged_bytes".into(), Json::Num(self.read_bytes as f64));
        m.insert("useful_bytes".into(), Json::Num(self.useful as f64));
        m.insert("aligned_bytes".into(), Json::Num(self.aligned as f64));
        m.insert("wall_ms_sim".into(), Json::Num(self.wall_ms));
        Json::Obj(m)
    }

    fn row(&self) -> String {
        format!(
            "{:<11} coalesce={:<8} rows {:>6}  reqs {:>6}  charged {:>10}B  useful {:>10}B  aligned {:>10}B  wall {:>9.2}ms",
            self.workload,
            if self.coalesce.enabled() {
                format!("{}K/{}K", self.coalesce.max_bytes >> 10, self.coalesce.gap_bytes >> 10)
            } else {
                "off".into()
            },
            self.rows,
            self.reads,
            self.read_bytes,
            self.useful,
            self.aligned,
            self.wall_ms,
        )
    }
}

/// Extract every batch once on a fresh feature buffer; returns the run's
/// charged-request/byte accounting and sim wall time.
fn run_extraction(
    machine: &Machine,
    ds: &Dataset,
    batches: &[Vec<u32>],
    coalesce: CoalesceConfig,
    workload: &'static str,
) -> Run {
    let total_nodes: usize = batches.iter().map(Vec::len).sum();
    let fb = Arc::new(
        FeatureBuffer::in_host(&machine.host, total_nodes + BATCH, ds.spec.dim).unwrap(),
    );
    let staging =
        StagingBuffer::new(&machine.host, 4096, ds.features.row_bytes() as usize).unwrap();
    let ex = Extractor::with_options(
        machine.backend.clone(),
        128,
        staging,
        fb.clone(),
        ds.features.clone(),
        ExtractTarget::Host,
        ExtractOptions { coalesce, ..Default::default() },
    );
    machine.backend.reset_io_stats();
    let dio = machine.backend.direct_stats().snapshot();
    let t0 = Instant::now();
    for nodes in batches {
        let aliases = ex.extract(nodes);
        std::hint::black_box(&aliases);
    }
    let wall = machine.clock.to_sim(t0.elapsed());
    let (useful, aligned) = machine.backend.direct_stats().snapshot();
    let (_, _, _, loads) = fb.stats();
    Run {
        workload,
        coalesce,
        rows: loads,
        reads: machine
            .backend
            .io_counters()
            .reads
            .load(std::sync::atomic::Ordering::Relaxed),
        read_bytes: machine
            .backend
            .io_counters()
            .read_bytes
            .load(std::sync::atomic::Ordering::Relaxed),
        useful: useful - dio.0,
        aligned: aligned - dio.1,
        wall_ms: wall.as_secs_f64() * 1e3,
    }
}

/// GraphSAGE mini-batches: the pipeline's own sampler + padding caps.
fn graphsage_batches(machine: &Machine, ds: &Dataset) -> Vec<Vec<u32>> {
    let caps = derive_caps(
        BATCH,
        &FANOUTS,
        ds.spec.dim,
        machine.devices[0].capacity() * 9 / 10,
        9, // train queue 4 + extractors 4 + 1, the paper default
        1,
    );
    let plan = EpochPlan::new(&ds.train_ids, BATCH, 17, 0, Some(BATCHES));
    let sampler = Sampler::new(FANOUTS.to_vec(), 17);
    let mut batches = Vec::new();
    while let Some((batch_id, seeds)) = plan.claim() {
        let sub = sampler.sample_batch(ds, machine.backend.as_ref(), batch_id, seeds);
        let padded = sub.pad(&caps, &FANOUTS);
        batches.push(padded.nodes[..padded.real_nodes].to_vec());
    }
    batches
}

/// Power-law-ish draws: hot head, long sparse tail (dedup'd per batch).
fn skewed_batches(n_nodes: u32) -> Vec<Vec<u32>> {
    let mut rng = Pcg::new(0xBEEF);
    (0..BATCHES)
        .map(|_| {
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..12_000 {
                let u = (rng.next_u64() % (1 << 20)) as f64 / (1u64 << 20) as f64;
                let id = ((n_nodes as f64) * u * u * u) as u32;
                seen.insert(id.min(n_nodes - 1));
            }
            seen.into_iter().collect()
        })
        .collect()
}

fn main() {
    // Compressed sim time: charged-request counts are clock-independent and
    // wall times are reported in sim time, so the bench stays fast. The host
    // budget is raised above paper scale only so the bench can hold every
    // extracted batch in one host-resident buffer — the SSD model, sector
    // size and staging bound (what coalescing interacts with) stay paper.
    let machine =
        Machine::new(MachineConfig::paper().with_host_mem(1 << 30), Clock::new(0.02));
    println!("materializing papers100m-mini …");
    let ds = Dataset::materialize(&DatasetSpec::papers100m_mini(), &machine)
        .expect("materialize papers100m-mini");

    let workloads: Vec<(&'static str, Vec<Vec<u32>>)> = vec![
        ("graphsage", graphsage_batches(&machine, &ds)),
        ("sequential", vec![(0..20_000u32).collect()]),
        ("skewed", skewed_batches(ds.spec.nodes)),
    ];

    let mut records = Vec::new();
    let mut graphsage_ratio = None;
    for (name, batches) in &workloads {
        let name = *name;
        let off = run_extraction(&machine, &ds, batches, CoalesceConfig::disabled(), name);
        println!("{}", off.row());
        let on = run_extraction(&machine, &ds, batches, CoalesceConfig::default(), name);
        println!("{}", on.row());
        let ratio = off.reads as f64 / on.reads.max(1) as f64;
        println!("  -> {ratio:.2}x fewer charged requests, useful bytes {}",
            if on.useful == off.useful { "unchanged" } else { "CHANGED (bug!)" });
        assert_eq!(on.useful, off.useful, "{name}: useful bytes must not change");
        assert_eq!(on.rows, off.rows, "{name}: loaded row count must not change");
        if name == "graphsage" {
            graphsage_ratio = Some(ratio);
        }
        records.push(off);
        records.push(on);
    }

    // The ISSUE 4 acceptance gate: paper config, GraphSAGE batch workload,
    // sim backend — charged read requests drop ≥ 2× vs --coalesce-bytes 0.
    let ratio = graphsage_ratio.unwrap();
    assert!(
        ratio >= 2.0,
        "acceptance: GraphSAGE charged-request reduction {ratio:.2}x < 2x"
    );
    println!("acceptance: GraphSAGE charged-request reduction {ratio:.2}x (>= 2x required)");

    let line = Json::Arr(records.iter().map(Run::json).collect()).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_extract.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended {} records to BENCH_extract.json", records.len()),
        Err(e) => eprintln!("could not append to BENCH_extract.json: {e}"),
    }
}
