//! `cargo bench --bench fig08_feature_dims` — regenerates paper Fig 8 (epoch time vs feature dimensions).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig08(quick));
}
