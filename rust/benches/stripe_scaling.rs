//! `cargo bench --bench stripe_scaling` — striped storage-stack scaling
//! (ISSUE 7): the same offered load (24 000 single-row feature reads,
//! papers100m-mini, coalescing off so the run is IOPS-bound) against sim
//! arrays of 1 and 4 devices, plus a devices=1 charging-parity check
//! against the pre-striping flat stack.
//!
//! Two acceptance gates:
//! * **scaling** — with 4 devices the *charged epoch I/O time* (the
//!   bottleneck device's `ops/IOPS + bytes/bandwidth` from the per-device
//!   charge counters) must be ≥ 2.5× lower than with 1 device. Round-robin
//!   chunk placement makes the ideal 4.0×; the gate leaves headroom for
//!   boundary imbalance.
//! * **parity** — a `--devices 1` machine must charge *exactly* the same
//!   request count and byte volume as the flat (pre-refactor) machine on
//!   the identical workload, with coalescing both off and on: striping
//!   degenerates to a no-op, not an approximation.
//!
//! Charged counters are deterministic, so the gates are noise-free; sim
//! wall time is also measured (scale 1.0, like the SSD-model tests, so real
//! bookkeeping cost does not swamp scaled device time) but only reported.
//! Machine-readable results append to `BENCH_stripe.json` (JSONL);
//! `scripts/tier1.sh` runs this bench and prints the last record.

use gnndrive::config::{Machine, MachineConfig};
use gnndrive::extract::{CoalesceConfig, ExtractOptions, ExtractTarget, Extractor};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::membuf::{FeatureBuffer, StagingBuffer};
use gnndrive::sim::Clock;
use gnndrive::storage::{IoBackend as _, SsdConfig};
use gnndrive::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// 64 KiB chunks: 128 rows of the 512 B papers100m-mini features per chunk,
/// so 24 000 sequential rows round-robin ~47 chunks onto each of 4 devices.
const STRIPE: u64 = 64 << 10;
const ROWS: u32 = 24_000;
const IO_DEPTH: usize = 128;

struct Run {
    label: &'static str,
    devices: usize,
    coalesce: bool,
    reads: u64,
    read_bytes: u64,
    dev_reads: Vec<(u64, u64)>,
    charged_io_ms: f64,
    wall_ms: f64,
}

impl Run {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".into(), Json::Str("stripe_scaling".into()));
        m.insert("label".into(), Json::Str(self.label.into()));
        m.insert("devices".into(), Json::Num(self.devices as f64));
        m.insert("coalesce".into(), Json::Num(if self.coalesce { 1.0 } else { 0.0 }));
        m.insert("rows".into(), Json::Num(ROWS as f64));
        m.insert("charged_requests".into(), Json::Num(self.reads as f64));
        m.insert("charged_bytes".into(), Json::Num(self.read_bytes as f64));
        let max_dev = self.dev_reads.iter().map(|&(r, _)| r).max().unwrap_or(0);
        let min_dev = self.dev_reads.iter().map(|&(r, _)| r).min().unwrap_or(0);
        m.insert("dev_reads_max".into(), Json::Num(max_dev as f64));
        m.insert("dev_reads_min".into(), Json::Num(min_dev as f64));
        m.insert("charged_io_ms".into(), Json::Num(self.charged_io_ms));
        m.insert("wall_ms_sim".into(), Json::Num(self.wall_ms));
        Json::Obj(m)
    }

    fn row(&self) -> String {
        format!(
            "{:<14} devices {}  coalesce {:<3}  reqs {:>6}  charged {:>10}B  per-dev {:?}  charged_io {:>8.2}ms  wall {:>8.2}ms",
            self.label,
            self.devices,
            if self.coalesce { "on" } else { "off" },
            self.reads,
            self.read_bytes,
            self.dev_reads.iter().map(|&(r, _)| r).collect::<Vec<_>>(),
            self.charged_io_ms,
            self.wall_ms,
        )
    }
}

/// Charged epoch I/O time: the bottleneck device's service demand under the
/// SSD model — requests against the IOPS ceiling plus bytes against the
/// bandwidth ceiling. Devices run in parallel, so the max governs the epoch.
fn charged_io_ms(dev_reads: &[(u64, u64)], cfg: &SsdConfig) -> f64 {
    dev_reads
        .iter()
        .map(|&(r, b)| r as f64 / cfg.iops + b as f64 / cfg.read_bw)
        .fold(0.0, f64::max)
        * 1e3
}

fn machine_for(devices: Option<usize>) -> (Machine, Dataset) {
    // Host budget above paper scale only so one buffer holds every extracted
    // row; SSD model, sector and staging bound stay paper. `None` builds the
    // flat pre-striping stack (no devices/stripe knobs touched at all).
    let mut cfg = MachineConfig::paper().with_host_mem(1 << 30);
    if let Some(d) = devices {
        cfg = cfg.with_devices(d).with_stripe_bytes(STRIPE);
    }
    let machine = Machine::new(cfg, Clock::new(1.0));
    let ds = Dataset::materialize(&DatasetSpec::papers100m_mini(), &machine)
        .expect("materialize papers100m-mini");
    (machine, ds)
}

/// Extract rows 0..ROWS once on a fresh feature buffer; returns the run's
/// charged accounting (aggregate + per device) and sim wall time.
fn run_extraction(
    machine: &Machine,
    ds: &Dataset,
    coalesce: CoalesceConfig,
    label: &'static str,
) -> Run {
    let fb = Arc::new(
        FeatureBuffer::in_host(&machine.host, ROWS as usize + 64, ds.spec.dim).unwrap(),
    );
    let staging =
        StagingBuffer::new(&machine.host, 4096, ds.features.row_bytes() as usize).unwrap();
    let ex = Extractor::with_options(
        machine.backend.clone(),
        IO_DEPTH,
        staging,
        fb.clone(),
        ds.features.clone(),
        ExtractTarget::Host,
        ExtractOptions { coalesce, ..Default::default() },
    );
    machine.backend.reset_io_stats();
    let dev0 = machine.backend.device_io_snapshot();
    let nodes: Vec<u32> = (0..ROWS).collect();
    let t0 = Instant::now();
    let aliases = ex.extract(&nodes);
    let wall = machine.clock.to_sim(t0.elapsed());
    std::hint::black_box(&aliases);
    let dev_reads: Vec<(u64, u64)> = machine
        .backend
        .device_io_snapshot()
        .iter()
        .enumerate()
        .map(|(d, &(r, b))| {
            let (r0, b0) = dev0.get(d).copied().unwrap_or((0, 0));
            (r - r0, b - b0)
        })
        .collect();
    Run {
        label,
        devices: machine.backend.stripe().devices,
        coalesce: coalesce.enabled(),
        reads: machine
            .backend
            .io_counters()
            .reads
            .load(std::sync::atomic::Ordering::Relaxed),
        read_bytes: machine
            .backend
            .io_counters()
            .read_bytes
            .load(std::sync::atomic::Ordering::Relaxed),
        dev_reads: dev_reads.clone(),
        charged_io_ms: charged_io_ms(&dev_reads, &machine.cfg.ssd),
        wall_ms: wall.as_secs_f64() * 1e3,
    }
}

fn main() {
    let mut records = Vec::new();

    // ---- scaling: 1 vs 4 devices, IOPS-bound offered load -----------------
    println!("materializing papers100m-mini (flat + striped machines) …");
    let (flat, flat_ds) = machine_for(None);
    let (one, one_ds) = machine_for(Some(1));
    let (four, four_ds) = machine_for(Some(4));

    let r1 = run_extraction(&one, &one_ds, CoalesceConfig::disabled(), "striped-d1");
    println!("{}", r1.row());
    let r4 = run_extraction(&four, &four_ds, CoalesceConfig::disabled(), "striped-d4");
    println!("{}", r4.row());
    let ratio = r1.charged_io_ms / r4.charged_io_ms.max(1e-9);
    println!("  -> charged epoch I/O time {ratio:.2}x lower with 4 devices (wall: {:.2}ms -> {:.2}ms)",
        r1.wall_ms, r4.wall_ms);
    assert_eq!(r4.dev_reads.len(), 4, "four devices must each report charges");
    assert!(
        r4.dev_reads.iter().all(|&(r, _)| r > 0),
        "round-robin placement must load every device: {:?}",
        r4.dev_reads
    );
    assert!(
        ratio >= 2.5,
        "acceptance: devices=4 charged I/O time only {ratio:.2}x lower (>= 2.5x required)"
    );

    // ---- parity: devices=1 must equal the pre-striping flat stack --------
    let mut parity = Vec::new();
    for (coalesce, tag_flat, tag_one) in [
        (CoalesceConfig::disabled(), "flat-nocoal", "d1-nocoal"),
        (CoalesceConfig::default(), "flat-coal", "d1-coal"),
    ] {
        let rf = run_extraction(&flat, &flat_ds, coalesce, tag_flat);
        println!("{}", rf.row());
        let r1 = run_extraction(&one, &one_ds, coalesce, tag_one);
        println!("{}", r1.row());
        assert_eq!(
            (r1.reads, r1.read_bytes),
            (rf.reads, rf.read_bytes),
            "acceptance: devices=1 charging must match the flat stack exactly ({tag_one})"
        );
        parity.push((rf, r1));
    }
    println!("acceptance: devices=1 charging identical to pre-striping stack (requests + bytes)");
    println!("acceptance: devices=4 charged I/O time {ratio:.2}x lower (>= 2.5x required)");

    records.push(r1);
    records.push(r4);
    for (rf, r1) in parity {
        records.push(rf);
        records.push(r1);
    }

    let line = Json::Arr(records.iter().map(Run::json).collect()).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_stripe.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended {} records to BENCH_stripe.json", records.len()),
        Err(e) => eprintln!("could not append to BENCH_stripe.json: {e}"),
    }
}
