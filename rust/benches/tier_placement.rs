//! `cargo bench --bench tier_placement` — tiered feature placement
//! benchmark (ISSUE 10): the GPU-resident hot tier vs the single-tier host
//! buffer on the sim backend, with four acceptance gates:
//!
//! * **The hot head goes device-resident.** On a cubic-skew serve workload
//!   (`--hot-nodes`, the serving frontend's popularity model), a warm GPU
//!   tier must serve ≥80% of buffer hits from device memory
//!   (`gpu_hit_fraction ≥ 0.8`).
//! * **Tiering beats single-tier on tail latency.** At the same offered
//!   load and measured on a warm engine, `--tier gpu` must achieve
//!   strictly lower p99 extract latency than `--tier host` — promoted rows
//!   stop competing for host slots and stop reloading from SSD.
//! * **Explicit tiering beats UVM oversubscription.** With the same
//!   (deliberately undersized) device budget and a working set larger than
//!   capacity, explicit promote/demote must charge strictly fewer PCIe
//!   bytes than the `--gpu-oversub` ablation, which pays a fault migration
//!   on every over-capacity access.
//! * **`--tier host` is charge-identical.** A deterministic schedule driven
//!   through the host-tier store must produce exactly the charged requests,
//!   bytes, and buffer-reuse counters of the raw pre-tier buffer — same
//!   aliases, same stats, zero tier counters.
//!
//! Machine-readable results append to `BENCH_tier.json` (one JSON array per
//! run, JSONL); `scripts/tier1.sh` runs this bench and prints the last
//! record.

use gnndrive::config::{Machine, MachineConfig};
use gnndrive::extract::{CoalesceConfig, ExtractOptions, ExtractTarget, Extractor};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::membuf::{FeatureBuffer, StagingBuffer};
use gnndrive::serve::{BatchSpec, ServeConfig, ServeEngine, ServeReport};
use gnndrive::sim::Clock;
use gnndrive::storage::IoBackend as _;
use gnndrive::tier::{TierKind, TierSnapshot, TieredFeatureStore};
use gnndrive::util::json::Json;
use gnndrive::util::rng::Pcg;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn record(label: &str, r: &ServeReport) -> Json {
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    let mut m = BTreeMap::new();
    m.insert("bench".into(), Json::Str("tier_placement".into()));
    m.insert("config".into(), Json::Str(label.into()));
    m.insert("completed".into(), Json::Num(r.completed as f64));
    m.insert("extract_p50_ms".into(), Json::Num(ms(r.stages.extract.p50())));
    m.insert("extract_p99_ms".into(), Json::Num(ms(r.stages.extract.p99())));
    m.insert("e2e_p99_ms".into(), Json::Num(ms(r.stages.total.p99())));
    m.insert("ssd_requests".into(), Json::Num(r.ssd_read_requests as f64));
    m.insert("ssd_bytes".into(), Json::Num(r.ssd_read_bytes as f64));
    m.insert("buffer_hits".into(), Json::Num(r.buffer_hits as f64));
    m.insert("buffer_loads".into(), Json::Num(r.buffer_loads as f64));
    let t = r.tier.unwrap_or_default();
    m.insert("gpu_hits".into(), Json::Num(t.gpu_hits as f64));
    m.insert("host_hits".into(), Json::Num(t.host_hits as f64));
    m.insert("gpu_hit_fraction".into(), Json::Num(t.gpu_hit_fraction()));
    m.insert("promotions".into(), Json::Num(t.promotions as f64));
    m.insert("demotions".into(), Json::Num(t.demotions as f64));
    m.insert("bypassed".into(), Json::Num(t.bypassed as f64));
    m.insert("oversub_faults".into(), Json::Num(t.oversub_faults as f64));
    m.insert("pcie_saved_bytes".into(), Json::Num(t.pcie_saved_bytes as f64));
    m.insert("pcie_tier_bytes".into(), Json::Num(t.pcie_tier_bytes as f64));
    Json::Obj(m)
}

fn row(label: &str, r: &ServeReport) -> String {
    format!("{label:<18} {}", r.summary())
}

/// The shared load: one-hop inference, tiny matched batches, requests
/// concentrated on a cubic-skew hot head, and a deliberately residency-
/// starved host buffer — so placement capacity, not batching, decides the
/// tails.
fn base_cfg() -> ServeConfig {
    ServeConfig {
        tenants: 4,
        workers: 4,
        requests: 600,
        clients: 16,
        admit_cap: 256,
        batch: BatchSpec { max_requests: 4, max_wait: Duration::from_millis(1) },
        fanouts: vec![10],
        io_depth: 16,
        buffer_mult: 8,
        hot_nodes: 2000,
        seed: 23,
        ..ServeConfig::default()
    }
}

fn gpu_cfg(gpu_mem: u64, oversub: bool) -> ServeConfig {
    ServeConfig { tier: TierKind::Gpu, gpu_mem, gpu_oversub: oversub, ..base_cfg() }
}

/// Warm the engine with one full epoch (promotions happen here), then
/// measure the second: the gates compare steady-state placement, not the
/// shared cold start.
fn warm_then_measure(engine: &ServeEngine) -> ServeReport {
    engine.run(0).expect("warm-up epoch");
    engine.run(1).expect("measured epoch")
}

/// Gate 4 driver: the same deterministic single-threaded schedule through a
/// raw `FeatureBuffer` and through a `--tier host` store, on two identical
/// machines, comparing per-batch aliases and every charge counter.
fn host_parity_check() {
    const SLOTS: usize = 192;
    const BATCHES: u64 = 120;
    let build = || {
        let machine = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &machine)
            .expect("materialize unit-test dataset");
        (machine, ds)
    };
    let (m_raw, ds_raw) = build();
    let (m_tier, ds_tier) = build();
    let fb_raw =
        Arc::new(FeatureBuffer::in_host(&m_raw.host, SLOTS, ds_raw.spec.dim).unwrap());
    let fb_tier =
        Arc::new(FeatureBuffer::in_host(&m_tier.host, SLOTS, ds_tier.spec.dim).unwrap());
    let store = TieredFeatureStore::host(fb_tier.clone());
    m_raw.backend.reset_io_stats();
    m_tier.backend.reset_io_stats();
    let extractor = |machine: &Machine, fb: &Arc<FeatureBuffer>, ds: &Dataset| {
        let staging =
            StagingBuffer::new(&machine.host, 64, ds.features.row_bytes() as usize).unwrap();
        Extractor::with_options(
            machine.backend.clone(),
            32,
            staging,
            fb.clone(),
            ds.features.clone(),
            ExtractTarget::Host,
            ExtractOptions { coalesce: CoalesceConfig::default(), ..Default::default() },
        )
    };
    let ex_raw = extractor(&m_raw, &fb_raw, &ds_raw);
    let ex_tier = extractor(&m_tier, &fb_tier, &ds_tier);
    let dim = ds_raw.spec.dim;
    let mut out_raw = vec![0f32; 32 * dim];
    let mut out_tier = vec![0f32; 32 * dim];
    for i in 0..BATCHES {
        let mut rng = Pcg::with_stream(0x7143, i);
        let mut batch: Vec<u32> =
            (0..24).map(|_| rng.below(ds_raw.spec.nodes)).collect();
        batch.sort_unstable();
        batch.dedup();
        let a_raw = ex_raw.extract(&batch);
        let a_tier = ex_tier.extract(&batch);
        assert_eq!(a_raw, a_tier, "batch {i}: host-tier store changed alias assignment");
        fb_raw.gather(&a_raw, &mut out_raw[..batch.len() * dim]);
        store.gather(&a_tier, &mut out_tier[..batch.len() * dim]);
        assert_eq!(
            out_raw[..batch.len() * dim],
            out_tier[..batch.len() * dim],
            "batch {i}: host-tier store changed gathered bytes"
        );
        fb_raw.release_aliases(&a_raw);
        store.release_aliases(&a_tier);
        assert_eq!(fb_raw.stats(), fb_tier.stats(), "batch {i}: buffer-reuse divergence");
    }
    let reads = |m: &Machine| {
        (
            m.backend.io_counters().reads.load(Ordering::Relaxed),
            m.backend.io_counters().read_bytes.load(Ordering::Relaxed),
        )
    };
    assert_eq!(reads(&m_raw), reads(&m_tier), "host tier changed charged requests/bytes");
    assert_eq!(
        m_raw.backend.direct_stats().snapshot(),
        m_tier.backend.direct_stats().snapshot(),
        "host tier changed direct-I/O accounting"
    );
    assert_eq!(
        store.snapshot(),
        TierSnapshot::default(),
        "host tier must keep every tier counter at zero"
    );
    store.check_invariants().unwrap();
    println!(
        "host-parity        {} batches: aliases, bytes, {:?} stats, {:?} io charges all equal",
        BATCHES,
        fb_raw.stats(),
        reads(&m_raw),
    );
}

fn main() {
    // Same mild sim-time compression as the serve bench: tails mix device
    // sleeps with real CPU work; charged counters are clock-independent.
    let machine = Arc::new(Machine::new(
        MachineConfig::paper().with_host_mem(1 << 30),
        Clock::new(0.5),
    ));
    println!("materializing papers100m-mini …");
    let ds = Arc::new(
        Dataset::materialize(&DatasetSpec::papers100m_mini(), &machine)
            .expect("materialize papers100m-mini"),
    );
    let row_bytes = ds.spec.dim as u64 * 4;
    let mut records = Vec::new();

    // ---- gates 1 + 2: generous GPU tier vs single-tier host, same load ----
    // 128Ki rows (64 MiB at dim 128): the whole repeated working set fits,
    // so the comparison isolates placement, not device capacity.
    let roomy = 131_072 * row_bytes;
    let host_only = ServeEngine::new(&machine, &ds, base_cfg()).expect("host engine");
    let tiered = ServeEngine::new(&machine, &ds, gpu_cfg(roomy, false)).expect("gpu engine");
    let r_host = warm_then_measure(&host_only);
    println!("{}", row("tier-host", &r_host));
    let r_gpu = warm_then_measure(&tiered);
    println!("{}", row("tier-gpu", &r_gpu));
    assert_eq!(r_host.completed, base_cfg().requests, "host run must complete");
    assert_eq!(r_gpu.completed, base_cfg().requests, "gpu run must complete");
    assert!(r_host.tier.is_none(), "host mode must not report tier counters");

    let t_gpu = r_gpu.tier.expect("gpu run reports tier counters");
    let p99_host = r_host.stages.extract.p99();
    let p99_gpu = r_gpu.stages.extract.p99();
    println!(
        "  -> gpu hit fraction {:.3} ({} gpu / {} host hits); extract p99 {:.3}ms (gpu) vs {:.3}ms (host); ssd reqs {} vs {}",
        t_gpu.gpu_hit_fraction(),
        t_gpu.gpu_hits,
        t_gpu.host_hits,
        p99_gpu.as_secs_f64() * 1e3,
        p99_host.as_secs_f64() * 1e3,
        r_gpu.ssd_read_requests,
        r_host.ssd_read_requests,
    );
    // Acceptance gate 1: the cubic-skew hot head ends up device-resident —
    // a warm tier serves ≥80% of buffer hits from GPU memory.
    assert!(
        t_gpu.gpu_hit_fraction() >= 0.8,
        "acceptance: warm GPU tier must serve ≥80% of hits ({} gpu / {} host)",
        t_gpu.gpu_hits,
        t_gpu.host_hits
    );
    assert!(t_gpu.pcie_saved_bytes > 0, "gpu hits must bank saved batch transfers");
    // Acceptance gate 2: tiering strictly beats the single-tier host buffer
    // on tail extract latency at the same offered load.
    assert!(
        p99_gpu < p99_host,
        "acceptance: tiered p99 extract {p99_gpu:?} must beat single-tier {p99_host:?}"
    );
    records.push(record("tier-host", &r_host));
    records.push(record("tier-gpu", &r_gpu));

    // ---- gate 3: explicit tiering vs UVM oversubscription, tiny budget ----
    // 1Ki rows (512 KiB): far below the hot working set, so the placement
    // policy is actually exercised — explicit mode demotes, the ablation
    // spills past capacity and pays a migration per over-capacity access.
    let tiny = 1024 * row_bytes;
    let explicit = ServeEngine::new(&machine, &ds, gpu_cfg(tiny, false)).expect("explicit");
    let oversub = ServeEngine::new(&machine, &ds, gpu_cfg(tiny, true)).expect("oversub");
    let r_explicit = warm_then_measure(&explicit);
    println!("{}", row("tier-gpu-tiny", &r_explicit));
    let r_oversub = warm_then_measure(&oversub);
    println!("{}", row("tier-gpu-oversub", &r_oversub));
    let t_explicit = r_explicit.tier.expect("explicit tier counters");
    let t_oversub = r_oversub.tier.expect("oversub tier counters");
    println!(
        "  -> pcie tier bytes {} (explicit, {} demotions) vs {} (oversub, {} faults)",
        t_explicit.pcie_tier_bytes,
        t_explicit.demotions,
        t_oversub.pcie_tier_bytes,
        t_oversub.oversub_faults,
    );
    assert!(t_explicit.demotions > 0, "an undersized explicit tier must demote");
    assert!(t_oversub.oversub_faults > 0, "an undersized oversub tier must fault");
    // Acceptance gate 3: explicit promote/demote placement charges strictly
    // fewer PCIe bytes than faulting on every over-capacity access.
    assert!(
        t_explicit.pcie_tier_bytes < t_oversub.pcie_tier_bytes,
        "acceptance: explicit tiering must charge fewer PCIe bytes ({} vs {})",
        t_explicit.pcie_tier_bytes,
        t_oversub.pcie_tier_bytes
    );
    records.push(record("tier-gpu-tiny", &r_explicit));
    records.push(record("tier-gpu-oversub", &r_oversub));

    // ---- gate 4: `--tier host` charge parity with the pre-tier stack ----
    host_parity_check();

    println!(
        "acceptance: gpu hit fraction {:.3} ≥ 0.8; tiered p99 {:.3}ms < host {:.3}ms; \
         explicit {} < oversub {} pcie bytes; host-tier parity exact",
        t_gpu.gpu_hit_fraction(),
        p99_gpu.as_secs_f64() * 1e3,
        p99_host.as_secs_f64() * 1e3,
        t_explicit.pcie_tier_bytes,
        t_oversub.pcie_tier_bytes,
    );

    let line = Json::Arr(records).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_tier.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended 4 records to BENCH_tier.json"),
        Err(e) => eprintln!("could not append to BENCH_tier.json: {e}"),
    }
}
