//! `cargo bench --bench fault_tolerance` — fault-tolerance cost/benefit
//! sweep (ISSUE 6): training epochs under injected transient-fault storms at
//! rates 0 / 0.1% / 1%, comparing the engine retry policy (bounded retries,
//! exponential backoff, batch-level re-extract) against a fail-fast policy
//! (no retries, abort on first error). Reported per run: sim epoch time,
//! retries, typed failures, and whether the epoch completed — the fault-
//! tolerance headline is that retry completes every storm with
//! `io_failures == 0` while fail-fast aborts with a typed error (never a
//! hang), and the zero-rate rows bound the wrapper's overhead.
//!
//! Machine-readable results append to `BENCH_faults.json` (one JSON array
//! per run, JSONL); `scripts/tier1.sh` runs this bench and prints the last
//! record.

use gnndrive::baselines::sim_trainer;
use gnndrive::config::{FaultProfile, Machine, MachineConfig, OnIoError, TrainConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::pipeline::{GnnDrive, Variant};
use gnndrive::runtime::simcompute::ModelKind;
use gnndrive::sim::Clock;
use gnndrive::storage::{FaultPlan, RetryPolicy};
use gnndrive::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;

const RATES: [f64; 3] = [0.0, 0.001, 0.01];
const BATCHES: usize = 6;

struct Run {
    rate: f64,
    policy: &'static str,
    max_retries: u32,
    completed: bool,
    epoch_ms: f64,
    batches: usize,
    retries: u64,
    failures: u64,
    dropped_rows: usize,
    error: String,
}

impl Run {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".into(), Json::Str("fault_tolerance".into()));
        m.insert("fault_rate".into(), Json::Num(self.rate));
        m.insert("policy".into(), Json::Str(self.policy.into()));
        m.insert("max_retries".into(), Json::Num(self.max_retries as f64));
        m.insert("completed".into(), Json::Bool(self.completed));
        m.insert("epoch_ms_sim".into(), Json::Num(self.epoch_ms));
        m.insert("batches".into(), Json::Num(self.batches as f64));
        m.insert("io_retries".into(), Json::Num(self.retries as f64));
        m.insert("io_failures".into(), Json::Num(self.failures as f64));
        m.insert("dropped_rows".into(), Json::Num(self.dropped_rows as f64));
        m.insert("error".into(), Json::Str(self.error.clone()));
        Json::Obj(m)
    }

    fn row(&self) -> String {
        format!(
            "rate {:>6.3}%  policy {:<6} retries<= {:<2} {:<9}  epoch {:>9.2}ms  batches {:>2}  retries {:>6}  failures {:>4}{}",
            self.rate * 100.0,
            self.policy,
            self.max_retries,
            if self.completed { "completed" } else { "ABORTED" },
            self.epoch_ms,
            self.batches,
            self.retries,
            self.failures,
            if self.error.is_empty() { String::new() } else { format!("  ({})", self.error) },
        )
    }
}

/// Mid-size synthetic graph: big enough that an epoch issues tens of
/// thousands of charged row reads (so even the 0.1% storm hits many times
/// and `io_retries > 0` is overwhelmingly certain), small enough to
/// materialize six times (one machine per fault profile) in seconds.
fn bench_spec() -> DatasetSpec {
    DatasetSpec {
        name: "fault-bench".into(),
        nodes: 60_000,
        avg_degree: 12.0,
        dim: 64,
        classes: 16,
        train_frac: 0.2,
        community_size: 200,
        homophily: 0.6,
        degree_alpha: 2.2,
        noise: 0.5,
        seed: 0xFAB0,
    }
}

/// Coalescing is disabled so every loaded row is its own charged request:
/// the per-offset fault draws then cover thousands of distinct offsets per
/// epoch, which is what makes the nonzero-rate assertions deterministic in
/// practice rather than a coin flip.
fn bench_cfg(on_io_error: OnIoError) -> TrainConfig {
    TrainConfig {
        batch_size: 512,
        fanouts: vec![10, 10],
        batches_per_epoch: Some(BATCHES),
        samplers: 2,
        extractors: 2,
        io_depth: 64,
        coalesce_bytes: 0,
        coalesce_gap: 0,
        seed: 23,
        on_io_error,
        ..TrainConfig::default()
    }
}

/// One full training epoch on a fresh machine wrapped with the given fault
/// plan + engine retry policy. Aborted epochs report the typed error text
/// and process-level retry/failure counters (the per-epoch stats never
/// materialize when the epoch fails).
fn run_epoch(rate: f64, policy_name: &'static str, policy: RetryPolicy, on: OnIoError) -> Run {
    let profile = FaultProfile { plan: FaultPlan::transient(0xFA_0001 + (rate * 1e6) as u64, rate), policy };
    let machine = Machine::new(MachineConfig::paper().with_fault(profile), Clock::new(0.02));
    let ds = Dataset::materialize(&bench_spec(), &machine).expect("materialize fault-bench");
    let machine = Arc::new(machine);
    let ds = Arc::new(ds);
    let cfg = bench_cfg(on);
    let trainer = sim_trainer(&machine, &ds, &cfg, ModelKind::GraphSage, Variant::Gpu, 64);
    let engine = GnnDrive::new(&machine, &ds, cfg, Variant::Gpu, trainer).expect("build engine");
    let (r0, f0, _) = machine.backend.direct_stats().fault_snapshot();
    let out = engine.try_run_epoch(0);
    let (r1, f1, _) = machine.backend.direct_stats().fault_snapshot();
    match out {
        Ok(st) => Run {
            rate,
            policy: policy_name,
            max_retries: machine.backend.retry_policy().max_retries,
            completed: true,
            epoch_ms: st.epoch_time.as_secs_f64() * 1e3,
            batches: st.batches,
            retries: st.io_retries,
            failures: st.io_failures,
            dropped_rows: st.dropped_rows,
            error: String::new(),
        },
        Err(e) => Run {
            rate,
            policy: policy_name,
            max_retries: machine.backend.retry_policy().max_retries,
            completed: false,
            epoch_ms: 0.0,
            batches: 0,
            retries: r1 - r0,
            failures: f1 - f0,
            dropped_rows: 0,
            error: format!("{e:#}"),
        },
    }
}

fn main() {
    let mut records = Vec::new();
    for &rate in &RATES {
        let retry = run_epoch(rate, "retry", RetryPolicy::default(), OnIoError::Retry);
        println!("{}", retry.row());
        let fail = run_epoch(rate, "fail", RetryPolicy::none(), OnIoError::Fail);
        println!("{}", fail.row());

        if rate == 0.0 {
            // Zero-rate rows bound the fault layer's overhead: no retries,
            // no failures, both policies complete.
            for r in [&retry, &fail] {
                assert!(r.completed, "rate 0: {} policy must complete", r.policy);
                assert_eq!(r.retries, 0, "rate 0: no retries expected");
                assert_eq!(r.failures, 0, "rate 0: no failures expected");
                assert_eq!(r.batches, BATCHES, "rate 0: all batches must run");
            }
        } else {
            // The fault-tolerance headline: bounded retries + batch-level
            // re-extract ride out the storm with zero surfaced failures,
            // while fail-fast aborts with a typed error — never a hang.
            assert!(retry.completed, "rate {rate}: retry policy must complete the epoch");
            assert_eq!(retry.batches, BATCHES, "rate {rate}: retry policy must train every batch");
            assert!(retry.retries > 0, "rate {rate}: storm must have triggered retries");
            assert_eq!(retry.failures, 0, "rate {rate}: retry policy must surface zero failures");
            assert!(!fail.completed, "rate {rate}: fail-fast policy must abort");
            assert!(fail.failures > 0, "rate {rate}: fail-fast abort must count a typed failure");
            assert!(
                fail.error.contains("I/O error"),
                "rate {rate}: abort must carry the typed I/O error, got: {}",
                fail.error
            );
            println!(
                "  -> retry absorbed {} transient fault(s); fail-fast aborted after {} failure(s)",
                retry.retries, fail.failures
            );
        }
        records.push(retry);
        records.push(fail);
    }

    println!("acceptance: retry completes every storm with io_failures == 0; fail-fast aborts typed");

    let line = Json::Arr(records.iter().map(Run::json).collect()).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_faults.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended {} records to BENCH_faults.json", records.len()),
        Err(e) => eprintln!("could not append to BENCH_faults.json: {e}"),
    }
}
