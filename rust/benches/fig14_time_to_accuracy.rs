//! `cargo bench --bench fig14_time_to_accuracy` — regenerates paper Fig 14 (time-to-accuracy, real PJRT training).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig14(quick));
}
