//! `cargo bench --bench micro_hotpath` — microbenchmarks of the coordinator
//! hot-path structures (mapping table / standby list, bounded queues, LRU,
//! sampler CPU, feature-row synthesis). These back the §Perf iteration log
//! in EXPERIMENTS.md.

use gnndrive::bench::{measure, per_op};
use gnndrive::config::{Machine, MachineConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::membuf::FeatureBuffer;
use gnndrive::sample::Sampler;
use gnndrive::sim::queue::BoundedQueue;
use gnndrive::sim::Clock;
use gnndrive::storage::DeviceMemory;
use gnndrive::util::lru::Lru;
use gnndrive::util::rng::Pcg;
use std::sync::Arc;

fn main() {
    println!("# micro_hotpath — coordinator hot-path microbenchmarks\n");

    // Feature-buffer begin/release cycle (Algorithm 1 bookkeeping, no I/O).
    {
        let dev = DeviceMemory::new(1 << 30);
        let fb = FeatureBuffer::in_device(&dev, 64 * 1024, 128).unwrap();
        let mut rng = Pcg::new(1);
        let batch: Vec<u32> = (0..4096).map(|_| rng.below(1 << 20)).collect();
        let m = measure("feature_buffer begin+release (4096 nodes)", 3, 30, || {
            let plan = fb.begin_batch(&batch);
            // Publish a few so future batches exercise the hit path too.
            for &(node, slot) in plan.to_load.iter().take(64) {
                fb.publish(node, slot, &[0.0; 128]);
            }
            fb.release(&batch);
        });
        println!("{}", m.row());
        println!("  -> {:?}/node", per_op(&m, 4096));
    }

    // Standby-list LRU ops.
    {
        let mut lru: Lru<u32> = Lru::new();
        for i in 0..65_536u32 {
            lru.insert(i);
        }
        let mut i = 0u32;
        let m = measure("lru touch+pop+insert (batch of 1024)", 3, 50, || {
            for _ in 0..1024 {
                lru.touch(&(i % 65_536));
                if let Some(k) = lru.pop_lru() {
                    lru.insert(k);
                }
                i = i.wrapping_add(2654435761);
            }
        });
        println!("{}", m.row());
        println!("  -> {:?}/op", per_op(&m, 3 * 1024));
    }

    // Bounded queue round trip (the three pipeline queues are ID-only).
    {
        let q: BoundedQueue<u64> = BoundedQueue::new(1024);
        let m = measure("bounded queue push+pop (batch of 1024)", 3, 50, || {
            for v in 0..1024u64 {
                q.push(v).unwrap();
            }
            for _ in 0..1024 {
                q.pop().unwrap();
            }
        });
        println!("{}", m.row());
        println!("  -> {:?}/op", per_op(&m, 2 * 1024));
    }

    // Sampler CPU cost (warm page cache → pure coordinator work).
    {
        let machine = Machine::new(
            MachineConfig::paper().with_host_mem(1 << 30),
            Clock::new(1.0),
        );
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap();
        let sampler = Sampler::new(vec![10, 10], 7);
        let seeds: Vec<u32> = ds.train_ids.iter().take(256).copied().collect();
        sampler.sample_batch(&ds, &machine.storage, 0, &seeds); // warm
        let mut b = 1u64;
        let m = measure("sampler 2-hop (256 seeds, fanout 10, warm cache)", 2, 15, || {
            let sub = sampler.sample_batch(&ds, &machine.storage, b, &seeds);
            std::hint::black_box(&sub);
            b += 1;
        });
        println!("{}", m.row());
    }

    // Procedural feature-row synthesis (backing-store hot loop).
    {
        let labels = Arc::new(vec![0u16; 1 << 16]);
        let gen = gnndrive::graph::FeatureGen::new(3, 128, 4, 0.5, labels);
        let mut row = vec![0u8; 512];
        let mut v = 0u64;
        let m = measure("feature row synthesis (dim 128, batch of 256)", 3, 50, || {
            for _ in 0..256 {
                gen.fill_row(v % (1 << 16), &mut row);
                v = v.wrapping_add(7919);
            }
        });
        println!("{}", m.row());
        println!("  -> {:?}/row", per_op(&m, 256));
    }
}
