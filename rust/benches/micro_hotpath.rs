//! `cargo bench --bench micro_hotpath` — microbenchmarks of the coordinator
//! hot-path structures (feature-buffer bookkeeping under contention, bounded
//! queues, LRU, sampler CPU, feature-row synthesis). These back the §Perf
//! iteration log in EXPERIMENTS.md.
//!
//! The feature-buffer sections run begin+publish+release workloads against
//! all three coordinator generations — the lock-free-allocation
//! [`FeatureBuffer`], the PR-1 sharded mutex-LRU baseline, and the original
//! single-mutex design — single-threaded and with 4/8 concurrent extractor
//! threads: a mixed reuse workload, plus an alloc/release-heavy high-steal
//! workload that isolates the slot-allocation path. Machine-readable
//! results append to `BENCH_hotpath.json` so future PRs can track the
//! contention numbers.

use gnndrive::bench::{measure, per_op};
use gnndrive::config::{Machine, MachineConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::membuf::{FeatureBuffer, MutexLruFeatureBuffer, SingleMutexFeatureBuffer};
use gnndrive::sample::Sampler;
use gnndrive::sim::queue::BoundedQueue;
use gnndrive::sim::Clock;
use gnndrive::storage::DeviceMemory;
use gnndrive::util::json::Json;
use gnndrive::util::lru::Lru;
use gnndrive::util::rng::Pcg;
use std::collections::BTreeMap;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

const DIM: usize = 16;
const ROW: [f32; DIM] = [0.5; DIM];

/// The coordinator workload: plan a batch, publish every planned load,
/// release. Implemented for every coordinator generation so the bench
/// bodies are shared; each generation releases through its own production
/// path (by alias for the lock-free buffer, by node for the baselines).
trait Coordinator: Sync {
    fn run_batch(&self, batch: &[u32]);
}

impl Coordinator for FeatureBuffer {
    fn run_batch(&self, batch: &[u32]) {
        let plan = self.begin_batch(batch);
        for &(node, slot) in &plan.to_load {
            self.publish(node, slot, &ROW);
        }
        // The production release path: by alias, no map lookup, no lock.
        self.release_aliases(&plan.aliases);
    }
}

impl Coordinator for MutexLruFeatureBuffer {
    fn run_batch(&self, batch: &[u32]) {
        let plan = self.begin_batch(batch);
        for &(node, slot) in &plan.to_load {
            self.publish(node, slot, &ROW);
        }
        self.release(batch);
    }
}

impl Coordinator for SingleMutexFeatureBuffer {
    fn run_batch(&self, batch: &[u32]) {
        let plan = self.begin_batch(batch);
        for &(node, slot) in &plan.to_load {
            self.publish(node, slot, &ROW);
        }
        self.release(batch);
    }
}

/// One record for stdout + BENCH_hotpath.json.
struct Record {
    name: String,
    threads: usize,
    per_op_ns: f64,
    mean_ns: f64,
    min_ns: f64,
    ops: u64,
}

/// Convert a harness `Measurement` into a single-threaded record; `ops` is
/// the number of operations one iteration performs (the per-op divisor).
fn record_of(m: &gnndrive::bench::Measurement, ops: u64) -> Record {
    Record {
        name: m.name.clone(),
        threads: 1,
        per_op_ns: per_op(m, ops).as_nanos() as f64,
        mean_ns: m.mean.as_nanos() as f64,
        min_ns: m.min.as_nanos() as f64,
        ops,
    }
}

impl Record {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("threads".into(), Json::Num(self.threads as f64));
        m.insert("per_op_ns".into(), Json::Num(self.per_op_ns));
        m.insert("mean_ns".into(), Json::Num(self.mean_ns));
        m.insert("min_ns".into(), Json::Num(self.min_ns));
        m.insert("ops".into(), Json::Num(self.ops as f64));
        Json::Obj(m)
    }
}

/// Per-thread node-id stream: mostly disjoint ranges (each extractor works
/// its own region of the graph) with enough reuse for hits and steals.
fn batch_for(thread: usize, iter: u64, batch_len: usize, id_space: u32) -> Vec<u32> {
    let mut rng = Pcg::with_stream(0xB0B + thread as u64, iter);
    (0..batch_len)
        .map(|_| thread as u32 * id_space + rng.below(id_space))
        .collect()
}

/// Run `iters` batches of `batch_len` on each of `threads` threads against
/// one shared coordinator; repeat `reps` times and keep mean + best. The
/// per-thread workload comes from `gen_batch(thread, iter)`.
fn bench_coordinator<C: Coordinator + ?Sized>(
    name: &str,
    fb: &C,
    threads: usize,
    iters: u64,
    batch_len: usize,
    reps: usize,
    gen_batch: &(dyn Fn(usize, u64) -> Vec<u32> + Sync),
) -> Record {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let barrier = Barrier::new(threads);
        let elapsed = std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let barrier = &barrier;
                    s.spawn(move || {
                        // Generate the workload outside the timed region so
                        // RNG/alloc cost does not dilute the measured ratio.
                        let batches: Vec<Vec<u32>> =
                            (0..iters).map(|i| gen_batch(t, i)).collect();
                        barrier.wait();
                        let t0 = Instant::now();
                        for batch in &batches {
                            fb.run_batch(batch);
                        }
                        t0.elapsed()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).max().unwrap()
        });
        samples.push(elapsed);
    }
    let ops = threads as u64 * iters * batch_len as u64;
    let mean = samples.iter().sum::<Duration>() / reps as u32;
    let min = *samples.iter().min().unwrap();
    let rec = Record {
        name: name.to_string(),
        threads,
        per_op_ns: mean.as_nanos() as f64 / ops as f64,
        mean_ns: mean.as_nanos() as f64,
        min_ns: min.as_nanos() as f64,
        ops,
    };
    println!(
        "{:<52} {:>8.1} ns/op  (mean {:>9?}, best {:>9?}, {} threads)",
        rec.name,
        rec.per_op_ns,
        mean,
        min,
        threads
    );
    rec
}

/// Fully-unique node ids per (thread, iter): every batch is ~all misses, so
/// once the buffer warms, every allocation is an eviction — the
/// alloc/release-heavy, high-steal workload that isolates the slot
/// allocation path (hits and sharing are measured by the mixed workload).
fn fresh_batch(thread: usize, iter: u64, batch_len: usize) -> Vec<u32> {
    (0..batch_len as u32)
        .map(|k| ((thread as u32) << 24) | (iter as u32 * batch_len as u32 + k))
        .collect()
}

fn main() {
    println!("# micro_hotpath — coordinator hot-path microbenchmarks\n");
    let mut records: Vec<Record> = Vec::new();

    // Feature-buffer begin+publish+release (Algorithm 1 bookkeeping, no
    // I/O): lock-free-allocation coordinator vs the single-mutex baseline,
    // 1/4/8 concurrent extractor threads on one shared buffer. Mixed
    // workload: per-thread id regions with reuse (hits + steals).
    {
        const SLOTS: usize = 16 * 1024;
        const BATCH: usize = 1024;
        const ITERS: u64 = 40;
        println!("## feature buffer: sharded vs single-mutex baseline");
        let mixed = |t: usize, i: u64| batch_for(t, i, BATCH, 100_000);
        for &threads in &[1usize, 4, 8] {
            let dev = DeviceMemory::new(1 << 30);
            let sharded = FeatureBuffer::in_device(&dev, SLOTS, DIM).unwrap();
            let r_sharded = bench_coordinator(
                &format!("sharded begin+publish+release t{threads}"),
                &sharded,
                threads,
                ITERS,
                BATCH,
                3,
                &mixed,
            );
            let baseline = SingleMutexFeatureBuffer::in_device(&dev, SLOTS, DIM).unwrap();
            let r_base = bench_coordinator(
                &format!("single-mutex begin+publish+release t{threads}"),
                &baseline,
                threads,
                ITERS,
                BATCH,
                3,
                &mixed,
            );
            println!(
                "  -> t{threads} speedup: {:.2}x per-op (shards={})\n",
                r_base.per_op_ns / r_sharded.per_op_ns,
                sharded.shard_count(),
            );
            records.push(r_sharded);
            records.push(r_base);
        }
    }

    // Allocation-path shoot-out: alloc/release-heavy, high-steal workload
    // (every batch is fresh ids → once warm, every slot comes from an
    // eviction) across all three coordinator generations — lock-free
    // (Treiber stack + clock), PR-1 sharded mutex-LRU, and the original
    // single mutex. This is the workload the lock-free standby path exists
    // for: the mutex-LRU's per-shard standby lock is its last allocation
    // lock, and it serializes exactly here.
    {
        const SLOTS: usize = 16 * 1024; // ≥ threads × batch: blocking-free
        const BATCH: usize = 1024;
        const ITERS: u64 = 25;
        println!("## allocation path: lock-free vs mutex-LRU vs single-mutex (high steal)");
        let fresh = |t: usize, i: u64| fresh_batch(t, i, BATCH);
        for &threads in &[1usize, 4, 8] {
            let dev = DeviceMemory::new(1 << 30);
            let lockfree = FeatureBuffer::in_device(&dev, SLOTS, DIM).unwrap();
            let r_lockfree = bench_coordinator(
                &format!("lock-free alloc-heavy t{threads}"),
                &lockfree,
                threads,
                ITERS,
                BATCH,
                3,
                &fresh,
            );
            let mutex_lru = MutexLruFeatureBuffer::in_device(&dev, SLOTS, DIM).unwrap();
            let r_lru = bench_coordinator(
                &format!("mutex-lru alloc-heavy t{threads}"),
                &mutex_lru,
                threads,
                ITERS,
                BATCH,
                3,
                &fresh,
            );
            let single = SingleMutexFeatureBuffer::in_device(&dev, SLOTS, DIM).unwrap();
            let r_single = bench_coordinator(
                &format!("single-mutex alloc-heavy t{threads}"),
                &single,
                threads,
                ITERS,
                BATCH,
                3,
                &fresh,
            );
            let (_, _, steals, loads) = lockfree.stats();
            println!(
                "  -> t{threads}: lock-free {:.2}x vs mutex-lru, {:.2}x vs single-mutex (steals/loads {:.2})\n",
                r_lru.per_op_ns / r_lockfree.per_op_ns,
                r_single.per_op_ns / r_lockfree.per_op_ns,
                steals as f64 / loads.max(1) as f64,
            );
            records.push(r_lockfree);
            records.push(r_lru);
            records.push(r_single);
        }
    }

    // Standby-list LRU ops.
    {
        let mut lru: Lru<u32> = Lru::new();
        for i in 0..65_536u32 {
            lru.insert(i);
        }
        let mut i = 0u32;
        let m = measure("lru touch+pop+insert (batch of 1024)", 3, 50, || {
            for _ in 0..1024 {
                lru.touch(&(i % 65_536));
                if let Some(k) = lru.pop_lru() {
                    lru.insert(k);
                }
                i = i.wrapping_add(2654435761);
            }
        });
        println!("{}", m.row());
        println!("  -> {:?}/op", per_op(&m, 3 * 1024));
        records.push(record_of(&m, 3 * 1024));
    }

    // Bounded queue round trip (the three pipeline queues are ID-only).
    {
        let q: BoundedQueue<u64> = BoundedQueue::new(1024);
        let m = measure("bounded queue push+pop (batch of 1024)", 3, 50, || {
            for v in 0..1024u64 {
                q.push(v).unwrap();
            }
            for _ in 0..1024 {
                q.pop().unwrap();
            }
        });
        println!("{}", m.row());
        println!("  -> {:?}/op", per_op(&m, 2 * 1024));
        records.push(record_of(&m, 2 * 1024));
    }

    // Sampler CPU cost (warm page cache → pure coordinator work).
    {
        let machine = Machine::new(
            MachineConfig::paper().with_host_mem(1 << 30),
            Clock::new(1.0),
        );
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap();
        let sampler = Sampler::new(vec![10, 10], 7);
        let seeds: Vec<u32> = ds.train_ids.iter().take(256).copied().collect();
        sampler.sample_batch(&ds, &machine.storage, 0, &seeds); // warm
        let mut b = 1u64;
        let m = measure("sampler 2-hop (256 seeds, fanout 10, warm cache)", 2, 15, || {
            let sub = sampler.sample_batch(&ds, &machine.storage, b, &seeds);
            std::hint::black_box(&sub);
            b += 1;
        });
        println!("{}", m.row());
        records.push(record_of(&m, 1)); // one sampled batch per iteration
    }

    // Procedural feature-row synthesis (backing-store hot loop).
    {
        let labels = Arc::new(vec![0u16; 1 << 16]);
        let gen = gnndrive::graph::FeatureGen::new(3, 128, 4, 0.5, labels);
        let mut row = vec![0u8; 512];
        let mut v = 0u64;
        let m = measure("feature row synthesis (dim 128, batch of 256)", 3, 50, || {
            for _ in 0..256 {
                gen.fill_row(v % (1 << 16), &mut row);
                v = v.wrapping_add(7919);
            }
        });
        println!("{}", m.row());
        println!("  -> {:?}/row", per_op(&m, 256));
        records.push(record_of(&m, 256));
    }

    // Machine-readable sidecar for perf tracking across PRs: one JSON array
    // per run, appended as a line (JSONL) so earlier runs are preserved.
    let line = Json::Arr(records.iter().map(Record::json).collect()).to_string() + "\n";
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_hotpath.json")
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("\nappended {} records to BENCH_hotpath.json", records.len()),
        Err(e) => eprintln!("\ncould not append to BENCH_hotpath.json: {e}"),
    }
}
