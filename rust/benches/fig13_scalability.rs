//! `cargo bench --bench fig13_scalability` — regenerates paper Fig 13 (multi-GPU scalability).
//! Quick grids by default; GNNDRIVE_BENCH_FULL=1 for the full sweep.
fn main() {
    let quick = !gnndrive::experiments::is_full();
    print!("{}", gnndrive::experiments::fig13(quick));
}
