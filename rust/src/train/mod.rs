//! Train stage: the `TrainStep` abstraction plus loss/accuracy accounting.
//!
//! Two implementations exist: [`crate::runtime::PjrtTrainStep`] executes the
//! AOT-compiled JAX/Pallas artifact on the PJRT CPU client (real numerics —
//! the end-to-end example and Fig 14), and
//! [`crate::runtime::simcompute::SimTrainStep`] charges a roofline-model GPU
//! time (large sweeps, where the paper's train stage is never the
//! bottleneck: extract is 97.3 % of epoch time).

pub mod convergence;

use crate::sample::PaddedSubgraph;

/// Outcome of one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepResult {
    /// Mean cross-entropy over real (non-padded) seeds; NaN for simulated
    /// compute.
    pub loss: f32,
    /// Correct predictions among real seeds.
    pub correct: usize,
    /// Real seeds in the step.
    pub examples: usize,
}

/// A fixed-shape training step (one AOT artifact or one cost model).
pub trait TrainStep: Send {
    /// Node prefix caps per level (the padding shape contract).
    fn caps(&self) -> &[usize];
    /// Fixed fanouts per level.
    fn fanouts(&self) -> &[usize];
    /// Feature dimension.
    fn dim(&self) -> usize;
    /// Execute one step. `features` is row-major `[caps.last(), dim]`,
    /// gathered from the feature buffer by node alias.
    fn step(&mut self, batch: &PaddedSubgraph, features: &[f32]) -> StepResult;
    /// Read-only forward pass (the serving frontend's inference path): same
    /// shape contract as `step`, but parameters MUST NOT change. The default
    /// falls back to `step`, which is only correct for stateless cost
    /// models; real trainers override it (`TrainHandle` routes to its
    /// eval-only artifact, `SimTrainStep` charges forward-only time).
    fn forward(&mut self, batch: &PaddedSubgraph, features: &[f32]) -> StepResult {
        self.step(batch, features)
    }
    /// True when `loss`/`correct` are real numerics (PJRT path).
    fn is_real(&self) -> bool;
}

/// Running loss/accuracy aggregation over an epoch or a whole run.
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub steps: usize,
    pub examples: usize,
    pub correct: usize,
    pub loss_sum: f64,
}

impl TrainStats {
    pub fn push(&mut self, r: &StepResult) {
        self.steps += 1;
        self.examples += r.examples;
        self.correct += r.correct;
        if r.loss.is_finite() {
            self.loss_sum += r.loss as f64 * r.examples as f64;
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.examples == 0 {
            f64::NAN
        } else {
            self.loss_sum / self.examples as f64
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct as f64 / self.examples as f64
        }
    }

    pub fn merge(&mut self, other: &TrainStats) {
        self.steps += other.steps;
        self.examples += other.examples;
        self.correct += other.correct;
        self.loss_sum += other.loss_sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_aggregate() {
        let mut s = TrainStats::default();
        s.push(&StepResult { loss: 2.0, correct: 10, examples: 100 });
        s.push(&StepResult { loss: 1.0, correct: 30, examples: 100 });
        assert_eq!(s.steps, 2);
        assert!((s.mean_loss() - 1.5).abs() < 1e-9);
        assert!((s.accuracy() - 0.2).abs() < 1e-9);
        let mut t = TrainStats::default();
        t.merge(&s);
        assert_eq!(t.examples, 200);
    }

    #[test]
    fn nan_loss_ignored_in_mean() {
        let mut s = TrainStats::default();
        s.push(&StepResult { loss: f32::NAN, correct: 0, examples: 50 });
        assert_eq!(s.loss_sum, 0.0);
        assert_eq!(s.examples, 50);
    }
}
