//! Time-to-accuracy tracking (paper §5.3, Fig 14).
//!
//! Records (simulated time, epoch, loss, accuracy) points over a training
//! run and answers "when did the run first reach accuracy X".

use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct ConvergencePoint {
    pub time: Duration,
    pub epoch: usize,
    pub loss: f64,
    pub accuracy: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ConvergenceTrace {
    pub points: Vec<ConvergencePoint>,
}

impl ConvergenceTrace {
    pub fn record(&mut self, time: Duration, epoch: usize, loss: f64, accuracy: f64) {
        self.points.push(ConvergencePoint { time, epoch, loss, accuracy });
    }

    /// First time the accuracy reached `target`, if ever.
    pub fn time_to_accuracy(&self, target: f64) -> Option<Duration> {
        self.points.iter().find(|p| p.accuracy >= target).map(|p| p.time)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.points.iter().map(|p| p.accuracy).fold(0.0, f64::max)
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.loss)
    }

    /// Paper-style series rows: `time_s  epoch  loss  acc`.
    pub fn rows(&self) -> String {
        let mut out = String::from("time_s\tepoch\tloss\taccuracy\n");
        for p in &self.points {
            out.push_str(&format!(
                "{:.2}\t{}\t{:.4}\t{:.4}\n",
                p.time.as_secs_f64(),
                p.epoch,
                p.loss,
                p.accuracy
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let mut t = ConvergenceTrace::default();
        t.record(Duration::from_secs(1), 0, 2.0, 0.2);
        t.record(Duration::from_secs(2), 1, 1.0, 0.5);
        t.record(Duration::from_secs(3), 2, 0.5, 0.6);
        assert_eq!(t.time_to_accuracy(0.5), Some(Duration::from_secs(2)));
        assert_eq!(t.time_to_accuracy(0.9), None);
        assert_eq!(t.best_accuracy(), 0.6);
        assert_eq!(t.final_loss(), Some(0.5));
        assert!(t.rows().contains("2.00\t1\t1.0000\t0.5000"));
    }
}
