//! Backing stores: where the *bytes* of on-SSD data actually live.
//!
//! The SSD's timing is simulated ([`super::ssd::SsdSim`]) but every read
//! returns real bytes, so training consumes genuine data. Three stores:
//!
//! * [`FileBacking`] — a real file on the host filesystem (`pread`), used by
//!   the end-to-end example to prove the real-file path.
//! * [`MemBacking`]  — bytes held in process memory; used for generated
//!   topology ("on disk" in the simulation, reads still charge SSD time).
//! * [`ProceduralBacking`] — bytes synthesized deterministically on demand
//!   from `(region offset)` by a generator function; used for large feature
//!   tables so 100 GB-scale analogs need no disk space (DESIGN.md §3).
//! * [`StripedBacking`] — RAID-0 composition of N member stores: a logical
//!   byte range is split into `stripe_bytes` chunks laid out round-robin
//!   across members. [`StripeSpec`] owns the offset math; everything above
//!   the backing keeps purely logical offsets.

use super::api::IoError;
use std::fs::File;
use std::io;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};

/// Map a real OS read error to the typed I/O error surface.
fn os_err(e: &io::Error) -> IoError {
    IoError::Os { code: e.raw_os_error().unwrap_or(-1) }
}

/// Byte-addressed read-only store.
pub trait Backing: Send + Sync {
    fn len(&self) -> u64;

    /// Fill `buf` from `offset`; reads past the end zero-fill (the simulated
    /// device is sized by `len`, and aligned reads may overhang).
    fn read_at(&self, offset: u64, buf: &mut [u8]);

    /// Fallible `read_at`: surfaces real OS read errors as typed
    /// [`IoError`]s instead of panicking. Default: in-memory and procedural
    /// stores cannot fail.
    fn try_read_at(&self, offset: u64, buf: &mut [u8]) -> Result<(), IoError> {
        self.read_at(offset, buf);
        Ok(())
    }

    /// Like `read_at`, but bypassing the OS page cache where the store can
    /// (`O_DIRECT`). Returns `true` when the bytes were genuinely served
    /// through a direct descriptor, `false` when the cached path served them
    /// (the bounce-buffer fallback the backend surfaces as
    /// `DirectIoStats::direct_fallbacks`). Default: plain `read_at` — only
    /// [`FileBacking`] has a kernel cache to bypass; in-memory and
    /// procedural stores are their own "device".
    fn read_direct_at(&self, offset: u64, buf: &mut [u8]) -> bool {
        self.read_at(offset, buf);
        false
    }

    /// Fallible [`Backing::read_direct_at`] with the same `true` = really
    /// direct / `false` = cached-fallback result.
    fn try_read_direct_at(&self, offset: u64, buf: &mut [u8]) -> Result<bool, IoError> {
        Ok(self.read_direct_at(offset, buf))
    }

    /// Kernel-submittable translation of `[offset, offset+len)`: `Some((fd,
    /// physical_offset))` when the whole span is served by one real OS file
    /// descriptor at a single contiguous physical offset (so an `io_uring`
    /// read of `(fd, physical_offset, len)` returns exactly the bytes
    /// `read_at(offset, ..)` would). `None` (the default) for stores with no
    /// fd (memory, procedural) or spans straddling stripe members — those
    /// route through the engine's `serve_sqe` fallback instead. The returned
    /// fd remains owned by the backing; callers must not close it and must
    /// not outlive the backing.
    fn uring_target(&self, offset: u64, len: usize) -> Option<(i32, u64)> {
        let _ = (offset, len);
        None
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub type BackingRef = Arc<dyn Backing>;

/// RAID-0 stripe geometry: `devices` members, `stripe_bytes` chunk size.
///
/// This is the single owner of logical↔physical offset translation for the
/// whole storage stack: backings use it to route bytes, backends use it to
/// route charges, engines use it to route SQEs, and the coalescing planner
/// uses it to keep segments inside one chunk. `devices == 1` is the
/// degenerate identity mapping (every helper collapses to "device 0, same
/// offset"), which is what keeps single-device behavior byte-for-byte
/// identical to the pre-striping stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeSpec {
    pub devices: usize,
    pub stripe_bytes: u64,
}

impl StripeSpec {
    /// Identity geometry: one device, striping disabled.
    pub fn single() -> Self {
        StripeSpec { devices: 1, stripe_bytes: u64::MAX }
    }

    pub fn new(devices: usize, stripe_bytes: u64) -> Self {
        assert!(devices >= 1, "stripe needs at least one device");
        if devices == 1 {
            return StripeSpec::single();
        }
        assert!(stripe_bytes > 0, "stripe chunk must be non-empty");
        StripeSpec { devices, stripe_bytes }
    }

    /// Whether this spec maps anything anywhere (more than one device).
    pub fn is_striped(&self) -> bool {
        self.devices > 1
    }

    /// Which device serves logical `offset`.
    pub fn device_of(&self, offset: u64) -> usize {
        if !self.is_striped() {
            return 0;
        }
        ((offset / self.stripe_bytes) % self.devices as u64) as usize
    }

    /// Device-local offset of logical `offset` on its owning device.
    pub fn local_offset(&self, offset: u64) -> u64 {
        if !self.is_striped() {
            return offset;
        }
        let chunk = offset / self.stripe_bytes;
        (chunk / self.devices as u64) * self.stripe_bytes + offset % self.stripe_bytes
    }

    /// First logical offset past `offset`'s chunk — the point where the next
    /// byte lives on a different device. `u64::MAX` when unstriped, so
    /// "stay inside the chunk" comparisons degenerate to always-true.
    pub fn chunk_end(&self, offset: u64) -> u64 {
        if !self.is_striped() {
            return u64::MAX;
        }
        (offset / self.stripe_bytes + 1) * self.stripe_bytes
    }

    /// Split the logical range `[offset, offset+len)` into per-chunk runs of
    /// `(device, local_offset, run_len)`, in logical order.
    pub fn split(&self, offset: u64, len: usize) -> Vec<(usize, u64, usize)> {
        if !self.is_striped() || len == 0 {
            return vec![(self.device_of(offset), self.local_offset(offset), len)];
        }
        let mut runs = Vec::new();
        let mut at = offset;
        let end = offset + len as u64;
        while at < end {
            let run = (end - at).min(self.chunk_end(at) - at) as usize;
            runs.push((self.device_of(at), self.local_offset(at), run));
            at += run as u64;
        }
        runs
    }
}

/// RAID-0 over N member stores: logical offsets are translated through a
/// [`StripeSpec`] and delegated to the owning member at its local offset.
/// Multi-chunk reads stitch member reads back together in logical order.
pub struct StripedBacking {
    members: Vec<BackingRef>,
    spec: StripeSpec,
}

impl StripedBacking {
    pub fn new(members: Vec<BackingRef>, stripe_bytes: u64) -> Self {
        assert!(!members.is_empty(), "striped backing needs members");
        let spec = StripeSpec::new(members.len(), stripe_bytes);
        StripedBacking { members, spec }
    }

    pub fn spec(&self) -> StripeSpec {
        self.spec
    }
}

impl Backing for StripedBacking {
    fn len(&self) -> u64 {
        self.members.iter().map(|m| m.len()).sum()
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) {
        let mut at = 0usize;
        for (dev, local, run) in self.spec.split(offset, buf.len()) {
            self.members[dev].read_at(local, &mut buf[at..at + run]);
            at += run;
        }
    }

    fn try_read_at(&self, offset: u64, buf: &mut [u8]) -> Result<(), IoError> {
        let mut at = 0usize;
        for (dev, local, run) in self.spec.split(offset, buf.len()) {
            self.members[dev].try_read_at(local, &mut buf[at..at + run])?;
            at += run;
        }
        Ok(())
    }

    fn read_direct_at(&self, offset: u64, buf: &mut [u8]) -> bool {
        // Direct only if EVERY chunk was genuinely served O_DIRECT.
        let mut all_direct = true;
        let mut at = 0usize;
        for (dev, local, run) in self.spec.split(offset, buf.len()) {
            all_direct &= self.members[dev].read_direct_at(local, &mut buf[at..at + run]);
            at += run;
        }
        all_direct
    }

    fn try_read_direct_at(&self, offset: u64, buf: &mut [u8]) -> Result<bool, IoError> {
        let mut all_direct = true;
        let mut at = 0usize;
        for (dev, local, run) in self.spec.split(offset, buf.len()) {
            all_direct &=
                self.members[dev].try_read_direct_at(local, &mut buf[at..at + run])?;
            at += run;
        }
        Ok(all_direct)
    }

    fn uring_target(&self, offset: u64, len: usize) -> Option<(i32, u64)> {
        // Only a span confined to ONE member translates to one contiguous
        // physical read; multi-chunk spans reassemble through read_at.
        match self.spec.split(offset, len).as_slice() {
            [(dev, local, run)] => self.members[*dev].uring_target(*local, *run),
            _ => None,
        }
    }
}

/// `O_DIRECT` flag value per Linux arch ABI (not exposed by std; no libc in
/// the offline build). Zero on platforms where we don't attempt direct I/O.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "x86")))]
const O_DIRECT: i32 = 0o40000;
#[cfg(all(target_os = "linux", any(target_arch = "aarch64", target_arch = "arm")))]
const O_DIRECT: i32 = 0o200000;
#[cfg(not(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "x86", target_arch = "aarch64", target_arch = "arm")
)))]
const O_DIRECT: i32 = 0;

/// `O_DIRECT` alignment unit for offset, length and buffer memory: 4 KiB
/// covers every mainstream filesystem/device combination (logical block
/// sizes are 512 or 4096).
const DIO_ALIGN: usize = 4096;

/// Heap buffer aligned for `O_DIRECT` reads.
struct AlignedBuf {
    ptr: *mut u8,
    layout: std::alloc::Layout,
}

impl AlignedBuf {
    fn new(len: usize) -> Self {
        let layout = std::alloc::Layout::from_size_align(len.max(DIO_ALIGN), DIO_ALIGN)
            .expect("aligned layout");
        // SAFETY: non-zero size; allocation failure handled below.
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "aligned allocation failed");
        AlignedBuf { ptr, layout }
    }

    fn len(&self) -> usize {
        self.layout.size()
    }

    fn bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: owned allocation of `layout.size()` bytes.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.layout.size()) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        // SAFETY: allocated with this exact layout in `new`.
        unsafe { std::alloc::dealloc(self.ptr, self.layout) }
    }
}

// SAFETY: exclusive ownership of the raw allocation.
unsafe impl Send for AlignedBuf {}

/// Real file. Plain reads go through the kernel page cache; direct reads
/// ([`Backing::read_direct_at`]) use a lazily opened `O_DIRECT` descriptor
/// with an aligned bounce buffer, falling back to the cached descriptor —
/// with a one-time process warning — on filesystems that refuse the flag
/// (tmpfs, some network mounts).
pub struct FileBacking {
    file: File,
    len: u64,
    path: PathBuf,
    /// `Some(fd)` once an `O_DIRECT` open succeeded, `None` after a refusal.
    direct: OnceLock<Option<File>>,
}

/// One warning per process when `O_DIRECT` is unavailable and the `-direct`
/// path silently degrades to cached reads.
fn warn_no_odirect(path: &Path, why: &str) {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: O_DIRECT unavailable for {path:?} ({why}); \
             direct reads fall back to the OS page cache \
             (alignment accounting is unaffected)"
        );
    });
}

impl FileBacking {
    pub fn open(path: &Path) -> io::Result<Self> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        Ok(FileBacking { file, len, path: path.to_path_buf(), direct: OnceLock::new() })
    }

    /// The `O_DIRECT` descriptor, opened on first use; `None` (with a
    /// one-time warning) when the platform or filesystem refuses it.
    fn direct_file(&self) -> Option<&File> {
        self.direct
            .get_or_init(|| {
                if O_DIRECT == 0 {
                    warn_no_odirect(&self.path, "unsupported platform");
                    return None;
                }
                use std::os::unix::fs::OpenOptionsExt;
                match std::fs::OpenOptions::new()
                    .read(true)
                    .custom_flags(O_DIRECT)
                    .open(&self.path)
                {
                    Ok(f) => Some(f),
                    Err(e) => {
                        warn_no_odirect(&self.path, &e.to_string());
                        None
                    }
                }
            })
            .as_ref()
    }

    /// Serve `[offset, offset+buf.len())` through the `O_DIRECT` fd: read
    /// the covering `DIO_ALIGN`-aligned span into an aligned bounce buffer,
    /// then copy the requested window out. Returns false if the direct read
    /// could not be performed (caller falls back to the cached fd).
    fn try_read_odirect(&self, offset: u64, buf: &mut [u8]) -> bool {
        // One reusable bounce buffer per I/O thread (grown to the largest
        // span seen): direct reads are the extractor's hot path, and a
        // fresh aligned allocation per request would be a malloc+memset per
        // device read.
        thread_local! {
            static BOUNCE: std::cell::RefCell<Option<AlignedBuf>> =
                std::cell::RefCell::new(None);
        }
        let Some(fd) = self.direct_file() else { return false };
        let lo = offset / DIO_ALIGN as u64 * DIO_ALIGN as u64;
        let hi = (offset + buf.len() as u64).div_ceil(DIO_ALIGN as u64) * DIO_ALIGN as u64;
        let need = (hi - lo) as usize;
        BOUNCE.with(|cell| {
            let mut slot = cell.borrow_mut();
            if !slot.as_ref().is_some_and(|b| b.len() >= need) {
                *slot = Some(AlignedBuf::new(need));
            }
            let bounce = slot.as_mut().expect("bounce buffer just ensured");
            let span = &mut bounce.bytes_mut()[..need];
            // Fill only as far as the requested window needs, and stop at
            // EOF: a short read at an unaligned file tail must NOT be
            // retried — the follow-up offset/buffer/length would all be
            // unaligned and O_DIRECT rejects that with EINVAL. The unread
            // remainder is never copied out below.
            let want = (offset + buf.len() as u64 - lo) as usize;
            let mut filled = 0usize;
            while filled < want && lo + (filled as u64) < self.len {
                match fd.read_at(&mut span[filled..], lo + filled as u64) {
                    Ok(0) => break,
                    Ok(n) => filled += n,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        warn_no_odirect(&self.path, &e.to_string());
                        return false;
                    }
                }
            }
            let start = (offset - lo) as usize;
            let have = filled.saturating_sub(start).min(buf.len());
            buf[..have].copy_from_slice(&span[start..start + have]);
            buf[have..].fill(0);
            true
        })
    }
}

impl Backing for FileBacking {
    fn len(&self) -> u64 {
        self.len
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) {
        // Infallible entry point for callers with no error channel; the
        // fallible path is `try_read_at`.
        self.try_read_at(offset, buf).expect("backing file read failed");
    }

    fn try_read_at(&self, offset: u64, buf: &mut [u8]) -> Result<(), IoError> {
        buf.fill(0);
        if offset >= self.len {
            return Ok(());
        }
        let avail = (self.len - offset).min(buf.len() as u64) as usize;
        // read_exact_at on a read-only snapshot; a real OS error becomes a
        // typed completion error so the retry/degradation policy can act.
        self.file.read_exact_at(&mut buf[..avail], offset).map_err(|e| os_err(&e))
    }

    fn read_direct_at(&self, offset: u64, buf: &mut [u8]) -> bool {
        self.try_read_direct_at(offset, buf).expect("backing file direct read failed")
    }

    fn try_read_direct_at(&self, offset: u64, buf: &mut [u8]) -> Result<bool, IoError> {
        if buf.is_empty() {
            return Ok(true);
        }
        if offset >= self.len {
            buf.fill(0);
            return Ok(true);
        }
        if self.try_read_odirect(offset, buf) {
            return Ok(true);
        }
        self.try_read_at(offset, buf).map(|()| false)
    }

    fn uring_target(&self, offset: u64, len: usize) -> Option<(i32, u64)> {
        // Spans overhanging EOF fall back: read_at zero-fills the overhang
        // while a kernel read would come back short.
        if offset + len as u64 > self.len {
            return None;
        }
        use std::os::unix::io::AsRawFd;
        Some((self.file.as_raw_fd(), offset))
    }
}

/// In-process bytes (generated topology, labels, small tables).
pub struct MemBacking {
    bytes: Vec<u8>,
}

impl MemBacking {
    pub fn new(bytes: Vec<u8>) -> Self {
        MemBacking { bytes }
    }

    /// View a `u32` slice as a byte store (CSC indices).
    pub fn from_u32s(xs: &[u32]) -> Self {
        let mut bytes = Vec::with_capacity(xs.len() * 4);
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        MemBacking { bytes }
    }
}

impl Backing for MemBacking {
    fn len(&self) -> u64 {
        self.bytes.len() as u64
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) {
        buf.fill(0);
        if offset >= self.bytes.len() as u64 {
            return;
        }
        let off = offset as usize;
        let avail = (self.bytes.len() - off).min(buf.len());
        buf[..avail].copy_from_slice(&self.bytes[off..off + avail]);
    }
}

/// Procedurally generated bytes. The generator fills whole aligned `granule`
/// chunks (e.g. one feature row) so random access at any offset is served by
/// generating the covering chunks — deterministic, seekable, zero storage.
pub struct ProceduralBacking {
    len: u64,
    granule: u64,
    gen: Box<dyn Fn(u64, &mut [u8]) + Send + Sync>,
}

impl ProceduralBacking {
    /// `gen(chunk_index, out)` must fill `out` (of `granule` bytes, except a
    /// possibly-short final chunk) deterministically.
    pub fn new(
        len: u64,
        granule: u64,
        gen: impl Fn(u64, &mut [u8]) + Send + Sync + 'static,
    ) -> Self {
        assert!(granule > 0);
        ProceduralBacking { len, granule, gen: Box::new(gen) }
    }
}

impl Backing for ProceduralBacking {
    fn len(&self) -> u64 {
        self.len
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) {
        buf.fill(0);
        if offset >= self.len {
            return;
        }
        let end = (offset + buf.len() as u64).min(self.len);
        let first_chunk = offset / self.granule;
        let last_chunk = (end - 1) / self.granule;
        let mut chunk_buf = vec![0u8; self.granule as usize];
        for chunk in first_chunk..=last_chunk {
            let c_start = chunk * self.granule;
            let c_len = self.granule.min(self.len - c_start) as usize;
            (self.gen)(chunk, &mut chunk_buf[..c_len]);
            // Overlap of [c_start, c_start+c_len) with [offset, end).
            let lo = c_start.max(offset);
            let hi = (c_start + c_len as u64).min(end);
            if lo < hi {
                let src = (lo - c_start) as usize..(hi - c_start) as usize;
                let dst = (lo - offset) as usize..(hi - offset) as usize;
                buf[dst].copy_from_slice(&chunk_buf[src]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_backing_reads_and_zero_fills() {
        let b = MemBacking::from_u32s(&[1, 2, 3]);
        assert_eq!(b.len(), 12);
        let mut buf = [0u8; 8];
        b.read_at(4, &mut buf);
        assert_eq!(u32::from_le_bytes(buf[0..4].try_into().unwrap()), 2);
        assert_eq!(u32::from_le_bytes(buf[4..8].try_into().unwrap()), 3);
        // Past-end read zero-fills.
        let mut buf2 = [0xFFu8; 8];
        b.read_at(10, &mut buf2);
        assert_eq!(&buf2[2..], &[0, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn file_backing_roundtrip() {
        let dir = std::env::temp_dir().join("gnndrive_test_backing");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("file.bin");
        std::fs::write(&path, (0u8..200).collect::<Vec<u8>>()).unwrap();
        let b = FileBacking::open(&path).unwrap();
        assert_eq!(b.len(), 200);
        let mut buf = [0u8; 10];
        b.read_at(50, &mut buf);
        assert_eq!(buf, [50, 51, 52, 53, 54, 55, 56, 57, 58, 59]);
    }

    #[test]
    fn file_backing_direct_reads_match_cached_reads() {
        // O_DIRECT (or its graceful fallback) must return byte-identical
        // data at arbitrary offsets, including the zero-filled EOF overhang.
        let dir = std::env::temp_dir().join("gnndrive_test_backing");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("direct_{}.bin", std::process::id()));
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 249) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let b = FileBacking::open(&path).unwrap();
        for (off, len) in [(0usize, 512usize), (700, 100), (4095, 2), (9_990, 64)] {
            let mut cached = vec![0xAAu8; len];
            let mut direct = vec![0x55u8; len];
            b.read_at(off as u64, &mut cached);
            b.read_direct_at(off as u64, &mut direct);
            assert_eq!(cached, direct, "off={off} len={len}");
        }
        // Fully past-EOF direct read zero-fills.
        let mut tail = vec![0xFFu8; 16];
        b.read_direct_at(20_000, &mut tail);
        assert!(tail.iter().all(|&x| x == 0));
    }

    #[test]
    fn procedural_random_access_matches_sequential() {
        // Chunk c filled with byte (c % 251); verify unaligned reads stitch
        // chunks correctly.
        let b = ProceduralBacking::new(1000, 64, |c, out| {
            out.fill((c % 251) as u8);
        });
        let mut whole = vec![0u8; 1000];
        b.read_at(0, &mut whole);
        for (off, len) in [(0usize, 64usize), (10, 100), (63, 2), (500, 500), (990, 20)] {
            let mut buf = vec![0u8; len];
            b.read_at(off as u64, &mut buf);
            let end = (off + len).min(1000);
            assert_eq!(&buf[..end - off], &whole[off..end], "off={off} len={len}");
            assert!(buf[end - off..].iter().all(|&x| x == 0));
        }
    }

    #[test]
    fn stripe_spec_translates_raid0_offsets() {
        let s = StripeSpec::new(3, 64);
        // Chunk k lives on device k % 3 at local chunk k / 3.
        assert_eq!(s.device_of(0), 0);
        assert_eq!(s.device_of(63), 0);
        assert_eq!(s.device_of(64), 1);
        assert_eq!(s.device_of(128), 2);
        assert_eq!(s.device_of(192), 0);
        assert_eq!(s.local_offset(0), 0);
        assert_eq!(s.local_offset(70), 6);
        assert_eq!(s.local_offset(192), 64);
        assert_eq!(s.local_offset(200), 72);
        assert_eq!(s.chunk_end(0), 64);
        assert_eq!(s.chunk_end(63), 64);
        assert_eq!(s.chunk_end(64), 128);
        // A range crossing two boundaries splits into three runs.
        let runs = s.split(60, 80);
        assert_eq!(runs, vec![(0, 60, 4), (1, 0, 64), (2, 0, 12)]);
    }

    #[test]
    fn stripe_spec_single_is_identity() {
        let s = StripeSpec::new(1, 64);
        assert!(!s.is_striped());
        for off in [0u64, 17, 64, 1_000_000] {
            assert_eq!(s.device_of(off), 0);
            assert_eq!(s.local_offset(off), off);
            assert_eq!(s.chunk_end(off), u64::MAX);
        }
        assert_eq!(s.split(123, 456), vec![(0, 123, 456)]);
    }

    #[test]
    fn striped_backing_round_trips_logical_bytes() {
        // 3 members × 4 chunks of 64B; logical byte i = (i % 247).
        let devices = 3usize;
        let stripe = 64u64;
        let total = 3 * 4 * 64usize;
        let logical: Vec<u8> = (0..total).map(|i| (i % 247) as u8).collect();
        let spec = StripeSpec::new(devices, stripe);
        let mut per_dev: Vec<Vec<u8>> = vec![Vec::new(); devices];
        for (i, &b) in logical.iter().enumerate() {
            per_dev[spec.device_of(i as u64)].push(b);
        }
        let members: Vec<BackingRef> =
            per_dev.into_iter().map(|v| Arc::new(MemBacking::new(v)) as BackingRef).collect();
        let sb = StripedBacking::new(members, stripe);
        assert_eq!(sb.len(), total as u64);
        // Whole-range, chunk-straddling, and EOF-overhang reads all match.
        for (off, len) in [(0usize, total), (60, 80), (63, 2), (190, 5), (total - 10, 30)] {
            let mut buf = vec![0xFFu8; len];
            sb.read_at(off as u64, &mut buf);
            let end = (off + len).min(total);
            assert_eq!(&buf[..end - off], &logical[off..end], "off={off} len={len}");
            assert!(buf[end - off..].iter().all(|&x| x == 0), "EOF zero-fill off={off}");
        }
    }

    #[test]
    fn striped_backing_single_member_is_byte_identical() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 253) as u8).collect();
        let plain = MemBacking::new(data.clone());
        let striped = StripedBacking::new(vec![Arc::new(MemBacking::new(data))], 64);
        assert!(!striped.spec().is_striped());
        assert_eq!(plain.len(), striped.len());
        for (off, len) in [(0usize, 1000usize), (17, 100), (63, 2), (990, 20)] {
            let mut a = vec![0u8; len];
            let mut b = vec![0u8; len];
            plain.read_at(off as u64, &mut a);
            striped.read_at(off as u64, &mut b);
            assert_eq!(a, b, "off={off} len={len}");
        }
    }

    #[test]
    fn uring_target_translates_only_single_file_spans() {
        // Memory stores never translate.
        let mem = MemBacking::new(vec![0u8; 256]);
        assert_eq!(mem.uring_target(0, 64), None);

        // A real file translates in-bounds spans to (fd, same offset) and
        // refuses EOF-overhanging ones.
        let dir = std::env::temp_dir().join("gnndrive_test_backing");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("uring_target_{}.bin", std::process::id()));
        std::fs::write(&path, vec![7u8; 1000]).unwrap();
        let fb = FileBacking::open(&path).unwrap();
        let (fd, phys) = fb.uring_target(100, 200).expect("in-bounds span translates");
        assert!(fd >= 0);
        assert_eq!(phys, 100);
        assert_eq!(fb.uring_target(900, 200), None, "EOF overhang must not translate");

        // Striped: one-member spans translate through the member at the
        // LOCAL offset; chunk-straddling spans do not.
        let members: Vec<BackingRef> = (0..2)
            .map(|d| {
                let p = dir.join(format!("uring_member_{}_{d}.bin", std::process::id()));
                std::fs::write(&p, vec![d as u8; 512]).unwrap();
                Arc::new(FileBacking::open(&p).unwrap()) as BackingRef
            })
            .collect();
        let sb = StripedBacking::new(members, 64);
        let (_, local) = sb.uring_target(64, 32).expect("single-chunk span translates");
        assert_eq!(local, 0, "logical 64 is member 1's local 0");
        assert_eq!(sb.uring_target(60, 32), None, "chunk straddle must not translate");
    }

    #[test]
    fn procedural_deterministic() {
        let mk = || {
            ProceduralBacking::new(4096, 512, |c, out| {
                for (i, x) in out.iter_mut().enumerate() {
                    *x = (crate::util::rng::hash2(7, c * 512 + i as u64) & 0xFF) as u8;
                }
            })
        };
        let (a, b) = (mk(), mk());
        let mut x = vec![0u8; 777];
        let mut y = vec![0u8; 777];
        a.read_at(123, &mut x);
        b.read_at(123, &mut y);
        assert_eq!(x, y);
    }
}
