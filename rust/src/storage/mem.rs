//! Host and device memory budgets.
//!
//! The paper's experiments pivot on a hard host-memory capacity (32 GB,
//! swept 8–128 GB in Fig 9) shared between *hard* allocations (caches,
//! staging buffers, partition buffers, pinned index arrays) and the OS page
//! cache, plus a GPU device-memory capacity (24 GB) holding the feature
//! buffer. [`HostMemory`] hands out RAII [`Reservation`]s for hard
//! allocations — exceeding capacity is an out-of-memory error, which is how
//! the Ginex/MariusGNN OOM rows of Fig 9 / Table 2 arise — and exposes the
//! remainder as the page-cache budget. Byte sizes here are *simulated*
//! capacities (scaled 1/256 from the paper), not process RSS.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Error carrying the failed allocation for paper-style OOM reporting.
#[derive(Debug, Clone)]
pub struct OutOfMemory {
    pub what: String,
    pub requested: u64,
    pub capacity: u64,
    pub reserved: u64,
}

impl std::fmt::Display for OutOfMemory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OOM: {} needs {} but only {} of {} remain",
            self.what,
            crate::util::units::fmt_bytes(self.requested),
            crate::util::units::fmt_bytes(self.capacity.saturating_sub(self.reserved)),
            crate::util::units::fmt_bytes(self.capacity),
        )
    }
}

impl std::error::Error for OutOfMemory {}

#[derive(Debug)]
struct Budget {
    capacity: u64,
    reserved: AtomicU64,
    peak: AtomicU64,
}

impl Budget {
    fn reserve(&self, what: &str, bytes: u64) -> Result<(), OutOfMemory> {
        let mut cur = self.reserved.load(Ordering::Relaxed);
        loop {
            let next = cur + bytes;
            if next > self.capacity {
                return Err(OutOfMemory {
                    what: what.to_string(),
                    requested: bytes,
                    capacity: self.capacity,
                    reserved: cur,
                });
            }
            match self.reserved.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(next, Ordering::Relaxed);
                    return Ok(());
                }
                Err(actual) => cur = actual,
            }
        }
    }

    fn release(&self, bytes: u64) {
        self.reserved.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// Host memory: hard reservations + the page cache's residual budget.
#[derive(Clone, Debug)]
pub struct HostMemory {
    budget: Arc<Budget>,
}

impl HostMemory {
    pub fn new(capacity: u64) -> Self {
        HostMemory {
            budget: Arc::new(Budget {
                capacity,
                reserved: AtomicU64::new(0),
                peak: AtomicU64::new(0),
            }),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.budget.capacity
    }

    pub fn reserved(&self) -> u64 {
        self.budget.reserved.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.budget.peak.load(Ordering::Relaxed)
    }

    /// Bytes the OS page cache may occupy right now (everything not hard-
    /// reserved). The page cache re-checks this on every insertion and
    /// evicts to fit, so growing reservations squeeze cached pages out —
    /// exactly the paper's memory-contention mechanism (D1).
    pub fn cache_budget(&self) -> u64 {
        self.budget.capacity.saturating_sub(self.reserved())
    }

    /// Hard-reserve `bytes` (cache-evictable memory does not count; the page
    /// cache yields by shrinking its budget). RAII: dropping the reservation
    /// releases the bytes.
    pub fn reserve(&self, what: &str, bytes: u64) -> Result<Reservation, OutOfMemory> {
        self.budget.reserve(what, bytes)?;
        Ok(Reservation { budget: self.budget.clone(), bytes, what: what.to_string() })
    }
}

/// Device (GPU) memory: reservations only; no page cache.
#[derive(Clone, Debug)]
pub struct DeviceMemory {
    budget: Arc<Budget>,
}

impl DeviceMemory {
    pub fn new(capacity: u64) -> Self {
        DeviceMemory {
            budget: Arc::new(Budget {
                capacity,
                reserved: AtomicU64::new(0),
                peak: AtomicU64::new(0),
            }),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.budget.capacity
    }

    pub fn reserved(&self) -> u64 {
        self.budget.reserved.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.budget.peak.load(Ordering::Relaxed)
    }

    pub fn reserve(&self, what: &str, bytes: u64) -> Result<Reservation, OutOfMemory> {
        self.budget.reserve(what, bytes)?;
        Ok(Reservation { budget: self.budget.clone(), bytes, what: what.to_string() })
    }
}

/// RAII hard-memory reservation.
#[derive(Debug)]
pub struct Reservation {
    budget: Arc<Budget>,
    bytes: u64,
    what: String,
}

impl Reservation {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn what(&self) -> &str {
        &self.what
    }

    /// Grow the reservation in place (e.g. a cache warming up).
    pub fn grow(&mut self, extra: u64) -> Result<(), OutOfMemory> {
        self.budget.reserve(&self.what, extra)?;
        self.bytes += extra;
        Ok(())
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.budget.release(self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_cycle() {
        let hm = HostMemory::new(1000);
        let r1 = hm.reserve("a", 400).unwrap();
        assert_eq!(hm.reserved(), 400);
        assert_eq!(hm.cache_budget(), 600);
        let r2 = hm.reserve("b", 600).unwrap();
        assert_eq!(hm.cache_budget(), 0);
        assert!(hm.reserve("c", 1).is_err());
        drop(r1);
        assert_eq!(hm.reserved(), 600);
        drop(r2);
        assert_eq!(hm.reserved(), 0);
        assert_eq!(hm.peak(), 1000);
    }

    #[test]
    fn oom_reports_details() {
        let hm = HostMemory::new(100);
        let _r = hm.reserve("cache", 80).unwrap();
        let err = hm.reserve("staging buffer", 50).unwrap_err();
        assert_eq!(err.requested, 50);
        assert_eq!(err.reserved, 80);
        assert!(err.to_string().contains("staging buffer"));
    }

    #[test]
    fn reservation_grow() {
        let dm = DeviceMemory::new(100);
        let mut r = dm.reserve("feature buffer", 40).unwrap();
        r.grow(40).unwrap();
        assert_eq!(dm.reserved(), 80);
        assert!(r.grow(40).is_err());
        assert_eq!(r.bytes(), 80);
        drop(r);
        assert_eq!(dm.reserved(), 0);
    }

    #[test]
    fn concurrent_reservations_respect_capacity() {
        let hm = HostMemory::new(10_000);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let hm = hm.clone();
                std::thread::spawn(move || {
                    let mut held = Vec::new();
                    let mut failed = 0u32;
                    for _ in 0..50 {
                        match hm.reserve("x", 100) {
                            Ok(r) => held.push(r),
                            Err(_) => failed += 1,
                        }
                    }
                    (held.len(), failed)
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Reservations may come and go across threads, but the budget is
        // never oversubscribed at any instant.
        assert!(hm.peak() <= 10_000, "peak={}", hm.peak());
    }
}
