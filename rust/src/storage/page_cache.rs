//! Simulated OS page cache.
//!
//! Buffered (mmap-style) I/O goes through this cache; direct I/O bypasses
//! it. Pages are 4 KiB and are charged against the *residual* host-memory
//! budget ([`HostMemory::cache_budget`]) — hard reservations squeeze pages
//! out, and topology pages compete with feature pages, which is the paper's
//! memory-contention mechanism (D1, Fig 2). The cache stores no data (the
//! backing store is authoritative); it decides only whether a page access
//! pays SSD time. Hit/miss/eviction counters are attributed per data kind so
//! experiments can show *which* working set got thrashed.

use super::mem::HostMemory;
use crate::util::lru::Lru;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub const PAGE_SIZE: u64 = 4096;

/// What a file holds, for counter attribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DataKind {
    Topology,
    Features,
    Other,
}

/// Identifies a simulated file within the cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FileId {
    pub id: u32,
    pub kind: DataKind,
}

impl FileId {
    pub fn new(id: u32, kind: DataKind) -> Self {
        FileId { id, kind }
    }
}

#[derive(Debug, Default)]
pub struct KindCounters {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub evictions: AtomicU64,
}

#[derive(Debug, Default)]
pub struct PageCacheStats {
    pub topology: KindCounters,
    pub features: KindCounters,
    pub other: KindCounters,
}

impl PageCacheStats {
    pub fn for_kind(&self, kind: DataKind) -> &KindCounters {
        match kind {
            DataKind::Topology => &self.topology,
            DataKind::Features => &self.features,
            DataKind::Other => &self.other,
        }
    }

    pub fn reset(&self) {
        for k in [&self.topology, &self.features, &self.other] {
            k.hits.store(0, Ordering::Relaxed);
            k.misses.store(0, Ordering::Relaxed);
            k.evictions.store(0, Ordering::Relaxed);
        }
    }
}

pub struct PageCache {
    host: HostMemory,
    lru: Mutex<Lru<(FileId, u64)>>,
    stats: PageCacheStats,
}

impl PageCache {
    pub fn new(host: HostMemory) -> Self {
        PageCache { host, lru: Mutex::new(Lru::new()), stats: PageCacheStats::default() }
    }

    pub fn stats(&self) -> &PageCacheStats {
        &self.stats
    }

    pub fn host(&self) -> &HostMemory {
        &self.host
    }

    pub fn resident_bytes(&self) -> u64 {
        self.lru.lock().unwrap().len() as u64 * PAGE_SIZE
    }

    /// Probe one page. On hit: touch and return `true` (no device time).
    /// On miss: insert the page, evicting LRU pages until the cache fits the
    /// current residual budget, and return `false` (caller pays SSD time).
    pub fn access(&self, file: FileId, page: u64) -> bool {
        let mut lru = self.lru.lock().unwrap();
        if lru.touch(&(file, page)) {
            self.stats.for_kind(file.kind).hits.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        self.stats.for_kind(file.kind).misses.fetch_add(1, Ordering::Relaxed);
        let budget_pages = self.host.cache_budget() / PAGE_SIZE;
        // Evict down to the *current* budget before deciding whether to
        // cache — even when the budget is zero. The old early return on a
        // zero budget skipped eviction entirely, so pages cached before a
        // big reservation stayed resident forever and kept reporting hits
        // against memory the cache no longer owned.
        // Leave room for the new page when there is any budget at all.
        let target = budget_pages.saturating_sub(1);
        while lru.len() as u64 > target {
            if let Some((evicted, _)) = lru.pop_lru() {
                self.stats.for_kind(evicted.kind).evictions.fetch_add(1, Ordering::Relaxed);
            } else {
                break;
            }
        }
        if budget_pages == 0 {
            // No room to cache the new page: pure pass-through (but the
            // stale residents above are gone now).
            return false;
        }
        lru.insert((file, page));
        false
    }

    /// Shrink to the current budget (called after a large reservation when
    /// the caller wants the squeeze to happen immediately rather than lazily
    /// on the next access).
    pub fn shrink_to_budget(&self) {
        let mut lru = self.lru.lock().unwrap();
        let budget_pages = self.host.cache_budget() / PAGE_SIZE;
        while lru.len() as u64 > budget_pages {
            if let Some((evicted, _)) = lru.pop_lru() {
                self.stats.for_kind(evicted.kind).evictions.fetch_add(1, Ordering::Relaxed);
            } else {
                break;
            }
        }
    }

    /// Drop every cached page (e.g. between experiment runs).
    pub fn drop_all(&self) {
        let mut lru = self.lru.lock().unwrap();
        while lru.pop_lru().is_some() {}
    }

    /// Hit ratio for a kind since the last stats reset.
    pub fn hit_ratio(&self, kind: DataKind) -> f64 {
        let c = self.stats.for_kind(kind);
        let h = c.hits.load(Ordering::Relaxed) as f64;
        let m = c.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> FileId {
        FileId::new(0, DataKind::Topology)
    }

    fn feat() -> FileId {
        FileId::new(1, DataKind::Features)
    }

    #[test]
    fn hits_after_insert() {
        let hm = HostMemory::new(64 * PAGE_SIZE);
        let pc = PageCache::new(hm);
        assert!(!pc.access(topo(), 0)); // miss
        assert!(pc.access(topo(), 0)); // hit
        assert_eq!(pc.stats().topology.hits.load(Ordering::Relaxed), 1);
        assert_eq!(pc.stats().topology.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn capacity_bounds_residency() {
        let hm = HostMemory::new(8 * PAGE_SIZE);
        let pc = PageCache::new(hm);
        for p in 0..100 {
            pc.access(topo(), p);
        }
        assert!(pc.resident_bytes() <= 8 * PAGE_SIZE);
        assert!(pc.stats().topology.evictions.load(Ordering::Relaxed) >= 92);
    }

    #[test]
    fn feature_pressure_evicts_topology() {
        // The D1 mechanism in miniature: a topology working set that fits
        // alone gets thrashed once a larger feature stream shares the cache.
        let hm = HostMemory::new(32 * PAGE_SIZE);
        let pc = PageCache::new(hm);
        for p in 0..16 {
            pc.access(topo(), p);
        }
        pc.stats().reset();
        // Topology alone: all hits.
        for p in 0..16 {
            assert!(pc.access(topo(), p));
        }
        // Interleave a feature scan 4× the cache size.
        for p in 0..128 {
            pc.access(feat(), p);
        }
        // Topology re-scan now misses (pages were evicted by features).
        let before = pc.stats().topology.misses.load(Ordering::Relaxed);
        for p in 0..16 {
            pc.access(topo(), p);
        }
        let after = pc.stats().topology.misses.load(Ordering::Relaxed);
        assert!(after - before >= 12, "topology misses {before} -> {after}");
    }

    #[test]
    fn reservation_squeezes_cache() {
        let hm = HostMemory::new(32 * PAGE_SIZE);
        let pc = PageCache::new(hm.clone());
        for p in 0..32 {
            pc.access(topo(), p);
        }
        assert!(pc.resident_bytes() >= 24 * PAGE_SIZE);
        let _r = hm.reserve("staging", 24 * PAGE_SIZE).unwrap();
        pc.shrink_to_budget();
        assert!(pc.resident_bytes() <= 8 * PAGE_SIZE);
    }

    #[test]
    fn late_reservation_evicts_stale_residents_lazily() {
        // Regression: pages cached *before* a big reservation used to stay
        // resident (and report hits) forever, because the miss path
        // returned early once the budget hit zero instead of evicting.
        let hm = HostMemory::new(16 * PAGE_SIZE);
        let pc = PageCache::new(hm.clone());
        for p in 0..8 {
            pc.access(topo(), p);
        }
        assert!(pc.resident_bytes() >= 8 * PAGE_SIZE);
        // Reserve everything: the cache now owns no memory at all.
        let _r = hm.reserve("model state", hm.cache_budget()).unwrap();
        assert_eq!(hm.cache_budget(), 0);
        // The stale pages still answer hits until the next miss...
        assert!(pc.access(topo(), 0));
        // ...but the first miss must evict down to the zero budget.
        assert!(!pc.access(feat(), 100));
        assert_eq!(pc.resident_bytes(), 0, "stale residents must be evicted");
        assert!(
            pc.stats().topology.evictions.load(Ordering::Relaxed) >= 8,
            "evictions must be attributed"
        );
        // And nothing is resident afterwards: every access misses.
        assert!(!pc.access(topo(), 0));
        assert!(!pc.access(topo(), 0));
    }

    #[test]
    fn partial_squeeze_evicts_down_to_remaining_budget() {
        let hm = HostMemory::new(16 * PAGE_SIZE);
        let pc = PageCache::new(hm.clone());
        for p in 0..12 {
            pc.access(topo(), p);
        }
        let _r = hm.reserve("staging", 12 * PAGE_SIZE).unwrap();
        // Budget is now 4 pages; the next miss shrinks residency to fit
        // (3 old pages + the newly cached one).
        assert!(!pc.access(feat(), 0));
        assert!(pc.resident_bytes() <= 4 * PAGE_SIZE);
        assert!(pc.access(feat(), 0), "the new page itself was cached");
    }

    #[test]
    fn zero_budget_is_passthrough() {
        let hm = HostMemory::new(PAGE_SIZE);
        let _r = hm.reserve("all", PAGE_SIZE).unwrap();
        let pc = PageCache::new(hm);
        assert!(!pc.access(topo(), 0));
        assert!(!pc.access(topo(), 0)); // still a miss: nothing cached
        assert_eq!(pc.resident_bytes(), 0);
    }
}
