//! Genuine `io_uring` asynchronous engine over real OS files — the third
//! [`AsyncIoEngine`], selected with `--backend uring`.
//!
//! Unlike the sim ring ([`super::uring::Uring`]) and the `pread` pool
//! ([`super::osfile::PreadPool`]), this engine actually talks to the kernel:
//! raw `io_uring_setup`/`io_uring_enter`/`io_uring_register` syscalls (no
//! external crate — the offline build has no libc, so the syscalls are
//! inline `asm!`, gated to x86_64/aarch64), mmap'd SQ/CQ rings, registered
//! files, and — when the kernel grants it — registered buffers over the
//! staging arena so segment reads land with `IORING_OP_READ_FIXED`.
//!
//! ## Division of labour
//!
//! [`EngineCore`] still owns the engine contract: bounded per-device
//! sub-queues, the unbounded CQ, the `submitted`/`inflight`/`harvested`
//! counter discipline, poison/drain semantics. This module is *only* a
//! worker loop: each worker binds one stripe device's sub-queue and one
//! private kernel ring, pops a chunk of SQEs, partitions it into
//! kernel-eligible requests (the backend translated `(file, offset, len)`
//! into a single real `(fd, physical_offset)` via
//! [`IoBackend::uring_target`]) and fallback requests (sim-backed files,
//! fault wrappers with an active plan, chunk-straddling spans), serves the
//! fallback half through [`serve_sqe`] exactly like the pread pool, and
//! drives the kernel half through one `io_uring_enter` per chunk.
//!
//! ## Accounting parity
//!
//! A kernel-completed direct segment records *exactly* what the pread pool
//! records for the same request: one `requests` tick, `useful` bytes,
//! sector-rounded `aligned` bytes, and one `charge_multi_dev(dev, 1,
//! aligned)` — so `iostat`, the redundancy analysis, and the per-device
//! breakdown are engine-independent. The one intentional difference is
//! `direct_fallbacks`: kernel reads go through the cached fd (an arena
//! destination carries no O_DIRECT alignment guarantee), so every kernel
//! read counts one fallback, mirroring the "cached pread stand-in for
//! O_DIRECT" bookkeeping.
//!
//! ## Degradation ladder
//!
//! `--backend uring` is runtime-gated by [`probe_uring`] (ring setup + NOP
//! round-trip). If the probe fails at startup the *backend* falls back to
//! the pread pool with a typed warning (see `config.rs`). If a worker's
//! ring setup fails later anyway (e.g. seccomp), the worker degrades to a
//! pure `serve_sqe` loop — identical semantics, one-time warning. If a
//! single kernel CQE comes back short or errored, that request alone
//! retries through `serve_sqe` (counted as a retry) — the fault/retry
//! matrix holds for every request regardless of which path served it.

use super::api::{AsyncIoEngine, Cqe, IoBackend, IoMode, Sqe};
use super::engine_core::{serve_sqe, EngineCore, WorkerPort};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, Once};
use std::thread::JoinHandle;

// ---------------------------------------------------------------------------
// Raw syscall layer (no libc in this build: inline asm, arch-gated).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod sys {
    pub const SYS_CLOSE: usize = 3;
    pub const SYS_MMAP: usize = 9;
    pub const SYS_MUNMAP: usize = 11;
    pub const SYS_IO_URING_SETUP: usize = 425;
    pub const SYS_IO_URING_ENTER: usize = 426;
    pub const SYS_IO_URING_REGISTER: usize = 427;

    /// Six-argument raw syscall. Returns the kernel's raw result:
    /// negative values are `-errno`.
    ///
    /// # Safety
    /// The caller must uphold the invoked syscall's own contract (valid
    /// pointers/lengths for the given syscall number).
    pub unsafe fn syscall6(
        nr: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
        ret
    }

    pub const SUPPORTED: bool = true;
}

#[cfg(target_arch = "aarch64")]
mod sys {
    pub const SYS_CLOSE: usize = 57;
    pub const SYS_MMAP: usize = 222;
    pub const SYS_MUNMAP: usize = 215;
    pub const SYS_IO_URING_SETUP: usize = 425;
    pub const SYS_IO_URING_ENTER: usize = 426;
    pub const SYS_IO_URING_REGISTER: usize = 427;

    /// See the x86_64 twin.
    ///
    /// # Safety
    /// The caller must uphold the invoked syscall's own contract.
    pub unsafe fn syscall6(
        nr: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
        ret
    }

    pub const SUPPORTED: bool = true;
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    pub const SYS_CLOSE: usize = 0;
    pub const SYS_MMAP: usize = 0;
    pub const SYS_MUNMAP: usize = 0;
    pub const SYS_IO_URING_SETUP: usize = 0;
    pub const SYS_IO_URING_ENTER: usize = 0;
    pub const SYS_IO_URING_REGISTER: usize = 0;

    /// No raw-syscall support on this architecture: everything returns
    /// `-ENOSYS`, so the probe fails typed and the backend falls back to
    /// the pread pool.
    ///
    /// # Safety
    /// Trivially safe — never touches the kernel.
    pub unsafe fn syscall6(
        _nr: usize,
        _a1: usize,
        _a2: usize,
        _a3: usize,
        _a4: usize,
        _a5: usize,
        _a6: usize,
    ) -> isize {
        -38 // ENOSYS
    }

    pub const SUPPORTED: bool = false;
}

// ---------------------------------------------------------------------------
// io_uring ABI (uapi/linux/io_uring.h, stable since 5.1).
// ---------------------------------------------------------------------------

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct SqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    flags: u32,
    dropped: u32,
    array: u32,
    resv1: u32,
    user_addr: u64,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct CqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    overflow: u32,
    cqes: u32,
    flags: u32,
    resv1: u32,
    user_addr: u64,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct IoUringParams {
    sq_entries: u32,
    cq_entries: u32,
    flags: u32,
    sq_thread_cpu: u32,
    sq_thread_idle: u32,
    features: u32,
    wq_fd: u32,
    resv: [u32; 3],
    sq_off: SqringOffsets,
    cq_off: CqringOffsets,
}

/// One kernel submission-queue entry (64 bytes). Field unions in the uapi
/// header are flattened to the members this engine uses.
#[repr(C)]
#[derive(Clone, Copy, Default)]
struct KernelSqe {
    opcode: u8,
    flags: u8,
    ioprio: u16,
    fd: i32,
    off: u64,
    addr: u64,
    len: u32,
    rw_flags: u32,
    user_data: u64,
    buf_index: u16,
    personality: u16,
    splice_fd_in: i32,
    pad2: [u64; 2],
}

/// One kernel completion-queue entry (16 bytes).
#[repr(C)]
#[derive(Clone, Copy, Default)]
struct KernelCqe {
    user_data: u64,
    res: i32,
    flags: u32,
}

/// `struct iovec` for `IORING_REGISTER_BUFFERS`.
#[repr(C)]
#[derive(Clone, Copy)]
struct IoVec {
    base: usize,
    len: usize,
}

const IORING_OFF_SQ_RING: usize = 0;
const IORING_OFF_CQ_RING: usize = 0x0800_0000;
const IORING_OFF_SQES: usize = 0x1000_0000;

const IORING_ENTER_GETEVENTS: usize = 1;

const IORING_REGISTER_BUFFERS: usize = 0;
const IORING_UNREGISTER_BUFFERS: usize = 1;
const IORING_REGISTER_FILES: usize = 2;
const IORING_UNREGISTER_FILES: usize = 3;

const IORING_OP_NOP: u8 = 0;
const IORING_OP_READ_FIXED: u8 = 4;
const IORING_OP_READ: u8 = 22;

/// `IOSQE_FIXED_FILE`: `fd` is an index into the registered-file table.
const IOSQE_FIXED_FILE: u8 = 1;

const PROT_READ_WRITE: usize = 0x3;
const MAP_SHARED_POPULATE: usize = 0x8001;

const EINTR: isize = -4;
const EAGAIN: isize = -11;

/// Max distinct fds a worker keeps in its registered-file table before new
/// fds just ride as plain descriptors (a training run touches a handful of
/// feature/packed files; this is headroom, not a limit that binds).
const MAX_REGISTERED_FILES: usize = 16;

// ---------------------------------------------------------------------------
// The kernel ring.
// ---------------------------------------------------------------------------

struct MmapRegion {
    ptr: *mut u8,
    len: usize,
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            // SAFETY: ptr/len came from a successful mmap and nothing else
            // unmaps this region.
            unsafe {
                sys::syscall6(sys::SYS_MUNMAP, self.ptr as usize, self.len, 0, 0, 0, 0);
            }
        }
    }
}

/// A private kernel io_uring instance: ring fd plus the three mmaps and the
/// cached ring-geometry pointers. One ring per worker thread — single
/// producer, single consumer, so the only synchronization needed is the
/// acquire/release pairing with the kernel on the head/tail indices.
struct Ring {
    fd: i32,
    sq_entries: u32,
    sq_mask: u32,
    cq_mask: u32,
    sq_head: *const AtomicU32,
    sq_tail: *const AtomicU32,
    sq_array: *mut u32,
    sqes: *mut KernelSqe,
    cq_head: *const AtomicU32,
    cq_tail: *const AtomicU32,
    cqes: *const KernelCqe,
    /// Keeps the mappings alive for the ring's lifetime (field order puts
    /// them after the raw pointers purely for readability; Drop unmaps).
    _sq_map: MmapRegion,
    _cq_map: MmapRegion,
    _sqe_map: MmapRegion,
    /// fds registered with `IORING_REGISTER_FILES`, index = table slot.
    registered_files: Vec<i32>,
    /// `None` = never tried; `Some(range)` = this arena range is currently
    /// registered as buffer 0; `Some((0, 0))` is never stored (a failed
    /// registration resets to `None` with `buf_reg_failed` set).
    registered_buf: Option<(usize, usize)>,
    /// Buffer registration failed once (e.g. RLIMIT_MEMLOCK): stop trying.
    buf_reg_failed: bool,
}

// SAFETY: a Ring is confined to the worker thread that created it; Send is
// needed only to move it into that thread at spawn.
unsafe impl Send for Ring {}

impl Ring {
    fn mmap(fd: i32, len: usize, offset: usize) -> Result<MmapRegion, String> {
        // SAFETY: plain mmap of the ring fd at a kernel-defined offset.
        let ret = unsafe {
            sys::syscall6(
                sys::SYS_MMAP,
                0,
                len,
                PROT_READ_WRITE,
                MAP_SHARED_POPULATE,
                fd as usize,
                offset,
            )
        };
        if ret < 0 {
            return Err(format!("mmap(io_uring, off={offset:#x}) failed: errno {}", -ret));
        }
        Ok(MmapRegion { ptr: ret as *mut u8, len })
    }

    /// Set up a kernel ring with at least `entries` SQEs (the kernel rounds
    /// up to a power of two). Fails typed on any setup/mmap error — the
    /// caller decides whether that means "fall back" or "probe failed".
    fn new(entries: u32) -> Result<Ring, String> {
        if !sys::SUPPORTED {
            return Err("io_uring unavailable: no raw-syscall support on this arch".into());
        }
        let entries = entries.clamp(1, 4096).next_power_of_two();
        let mut params = IoUringParams::default();
        // SAFETY: params is a properly sized/aligned io_uring_params.
        let fd = unsafe {
            sys::syscall6(
                sys::SYS_IO_URING_SETUP,
                entries as usize,
                &mut params as *mut IoUringParams as usize,
                0,
                0,
                0,
                0,
            )
        };
        if fd < 0 {
            return Err(format!("io_uring_setup failed: errno {}", -fd));
        }
        let fd = fd as i32;
        let close_on_err = |fd: i32| {
            // SAFETY: closing the fd we just opened.
            unsafe { sys::syscall6(sys::SYS_CLOSE, fd as usize, 0, 0, 0, 0, 0) };
        };

        let sq_len = params.sq_off.array as usize + params.sq_entries as usize * 4;
        let cq_len = params.cq_off.cqes as usize
            + params.cq_entries as usize * std::mem::size_of::<KernelCqe>();
        let sqe_len = params.sq_entries as usize * std::mem::size_of::<KernelSqe>();
        let sq_map = match Self::mmap(fd, sq_len, IORING_OFF_SQ_RING) {
            Ok(m) => m,
            Err(e) => {
                close_on_err(fd);
                return Err(e);
            }
        };
        let cq_map = match Self::mmap(fd, cq_len, IORING_OFF_CQ_RING) {
            Ok(m) => m,
            Err(e) => {
                close_on_err(fd);
                return Err(e);
            }
        };
        let sqe_map = match Self::mmap(fd, sqe_len, IORING_OFF_SQES) {
            Ok(m) => m,
            Err(e) => {
                close_on_err(fd);
                return Err(e);
            }
        };

        // SAFETY: every offset below comes from the kernel's own
        // io_uring_params for these mappings.
        let ring = unsafe {
            Ring {
                fd,
                sq_entries: params.sq_entries,
                sq_mask: *(sq_map.ptr.add(params.sq_off.ring_mask as usize) as *const u32),
                cq_mask: *(cq_map.ptr.add(params.cq_off.ring_mask as usize) as *const u32),
                sq_head: sq_map.ptr.add(params.sq_off.head as usize) as *const AtomicU32,
                sq_tail: sq_map.ptr.add(params.sq_off.tail as usize) as *const AtomicU32,
                sq_array: sq_map.ptr.add(params.sq_off.array as usize) as *mut u32,
                sqes: sqe_map.ptr as *mut KernelSqe,
                cq_head: cq_map.ptr.add(params.cq_off.head as usize) as *const AtomicU32,
                cq_tail: cq_map.ptr.add(params.cq_off.tail as usize) as *const AtomicU32,
                cqes: cq_map.ptr.add(params.cq_off.cqes as usize) as *const KernelCqe,
                _sq_map: sq_map,
                _cq_map: cq_map,
                _sqe_map: sqe_map,
                registered_files: Vec::new(),
                registered_buf: None,
                buf_reg_failed: false,
            }
        };
        Ok(ring)
    }

    /// Queue one SQE; `false` when the kernel SQ is full (the caller
    /// enters and retries — with chunked submit ≤ ring size this only
    /// happens when a chunk exceeds `sq_entries`).
    fn push(&mut self, sqe: KernelSqe) -> bool {
        // SAFETY (all pointer ops below): the pointers are derived from
        // live mappings; this thread is the only SQ producer, the kernel
        // the only SQ consumer.
        unsafe {
            let tail = (*self.sq_tail).load(Ordering::Relaxed);
            let head = (*self.sq_head).load(Ordering::Acquire);
            if tail.wrapping_sub(head) >= self.sq_entries {
                return false;
            }
            let idx = tail & self.sq_mask;
            *self.sqes.add(idx as usize) = sqe;
            *self.sq_array.add(idx as usize) = idx;
            (*self.sq_tail).store(tail.wrapping_add(1), Ordering::Release);
        }
        true
    }

    /// `io_uring_enter(to_submit, min_complete, GETEVENTS)`, retrying
    /// `EINTR`/`EAGAIN`.
    fn enter(&self, to_submit: u32, min_complete: u32) -> Result<(), String> {
        loop {
            // SAFETY: fd is a live ring fd; no sigset is passed.
            let ret = unsafe {
                sys::syscall6(
                    sys::SYS_IO_URING_ENTER,
                    self.fd as usize,
                    to_submit as usize,
                    min_complete as usize,
                    IORING_ENTER_GETEVENTS,
                    0,
                    0,
                )
            };
            if ret >= 0 {
                return Ok(());
            }
            if ret == EINTR || ret == EAGAIN {
                continue;
            }
            return Err(format!("io_uring_enter failed: errno {}", -ret));
        }
    }

    /// Pop one kernel CQE if ready.
    fn pop_cqe(&mut self) -> Option<KernelCqe> {
        // SAFETY: see `push` — this thread is the only CQ consumer.
        unsafe {
            let head = (*self.cq_head).load(Ordering::Relaxed);
            let tail = (*self.cq_tail).load(Ordering::Acquire);
            if head == tail {
                return None;
            }
            let cqe = *self.cqes.add((head & self.cq_mask) as usize);
            (*self.cq_head).store(head.wrapping_add(1), Ordering::Release);
            Some(cqe)
        }
    }

    fn register(&self, opcode: usize, arg: usize, nr: u32) -> isize {
        // SAFETY: arg/nr match the register opcode's contract at each call
        // site below.
        unsafe {
            sys::syscall6(
                sys::SYS_IO_URING_REGISTER,
                self.fd as usize,
                opcode,
                arg,
                nr as usize,
                0,
                0,
            )
        }
    }

    /// Slot of `fd` in the registered-file table, registering it on first
    /// sight (table re-registered whole — a handful of syscalls per run,
    /// not per I/O). `None` = not registered (table full or kernel refused);
    /// the caller uses the plain fd.
    fn fixed_slot(&mut self, fd: i32) -> Option<u16> {
        if let Some(pos) = self.registered_files.iter().position(|&f| f == fd) {
            return Some(pos as u16);
        }
        if self.registered_files.len() >= MAX_REGISTERED_FILES {
            return None;
        }
        if !self.registered_files.is_empty() {
            self.register(IORING_UNREGISTER_FILES, 0, 0);
        }
        self.registered_files.push(fd);
        let ret = self.register(
            IORING_REGISTER_FILES,
            self.registered_files.as_ptr() as usize,
            self.registered_files.len() as u32,
        );
        if ret < 0 {
            self.registered_files.clear();
            return None;
        }
        Some((self.registered_files.len() - 1) as u16)
    }

    /// (Re-)register `range` as fixed buffer 0 if it differs from what is
    /// currently registered. Failure is sticky — buffer registration pins
    /// pages and a `RLIMIT_MEMLOCK` refusal will not heal itself.
    fn ensure_buffer(&mut self, range: (usize, usize)) {
        if self.buf_reg_failed || self.registered_buf == Some(range) {
            return;
        }
        if self.registered_buf.is_some() {
            self.register(IORING_UNREGISTER_BUFFERS, 0, 0);
            self.registered_buf = None;
        }
        let iov = IoVec { base: range.0, len: range.1 };
        let ret = self.register(IORING_REGISTER_BUFFERS, &iov as *const IoVec as usize, 1);
        if ret < 0 {
            self.buf_reg_failed = true;
        } else {
            self.registered_buf = Some(range);
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        // SAFETY: closing the ring fd; the kernel releases registrations
        // and the MmapRegion drops unmap the rings.
        unsafe {
            sys::syscall6(sys::SYS_CLOSE, self.fd as usize, 0, 0, 0, 0, 0);
        }
    }
}

/// Startup gate for `--backend uring`: set up a small ring, run one NOP
/// through submit → enter → harvest, tear down. `Err` carries the typed
/// reason (unsupported arch, ENOSYS, seccomp, mmap refusal, …) that the
/// fallback warning prints.
pub fn probe_uring() -> Result<(), String> {
    let mut ring = Ring::new(4)?;
    let nop = KernelSqe { opcode: IORING_OP_NOP, fd: -1, user_data: 0x1dea, ..Default::default() };
    if !ring.push(nop) {
        return Err("io_uring probe: fresh ring rejected a NOP".into());
    }
    ring.enter(1, 1)?;
    match ring.pop_cqe() {
        Some(cqe) if cqe.user_data == 0x1dea => Ok(()),
        Some(cqe) => Err(format!("io_uring probe: NOP came back with user_data {:#x}", cqe.user_data)),
        None => Err("io_uring probe: no completion after GETEVENTS".into()),
    }
}

// ---------------------------------------------------------------------------
// The engine.
// ---------------------------------------------------------------------------

fn warn_ring_degraded(err: &str) {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        eprintln!("[uring] WARN: ring setup failed ({err}); worker degraded to pread fallback");
    });
}

/// Sector-rounded span of a request — must match
/// `OsFileBackend::aligned_len` so kernel-path accounting is
/// charge-identical to the pread path.
fn aligned_len(sector: usize, offset: u64, len: usize) -> usize {
    let sector = sector.max(1) as u64;
    let lo = offset / sector * sector;
    let hi = (offset + len as u64).div_ceil(sector) * sector;
    (hi - lo) as usize
}

/// The genuine io_uring engine: [`EngineCore`] in front, per-device worker
/// threads each owning a private kernel [`Ring`] behind.
pub struct UringEngine {
    core: EngineCore,
    workers: Vec<JoinHandle<()>>,
    /// Staging-arena range advertised via
    /// [`AsyncIoEngine::register_buffer_range`]; workers pick it up lazily
    /// and register it as fixed buffer 0 on their ring.
    buf_range: Arc<Mutex<Option<(usize, usize)>>>,
}

impl UringEngine {
    pub fn new(backend: Arc<dyn IoBackend>, depth: usize, threads: usize) -> Self {
        let depth = depth.max(1);
        let spec = backend.stripe();
        let core = EngineCore::new_striped("uring engine", depth, spec);
        let devices = core.device_count();
        let policy = backend.retry_policy();
        // Chunked harvest amortizes io_uring_enter: one syscall submits and
        // reaps up to `chunk` segments. Deeper rings earn bigger chunks —
        // this is where the ≥ depth-8 submit+harvest win comes from.
        let chunk = depth.clamp(1, 32);
        let buf_range: Arc<Mutex<Option<(usize, usize)>>> = Arc::new(Mutex::new(None));
        // Same sizing rule as the pread pool: `--io-workers` threads, at
        // least one per stripe device, never more than the ring is deep.
        let workers = (0..threads.max(1).min(depth).max(devices))
            .map(|w| {
                let dev = w % devices;
                let port = core.worker_port(dev);
                let backend = backend.clone();
                let buf_range = buf_range.clone();
                let ring = Ring::new(depth as u32);
                std::thread::spawn(move || {
                    crate::metrics::state::register(crate::metrics::state::Role::IoWorker);
                    let guard = port.poison_guard();
                    match ring {
                        Ok(ring) => {
                            worker_loop(ring, &port, backend.as_ref(), &policy, dev, chunk, &buf_range)
                        }
                        Err(e) => {
                            // Ring refused after a successful probe (eg
                            // per-thread seccomp): identical semantics via
                            // the serve_sqe path, engine stays live.
                            warn_ring_degraded(&e);
                            fallback_loop(&port, backend.as_ref(), &policy, dev);
                        }
                    }
                    drop(guard);
                    crate::metrics::state::deregister();
                })
            })
            .collect();
        UringEngine { core, workers, buf_range }
    }
}

/// Degraded worker: byte-for-byte the pread-pool loop.
fn fallback_loop(port: &WorkerPort, backend: &dyn IoBackend, policy: &super::api::RetryPolicy, dev: usize) {
    while let Ok(sqe) = port.pop() {
        let (status, aligned) = serve_sqe(backend, policy, &sqe);
        match status {
            Ok(bytes) => {
                if sqe.mode == IoMode::Direct {
                    backend.charge_multi_dev(dev, 1, aligned);
                }
                port.complete(sqe.user_data, bytes);
            }
            Err(e) => port.complete_err(sqe.user_data, e),
        }
    }
}

/// Serve one request through `serve_sqe` and publish — shared by the
/// fallback partition and the kernel-error retry path.
fn serve_and_publish(
    port: &WorkerPort,
    backend: &dyn IoBackend,
    policy: &super::api::RetryPolicy,
    dev: usize,
    sqe: &Sqe,
) {
    let (status, aligned) = serve_sqe(backend, policy, sqe);
    match status {
        Ok(bytes) => {
            if sqe.mode == IoMode::Direct {
                backend.charge_multi_dev(dev, 1, aligned);
            }
            port.complete(sqe.user_data, bytes);
        }
        Err(e) => port.complete_err(sqe.user_data, e),
    }
}

/// Kernel-ring worker loop: chunked pop, partition, batch-enter, harvest.
fn worker_loop(
    mut ring: Ring,
    port: &WorkerPort,
    backend: &dyn IoBackend,
    policy: &super::api::RetryPolicy,
    dev: usize,
    chunk: usize,
    buf_range: &Mutex<Option<(usize, usize)>>,
) {
    let sector = backend.sector();
    while let Ok(sqes) = port.pop_many(chunk) {
        // Pick up a (re)advertised staging arena before building SQEs so
        // READ_FIXED eligibility is decided against the current range.
        let (registered, reg_failed, advertised) = {
            let adv = *buf_range.lock().expect("buf_range lock");
            if let Some(range) = adv {
                ring.ensure_buffer(range);
            }
            (ring.registered_buf, ring.buf_reg_failed, adv)
        };

        // Partition: direct requests the backend can translate to one real
        // (fd, physical offset) go to the kernel; everything else (sim
        // files, active fault plans, chunk-straddling spans, buffered
        // reads that must tick the page-cache accounting) serves inline.
        let mut kernel: Vec<(usize, i32, u64)> = Vec::with_capacity(sqes.len());
        for (i, sqe) in sqes.iter().enumerate() {
            let target = if sqe.mode == IoMode::Direct {
                backend.uring_target(&sqe.file, sqe.offset, sqe.len)
            } else {
                None
            };
            match target {
                Some((fd, phys)) => kernel.push((i, fd, phys)),
                None => serve_and_publish(port, backend, policy, dev, sqe),
            }
        }
        if kernel.is_empty() {
            continue;
        }

        // Build + submit the kernel half. user_data is the chunk-local
        // index; ring depth ≥ chunk so one push pass always fits.
        let mut submitted: Vec<usize> = Vec::with_capacity(kernel.len());
        for &(i, fd, phys) in &kernel {
            let sqe = &sqes[i];
            // SAFETY: the worker owns this staging sub-range until the
            // completion publishes (SlotRef range protocol) — same
            // justification as serve_sqe's slice_mut.
            let dst = unsafe { sqe.dst.slice_mut(sqe.dst_off, sqe.len) };
            let addr = dst.as_mut_ptr() as usize;
            let mut ksqe = KernelSqe {
                opcode: IORING_OP_READ,
                fd,
                off: phys,
                addr: addr as u64,
                len: sqe.len as u32,
                user_data: i as u64,
                ..Default::default()
            };
            if let Some((base, blen)) = registered {
                if addr >= base && addr + sqe.len <= base + blen {
                    ksqe.opcode = IORING_OP_READ_FIXED;
                    ksqe.buf_index = 0;
                }
            } else if reg_failed {
                // The destination sits inside the advertised arena, so this
                // read *would* have been READ_FIXED — registration failed
                // (RLIMIT_MEMLOCK) and it degrades to a plain READ. Counted
                // so the downgrade is visible in EpochStats instead of
                // silent (the one-time stderr warning scrolls away).
                if let Some((base, blen)) = advertised {
                    if addr >= base && addr + sqe.len <= base + blen {
                        backend.direct_stats().count_fixed_fallback();
                    }
                }
            }
            if let Some(slot) = ring.fixed_slot(fd) {
                ksqe.fd = slot as i32;
                ksqe.flags |= IOSQE_FIXED_FILE;
            }
            if ring.push(ksqe) {
                submitted.push(i);
            } else {
                // Ring full (chunk > sq_entries after kernel rounding):
                // serve the overflow inline rather than stalling.
                serve_and_publish(port, backend, policy, dev, sqe);
            }
        }
        if submitted.is_empty() {
            continue;
        }

        // One enter drives the whole chunk; harvest until every submitted
        // request has its CQE. An enter failure downgrades the entire
        // outstanding set to the serve_sqe path — completions must never
        // be dropped.
        let mut outstanding: Vec<bool> = vec![false; sqes.len()];
        for &i in &submitted {
            outstanding[i] = true;
        }
        let mut remaining = submitted.len();
        if let Err(e) = ring.enter(submitted.len() as u32, submitted.len() as u32) {
            warn_ring_degraded(&e);
            for &i in &submitted {
                serve_and_publish(port, backend, policy, dev, &sqes[i]);
            }
            continue;
        }
        let mut direct_ops = 0u64;
        let mut direct_bytes = 0usize;
        while remaining > 0 {
            let Some(kcqe) = ring.pop_cqe() else {
                // GETEVENTS returned before all CQEs were visible (the
                // kernel only guarantees min_complete); wait for the rest.
                if let Err(e) = ring.enter(0, 1) {
                    warn_ring_degraded(&e);
                    break;
                }
                continue;
            };
            let i = kcqe.user_data as usize;
            if i >= sqes.len() || !outstanding[i] {
                continue; // stray/duplicate kernel CQE: ignore defensively
            }
            outstanding[i] = false;
            remaining -= 1;
            let sqe = &sqes[i];
            if kcqe.res == sqe.len as i32 {
                // Full-length kernel read: mirror the pread pool's direct
                // accounting exactly — one request, useful vs aligned
                // bytes, a fallback tick (cached fd, not O_DIRECT), one
                // charged op of the aligned span (batched per chunk).
                let aligned = aligned_len(sector, sqe.offset, sqe.len);
                let stats = backend.direct_stats();
                stats.requests.fetch_add(1, Ordering::Relaxed);
                stats.useful_bytes.fetch_add(sqe.useful as u64, Ordering::Relaxed);
                stats.aligned_bytes.fetch_add(aligned as u64, Ordering::Relaxed);
                stats.count_fallback();
                direct_ops += 1;
                direct_bytes += aligned;
                port.complete(sqe.user_data, sqe.len);
            } else {
                // Short read or kernel error (-errno): retry this one
                // request through the bounded-retry pread path.
                backend.direct_stats().count_retry();
                serve_and_publish(port, backend, policy, dev, sqe);
            }
        }
        backend.charge_multi_dev(dev, direct_ops, direct_bytes);
        // Anything still outstanding after a mid-harvest enter failure
        // downgrades to the inline path.
        for (i, pending) in outstanding.into_iter().enumerate() {
            if pending {
                serve_and_publish(port, backend, policy, dev, &sqes[i]);
            }
        }
    }
}

impl AsyncIoEngine for UringEngine {
    fn submit(&self, sqe: Sqe) {
        self.core.submit(sqe)
    }

    fn submit_batch(&self, sqes: Vec<Sqe>) {
        self.core.submit_batch(sqes)
    }

    fn wait_cqe(&self) -> Cqe {
        self.core.wait_cqe()
    }

    fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        self.core.wait_cqes(n)
    }

    fn peek_cqe(&self) -> Option<Cqe> {
        self.core.peek_cqe()
    }

    fn inflight(&self) -> u64 {
        self.core.inflight()
    }

    fn pending_harvest(&self) -> u64 {
        self.core.pending_harvest()
    }

    fn drain(&self) {
        self.core.drain()
    }

    fn queue_highwater(&self) -> Vec<u64> {
        self.core.queue_highwater()
    }

    fn register_buffer_range(&self, addr: usize, len: usize) {
        if len == 0 {
            return;
        }
        // Workers observe the new range at their next chunk and
        // re-register; the caller keeps the arena alive for the engine's
        // lifetime (AsyncIoEngine contract).
        *self.buf_range.lock().expect("buf_range lock") = Some((addr, len));
    }
}

impl Drop for UringEngine {
    fn drop(&mut self) {
        self.core.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Rings live on the worker stacks and unmap/close as the threads
        // exit; buf_range outlives them harmlessly.
        let _ = self.buf_range.lock().map(|mut r| *r = None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::membuf::{SlotRef, StagingArena};
    use crate::storage::backing::{FileBacking, MemBacking};
    use crate::storage::engine::SimFile;
    use crate::storage::osfile::OsFileBackend;
    use crate::storage::page_cache::{DataKind, FileId};

    #[test]
    fn abi_struct_sizes_match_kernel() {
        use std::mem::size_of;
        assert_eq!(size_of::<IoUringParams>(), 120);
        assert_eq!(size_of::<KernelSqe>(), 64);
        assert_eq!(size_of::<KernelCqe>(), 16);
        assert_eq!(size_of::<SqringOffsets>(), 40);
        assert_eq!(size_of::<CqringOffsets>(), 40);
    }

    #[test]
    fn probe_round_trips_a_nop_or_fails_typed() {
        match probe_uring() {
            Ok(()) => {}
            Err(e) => {
                assert!(!e.is_empty());
                println!("SKIP: no io_uring ({e})");
            }
        }
    }

    #[test]
    fn kernel_reads_match_file_contents_and_pread_accounting() {
        if let Err(e) = probe_uring() {
            println!("SKIP: no io_uring ({e})");
            return;
        }
        let dir = std::env::temp_dir().join("gnndrive_uring_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("kern_{}.bin", std::process::id()));
        std::fs::write(&path, (0..16384u32).map(|i| (i % 251) as u8).collect::<Vec<u8>>())
            .unwrap();
        let file = SimFile::new(
            FileId::new(7, DataKind::Features),
            Arc::new(FileBacking::open(&path).unwrap()),
        );
        let be: Arc<dyn IoBackend> = Arc::new(OsFileBackend::with_stripe_uring(
            512,
            4,
            crate::storage::backing::StripeSpec::single(),
        ));
        let engine = UringEngine::new(be.clone(), 16, 4);
        let arena = StagingArena::new(1, 8 * 512);
        let dst = SlotRef::new(arena, 0);
        engine.register_buffer_range(dst.bytes().as_ptr() as usize, 8 * 512);
        let sqes: Vec<Sqe> = (0..8u64)
            .map(|i| Sqe {
                file: file.clone(),
                offset: 100 + i * 512,
                len: 512,
                useful: 512,
                dst: dst.clone(),
                dst_off: (i * 512) as usize,
                user_data: i,
                mode: IoMode::Direct,
            })
            .collect();
        engine.submit_batch(sqes);
        let cqes = engine.wait_cqes(8);
        assert!(cqes.iter().all(|c| c.is_ok()), "{cqes:?}");
        assert_eq!(engine.inflight(), 0);
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, ((100 + i) % 251) as u8, "byte {i}");
        }
        // Charge parity with the pread pool: 8 requests, each 512 useful
        // bytes inside a 1024-byte aligned span (offset 100 straddles a
        // sector boundary).
        let stats = be.direct_stats();
        assert_eq!(stats.requests.load(Ordering::Relaxed), 8);
        assert_eq!(stats.useful_bytes.load(Ordering::Relaxed), 8 * 512);
        assert_eq!(stats.aligned_bytes.load(Ordering::Relaxed), 8 * 1024);
        assert_eq!(be.io_counters().reads.load(Ordering::Relaxed), 8);
        assert_eq!(be.io_counters().read_bytes.load(Ordering::Relaxed), 8 * 1024);
    }

    #[test]
    fn untranslatable_files_fall_back_to_serve_sqe() {
        // MemBacking has no fd → every request rides the serve_sqe
        // partition; works with or without kernel io_uring.
        let bytes: Vec<u8> = (0..8192u32).map(|i| (i % 239) as u8).collect();
        let file =
            SimFile::new(FileId::new(5, DataKind::Features), Arc::new(MemBacking::new(bytes)));
        let be: Arc<dyn IoBackend> = Arc::new(OsFileBackend::with_stripe_uring(
            512,
            2,
            crate::storage::backing::StripeSpec::single(),
        ));
        let engine = UringEngine::new(be.clone(), 8, 2);
        let arena = StagingArena::new(1, 1024);
        engine.submit(Sqe {
            file,
            offset: 700,
            len: 1024,
            useful: 1024,
            dst: SlotRef::new(arena.clone(), 0),
            dst_off: 0,
            user_data: 42,
            mode: IoMode::Direct,
        });
        let cqe = engine.wait_cqe();
        assert_eq!(cqe.user_data, 42);
        assert_eq!(cqe.bytes, 1024);
        let dst = SlotRef::new(arena, 0);
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, ((700 + i) % 239) as u8, "byte {i}");
        }
        assert_eq!(be.io_counters().reads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn backend_factory_respects_uring_flag() {
        let be = Arc::new(OsFileBackend::with_stripe_uring(
            512,
            2,
            crate::storage::backing::StripeSpec::single(),
        ));
        assert_eq!(crate::storage::api::IoBackend::name(be.as_ref()), "uring");
        let engine = be.clone().async_engine(4);
        assert_eq!(engine.inflight(), 0);
        drop(engine);
    }
}
