//! The pluggable I/O backend API: the seam between everything that *uses*
//! storage (extractors, samplers, baselines, benches) and whatever
//! *provides* it.
//!
//! Two traits define the seam:
//!
//! * [`IoBackend`] — the synchronous read/write contract plus the charging
//!   and accounting rules. **The backend owns all charging**: a caller never
//!   touches an `SsdSim` or a page cache directly; it asks the backend to
//!   read and the backend decides what that costs (simulated device time,
//!   real `pread` latency, nothing at all). Consumers observe costs only
//!   through [`IoBackend::io_counters`] / [`IoBackend::direct_stats`].
//! * [`AsyncIoEngine`] — the submit/harvest contract of an asynchronous
//!   engine (io_uring-style). Backends mint their own engine via
//!   [`IoBackend::async_engine`]; the sim backend returns the simulated
//!   [`super::uring::Uring`], the OS-file backend a `pread` thread pool.
//!
//! What a backend must guarantee:
//!
//! * **Bytes are real.** Every read fills the destination with the true
//!   bytes of the backing store at that offset (zero-filled past EOF).
//! * **Direct reads are sector-accounted.** `read_direct*` rounds the
//!   request out to [`IoBackend::sector`] alignment and records the
//!   `useful`/`aligned` byte split in [`DirectIoStats`], whether or not the
//!   backend charges device time for the redundancy (§4.4 of the paper).
//! * **Counters balance.** `io_counters()` accumulates one `reads`
//!   increment per charged request and the *charged* byte volume. On the
//!   direct path the charged volume is the sector-aligned (possibly
//!   coalesced) size on every backend, so `EpochStats::ssd_read_bytes` is
//!   directly comparable there. Buffered accounting follows each backend's
//!   cost model: the sim backend charges page-cache *misses* at page
//!   granularity, while the OS backend charges the bytes requested (the
//!   kernel's cache is opaque, so hits cannot be discounted) — buffered
//!   volumes are backend-relative, not cross-backend comparable.
//! * **Completions are synchronized.** An [`AsyncIoEngine`] completion
//!   (harvested CQE) happens-after the destination slot write; the caller
//!   may read the staging slot without any further synchronization.

use super::backing::StripeSpec;
use super::engine::SimFile;
use super::ssd::SsdCounters;
use crate::membuf::SlotRef;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

/// Typed I/O failure. This is what a [`Cqe`] carries instead of a panic when
/// a request cannot be served: consumers decide policy (retry the batch,
/// drop the rows, abort the epoch) — the storage layer only classifies.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IoError {
    /// Transient device error (injected or real `EIO`-class failure); a
    /// retry of the same request may succeed.
    Transient,
    /// The request touches a permanently bad device range; retries cannot
    /// succeed.
    BadRange { offset: u64 },
    /// The device returned fewer bytes than requested; `got < want`.
    ShortRead { got: usize, want: usize },
    /// The retry/deadline policy gave up on the request before it was
    /// served (per-request deadline exceeded mid-backoff).
    Deadline,
    /// The serving worker panicked while handling this request; the panic
    /// was contained and converted into this completion.
    Internal,
    /// The engine was closed or lost a worker with this request
    /// outstanding; its fate is unknown and its staging bytes must not be
    /// trusted.
    EnginePoisoned,
    /// Real OS read error with the raw errno (when available).
    Os { code: i32 },
}

impl IoError {
    /// Whether a bounded retry of the same request is worth attempting.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            IoError::Transient | IoError::ShortRead { .. } | IoError::Os { .. }
        )
    }
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Transient => write!(f, "transient device error"),
            IoError::BadRange { offset } => write!(f, "bad device range at offset {offset}"),
            IoError::ShortRead { got, want } => write!(f, "short read ({got}/{want} bytes)"),
            IoError::Deadline => write!(f, "request deadline exceeded"),
            IoError::Internal => write!(f, "engine worker panicked serving the request"),
            IoError::EnginePoisoned => write!(f, "engine poisoned/closed with request outstanding"),
            IoError::Os { code } => write!(f, "os read error (errno {code})"),
        }
    }
}

impl std::error::Error for IoError {}

/// Bounded-retry policy the async engines apply per request at the
/// submission/service layer. Retries happen on the engine worker serving
/// the request: each attempt goes back through the backend's read path, so
/// a retried read is **re-charged honestly** in `io_counters` (device ops
/// and bytes accrue per attempt) and counted in [`DirectIoStats::retries`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (`0` = fail fast).
    pub max_retries: u32,
    /// First backoff, microseconds (doubles per attempt, jittered).
    pub backoff_base_us: u64,
    /// Backoff ceiling, microseconds.
    pub backoff_cap_us: u64,
    /// Per-request service deadline, microseconds of wall time across all
    /// attempts and backoffs; `None` = unbounded. When the deadline passes
    /// mid-policy the request completes with [`IoError::Deadline`].
    pub deadline_us: Option<u64>,
    /// Seed for deterministic backoff jitter.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            backoff_base_us: 50,
            backoff_cap_us: 5_000,
            deadline_us: None,
            jitter_seed: 0x5EED,
        }
    }
}

impl RetryPolicy {
    /// Fail-fast policy: no retries, no deadline (`--on-io-error fail`).
    pub fn none() -> Self {
        RetryPolicy { max_retries: 0, ..RetryPolicy::default() }
    }

    /// Backoff before retry number `attempt` (1-based) of the request
    /// identified by `key`: exponential with full jitter, capped.
    /// Deterministic in `(jitter_seed, key, attempt)`.
    pub fn backoff_us(&self, key: u64, attempt: u32) -> u64 {
        let exp = self
            .backoff_base_us
            .saturating_mul(1u64 << attempt.saturating_sub(1).min(20))
            .min(self.backoff_cap_us);
        if exp == 0 {
            return 0;
        }
        // Full jitter in [exp/2, exp]: keeps retries spread without
        // collapsing the backoff floor.
        let h = crate::util::rng::hash3(self.jitter_seed, key, attempt as u64);
        exp / 2 + h % (exp / 2 + 1)
    }
}

/// Counters for direct-I/O alignment overhead (redundant bytes loaded when a
/// request does not fit sector granularity — §4.4 "Access Granularity").
///
/// With segment coalescing, `useful_bytes` still counts only the genuinely
/// requested row bytes while `aligned_bytes` counts the merged device span
/// (shared sectors once, bridged gaps included), so the amplification ratio
/// `aligned / useful` *drops* as coalescing merges rows.
#[derive(Debug, Default)]
pub struct DirectIoStats {
    pub requests: AtomicU64,
    pub useful_bytes: AtomicU64,
    pub aligned_bytes: AtomicU64,
    /// Requests re-issued by the engine retry policy (per retry attempt).
    pub retries: AtomicU64,
    /// Requests that completed with an error after the policy gave up.
    pub failures: AtomicU64,
    /// Direct reads served through the cached-`pread` bounce-buffer
    /// fallback instead of a real `O_DIRECT` descriptor (OS backend on
    /// filesystems that refuse `O_DIRECT`, or memory-backed files).
    pub direct_fallbacks: AtomicU64,
    /// Speculative duplicate issues of straggling in-flight segments
    /// (hedged reissue; each hedge is a real, honestly-charged request).
    pub io_hedges: AtomicU64,
    /// Hedges whose completion arrived before the straggling original's —
    /// the hedge's bytes were the ones scattered.
    pub hedge_wins: AtomicU64,
    /// `READ_FIXED` opportunities the kernel-uring engine downgraded to a
    /// plain `READ` because registering the staging arena as a fixed buffer
    /// failed (sticky per worker past `RLIMIT_MEMLOCK`). Zero on every
    /// other engine; a non-zero count is the "registered buffers silently
    /// degraded" signal surfaced in `EpochStats::summary()`.
    pub fixed_fallbacks: AtomicU64,
}

impl DirectIoStats {
    /// `(useful, aligned)` snapshot for per-epoch deltas (these counters are
    /// process-cumulative; `reset_io_stats` intentionally leaves them alone).
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.useful_bytes.load(std::sync::atomic::Ordering::Relaxed),
            self.aligned_bytes.load(std::sync::atomic::Ordering::Relaxed),
        )
    }

    /// Alignment-overhead bytes (aligned − useful) accumulated since `snap`.
    pub fn overhead_since(&self, snap: (u64, u64)) -> u64 {
        let (useful0, aligned0) = snap;
        let (useful, aligned) = self.snapshot();
        (aligned.saturating_sub(aligned0)).saturating_sub(useful.saturating_sub(useful0))
    }

    /// `(retries, failures, direct_fallbacks)` snapshot — like `snapshot`,
    /// these are process-cumulative and consumed as per-epoch deltas.
    pub fn fault_snapshot(&self) -> (u64, u64, u64) {
        use std::sync::atomic::Ordering::Relaxed;
        (
            self.retries.load(Relaxed),
            self.failures.load(Relaxed),
            self.direct_fallbacks.load(Relaxed),
        )
    }

    pub fn count_retry(&self) {
        self.retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn count_failure(&self) {
        self.failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn count_fallback(&self) {
        self.direct_fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// `(io_hedges, hedge_wins)` snapshot — process-cumulative like
    /// `snapshot`; consumed as per-epoch deltas.
    pub fn hedge_snapshot(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering::Relaxed;
        (self.io_hedges.load(Relaxed), self.hedge_wins.load(Relaxed))
    }

    pub fn count_hedge(&self) {
        self.io_hedges.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn count_hedge_win(&self) {
        self.hedge_wins.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Process-cumulative `fixed_fallbacks` value; consumed as per-epoch
    /// deltas like the other snapshots.
    pub fn fixed_fallback_snapshot(&self) -> u64 {
        self.fixed_fallbacks.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn count_fixed_fallback(&self) {
        self.fixed_fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Start-of-epoch I/O bookmark: zeroes the backend's `io_counters` and pins
/// the process-cumulative [`DirectIoStats`], so the per-epoch charged totals
/// every training system reports are one `start`/`totals` pair instead of a
/// hand-rolled snapshot at each call site.
pub struct EpochIoSnapshot {
    dio: (u64, u64),
    faults: (u64, u64, u64),
    hedges: (u64, u64),
    fixed: u64,
}

/// Per-epoch charged-I/O totals derived from an [`EpochIoSnapshot`]
/// (feeds `EpochStats::{ssd_read_bytes, ssd_read_requests,
/// align_overhead_bytes, io_retries, io_failures, direct_fallbacks}`).
pub struct EpochIoTotals {
    pub reads: u64,
    pub read_bytes: u64,
    pub align_overhead_bytes: u64,
    pub io_retries: u64,
    pub io_failures: u64,
    pub direct_fallbacks: u64,
    pub io_hedges: u64,
    pub hedge_wins: u64,
    pub fixed_fallbacks: u64,
}

impl EpochIoSnapshot {
    pub fn start(backend: &dyn IoBackend) -> Self {
        backend.reset_io_stats();
        EpochIoSnapshot {
            dio: backend.direct_stats().snapshot(),
            faults: backend.direct_stats().fault_snapshot(),
            hedges: backend.direct_stats().hedge_snapshot(),
            fixed: backend.direct_stats().fixed_fallback_snapshot(),
        }
    }

    pub fn totals(&self, backend: &dyn IoBackend) -> EpochIoTotals {
        use std::sync::atomic::Ordering;
        let c = backend.io_counters();
        let (retries0, failures0, fallbacks0) = self.faults;
        let (retries, failures, fallbacks) = backend.direct_stats().fault_snapshot();
        let (hedges0, wins0) = self.hedges;
        let (hedges, wins) = backend.direct_stats().hedge_snapshot();
        EpochIoTotals {
            reads: c.reads.load(Ordering::Relaxed),
            read_bytes: c.read_bytes.load(Ordering::Relaxed),
            align_overhead_bytes: backend.direct_stats().overhead_since(self.dio),
            io_retries: retries.saturating_sub(retries0),
            io_failures: failures.saturating_sub(failures0),
            direct_fallbacks: fallbacks.saturating_sub(fallbacks0),
            io_hedges: hedges.saturating_sub(hedges0),
            hedge_wins: wins.saturating_sub(wins0),
            fixed_fallbacks: backend
                .direct_stats()
                .fixed_fallback_snapshot()
                .saturating_sub(self.fixed),
        }
    }
}

/// How a request travels through the I/O stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// O_DIRECT: bypass the page cache, sector-aligned charge (GNNDrive's
    /// feature-read mode).
    Direct,
    /// Through the (simulated or OS) page cache.
    Buffered,
}

/// Submission queue entry: read `len` bytes at `offset` of `file` into the
/// staging range `dst` at `dst_off`, tagging the completion with `user_data`.
///
/// A request may carry a single feature row or a whole coalesced *segment*
/// (several rows merged into one contiguous device read by the extractor's
/// planning layer). The engine never sees the segment's row table — it
/// serves one contiguous `[offset, offset+len)` read; the submitter scatters
/// rows out of the completed range itself. `useful` is the genuinely
/// requested byte count inside the range (Σ row bytes; `== len` for an
/// un-coalesced request) and feeds [`DirectIoStats::useful_bytes`], so
/// alignment-amplification accounting stays honest across merged spans.
///
/// The destination is a lock-free [`SlotRef`] into a staging arena — the
/// engine's completion path writes the range bytes directly (no mutex per
/// row). The submitter owns the range for the request's lifetime and must
/// not touch `[dst_off, dst_off + len)` until the matching CQE is harvested.
#[derive(Clone)]
pub struct Sqe {
    pub file: SimFile,
    pub offset: u64,
    pub len: usize,
    /// Requested (non-padding, non-gap) bytes within the range; `≤ len`.
    pub useful: usize,
    pub dst: SlotRef,
    pub dst_off: usize,
    pub user_data: u64,
    pub mode: IoMode,
}

/// Completion queue event.
///
/// `status` is the error contract of the whole async stack: `Ok(bytes)`
/// means the request's staging range holds the true backing bytes;
/// `Err(e)` means the range contents are **undefined** and the submitter
/// must not decode them (it still owns the range and must release/reuse it
/// through the normal wave protocol). `bytes` mirrors `Ok` (and is `0` on
/// error) so accounting-only readers keep working.
#[derive(Clone, Debug)]
pub struct Cqe {
    pub user_data: u64,
    pub bytes: usize,
    pub status: Result<usize, IoError>,
}

impl Cqe {
    /// `user_data` of synthetic completions minted by a poisoned/closed
    /// engine core: they correspond to no specific SQE, so harvesters must
    /// treat the *whole* outstanding wave as failed.
    pub const POISON_USER_DATA: u64 = u64::MAX;

    pub fn ok(user_data: u64, bytes: usize) -> Self {
        Cqe { user_data, bytes, status: Ok(bytes) }
    }

    pub fn err(user_data: u64, err: IoError) -> Self {
        Cqe { user_data, bytes: 0, status: Err(err) }
    }

    pub fn is_ok(&self) -> bool {
        self.status.is_ok()
    }
}

/// An asynchronous I/O engine: bounded submission, unordered completion.
///
/// Contract (shared by the sim ring and the OS thread pool):
/// * `submit`/`submit_batch` block only on submission-queue backpressure;
///   the I/O itself proceeds on engine threads.
/// * completions may be harvested in any order; each CQE's `user_data`
///   matches its SQE and its slot bytes are fully written (happens-before
///   the harvest).
/// * `inflight() == 0 && pending_harvest() == 0` once every submitted
///   request has been harvested.
pub trait AsyncIoEngine: Send + Sync {
    /// Submit one request (blocks only if the submission queue is full).
    fn submit(&self, sqe: Sqe);
    /// Submit a batch with amortized locking/wakeups.
    fn submit_batch(&self, sqes: Vec<Sqe>);
    /// Harvest one completion, blocking until available.
    fn wait_cqe(&self) -> Cqe;
    /// Harvest exactly `n` completions, blocking as needed.
    fn wait_cqes(&self, n: usize) -> Vec<Cqe>;
    /// Harvest a completion if one is ready.
    fn peek_cqe(&self) -> Option<Cqe>;
    /// Outstanding requests (submitted − completed).
    fn inflight(&self) -> u64;
    /// Completions not yet harvested by the caller.
    fn pending_harvest(&self) -> u64;
    /// Quiesce after an aborted submit/harvest cycle: block until every
    /// submitted request has completed, then discard all unharvested CQEs.
    /// On return `inflight() == 0 && pending_harvest() == 0`, so the staging
    /// ranges of the abandoned requests are safe to reset or reissue — a
    /// late completion can no longer scatter into recycled arena bytes.
    /// Callers that harvested every CQE they submitted (the normal wave
    /// protocol) never need this; it exists for early-exit/abort paths.
    fn drain(&self);
    /// Per-device in-flight high-water marks since the engine was built:
    /// entry `d` is the most requests ever simultaneously outstanding on
    /// device `d`'s sub-queue. Empty when the engine does not track
    /// per-device queues (wrappers delegate; plain single-queue engines
    /// report one entry). Observability only — never part of the
    /// completion contract.
    fn queue_highwater(&self) -> Vec<u64> {
        Vec::new()
    }

    /// Advertise one host byte range `[addr, addr+len)` that every future
    /// SQE destination will fall inside (the extractor's staging arena).
    /// Engines that can pre-register DMA buffers with the kernel
    /// (`UringEngine` via `IORING_REGISTER_BUFFERS`) use it to serve reads
    /// as `READ_FIXED`; everyone else ignores it. Purely an optimization
    /// hint: correctness never depends on the call, and destinations
    /// outside the range must still work (served unregistered). The caller
    /// must keep the range alive for the engine's lifetime — the extractor
    /// satisfies this because it owns both the staging arena and the engine
    /// and the arena outlives the engine.
    fn register_buffer_range(&self, addr: usize, len: usize) {
        let _ = (addr, len);
    }
}

/// A storage backend: synchronous reads/writes + charging + stats, and a
/// factory for the matching asynchronous engine.
///
/// Implementations: [`super::engine::SimBackend`] (simulated SSD + page
/// cache; timing charged by sleeping on a scaled clock) and
/// [`super::osfile::OsFileBackend`] (real `pread` over file-backed stores;
/// the OS is the device).
pub trait IoBackend: Send + Sync {
    /// Short CLI-facing name ("sim", "os").
    fn name(&self) -> &'static str;

    /// Direct-I/O alignment granularity in bytes.
    fn sector(&self) -> usize;

    /// Buffered read (mmap semantics): page-granular, through the backend's
    /// cache; sequential misses may coalesce into fewer device requests.
    fn read_buffered(&self, file: &SimFile, offset: u64, buf: &mut [u8]);

    /// Direct read (O_DIRECT semantics): bypasses the cache; the
    /// sector-aligned size is charged and recorded in `direct_stats`.
    fn read_direct(&self, file: &SimFile, offset: u64, buf: &mut [u8]);

    /// Direct-read accounting + data copy *without* the device-time charge;
    /// returns the sector-aligned byte count. Sugar for
    /// [`IoBackend::read_direct_segment_nocharge`] with every byte useful.
    fn read_direct_nocharge(&self, file: &SimFile, offset: u64, buf: &mut [u8]) -> usize {
        let useful = buf.len();
        self.read_direct_segment_nocharge(file, offset, useful, buf)
    }

    /// Segment-granular direct read: fill `buf` from `[offset,
    /// offset+buf.len())` (one contiguous, possibly multi-row span), record
    /// **one** request in `direct_stats` with `useful` useful bytes and the
    /// sector-aligned span as aligned bytes, and return that aligned span —
    /// *without* the device-time charge. Async engines pair this with
    /// [`IoBackend::charge_multi`]: one charged op per segment, so merged
    /// rows stop paying per-row IOPS and duplicate-sector redundancy.
    fn read_direct_segment_nocharge(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
    ) -> usize;

    /// Fallible segment-granular direct read (same accounting contract as
    /// [`IoBackend::read_direct_segment_nocharge`], same no-charge pairing
    /// with [`IoBackend::charge_multi`]). `attempt` is the 0-based service
    /// attempt of this request: fault-injecting backends key their
    /// deterministic fault plan on `(offset, attempt)`, so a transient
    /// fault on attempt 0 can deterministically succeed on attempt 1 and a
    /// fixed seed replays exactly. Plain backends ignore it and never fail.
    ///
    /// On `Err` the destination bytes are undefined and **nothing** was
    /// recorded in `direct_stats` alignment counters (device-time charges
    /// for the failed attempt, if any, are the backend's own business).
    fn try_read_direct_segment(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
        attempt: u32,
    ) -> Result<usize, IoError> {
        let _ = attempt;
        Ok(self.read_direct_segment_nocharge(file, offset, useful, buf))
    }

    /// Fallible fully-charged direct read (sync extraction path). Default:
    /// the infallible [`IoBackend::read_direct`], which never fails.
    fn try_read_direct(
        &self,
        file: &SimFile,
        offset: u64,
        buf: &mut [u8],
        attempt: u32,
    ) -> Result<(), IoError> {
        let _ = attempt;
        self.read_direct(file, offset, buf);
        Ok(())
    }

    /// Fallible buffered read. Default: the infallible
    /// [`IoBackend::read_buffered`], which never fails.
    fn try_read_buffered(
        &self,
        file: &SimFile,
        offset: u64,
        buf: &mut [u8],
        attempt: u32,
    ) -> Result<(), IoError> {
        let _ = attempt;
        self.read_buffered(file, offset, buf);
        Ok(())
    }

    /// The bounded-retry policy this backend's engines apply per request.
    /// Plain backends use the default policy (errors only arise from real
    /// OS faults there); the fault-injecting wrapper carries whatever the
    /// `--on-io-error` / `--io-retries` knobs configured.
    fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy::default()
    }

    /// Charge a coalesced batch of `ops` direct reads totalling `bytes`
    /// (pairs with `read_direct_nocharge` / `read_direct_segment_nocharge`).
    /// A no-op when `ops == 0`.
    fn charge_multi(&self, ops: u64, bytes: usize);

    /// The stripe geometry this backend serves. [`StripeSpec::single`] (the
    /// default) means "one device, logical == physical"; a striped backend
    /// returns its real geometry so engines can route SQEs to per-device
    /// sub-queues and the planner can keep segments inside one chunk.
    fn stripe(&self) -> StripeSpec {
        StripeSpec::single()
    }

    /// Per-device flavor of [`IoBackend::charge_multi`]: charge `ops` reads
    /// totalling `bytes` against device `dev` of the stripe set. Engines use
    /// this when every request in a charged batch landed on one known
    /// device, so a striped backend can debit that device's independent
    /// IOPS/bandwidth budget instead of a serialized global one. Default:
    /// ignore `dev` and fall through to `charge_multi` — which is exactly
    /// the pre-striping behavior and keeps single-device accounting
    /// byte-for-byte identical.
    fn charge_multi_dev(&self, dev: usize, ops: u64, bytes: usize) {
        let _ = dev;
        self.charge_multi(ops, bytes);
    }

    /// Per-device `(reads, read_bytes)` breakdown of the charged counters
    /// since the last `reset_io_stats`. Default: one entry mirroring
    /// `io_counters` (single-device backends have nothing to break down).
    fn device_io_snapshot(&self) -> Vec<(u64, u64)> {
        use std::sync::atomic::Ordering::Relaxed;
        let c = self.io_counters();
        vec![(c.reads.load(Relaxed), c.read_bytes.load(Relaxed))]
    }

    /// Buffered write: cache pages become resident; device time is charged
    /// for the whole range.
    fn write_buffered(&self, file: &SimFile, offset: u64, len: usize);

    /// Direct write of an aligned range (charge only; data writes are not
    /// persisted by any backend — training never reads them back).
    fn write_direct(&self, file: &SimFile, offset: u64, len: usize);

    /// Charge one sequential read of `len` bytes with no data destination
    /// (baseline cost models: Marius partition preloads, Ginex inspect).
    fn charge_read(&self, len: usize);

    /// Charge one write of `len` bytes with no data source (Ginex's
    /// superbatch dumps).
    fn charge_write(&self, len: usize);

    /// Alignment-overhead counters for the direct path.
    fn direct_stats(&self) -> &DirectIoStats;

    /// Charged-request counters (reads/writes, charged byte volume). On the
    /// sim backend these are the `SsdSim` counters; on real backends an
    /// equivalent tally.
    fn io_counters(&self) -> &SsdCounters;

    /// Zero `io_counters` (and any latency histograms) for a fresh epoch.
    fn reset_io_stats(&self);

    /// Build this backend's asynchronous engine with `depth` max outstanding
    /// requests.
    fn async_engine(self: Arc<Self>, depth: usize) -> Box<dyn AsyncIoEngine>;

    /// Kernel-submittable translation of `[offset, offset+len)` of `file`:
    /// `Some((raw_fd, physical_offset))` when the whole span lives in one
    /// real OS file the `UringEngine` may read directly (striped backings
    /// translate to the owning member; spans straddling members return
    /// `None`). `None` (the default) routes the request through the
    /// `serve_sqe` fallback path instead — sim backends, fault-injecting
    /// wrappers with an active plan, and procedural backings all say `None`
    /// so their semantics (charging by sleeping, deterministic fault draws,
    /// generated bytes) are never bypassed by a raw kernel read. The fd
    /// stays owned by the backing; callers must not close it.
    fn uring_target(&self, file: &SimFile, offset: u64, len: usize) -> Option<(i32, u64)> {
        let _ = (file, offset, len);
        None
    }
}

/// Which backend to instantiate (CLI/config selector).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Simulated SSD + simulated page cache + sim io_uring (the default:
    /// reproduces the paper's timing model).
    #[default]
    Sim,
    /// Real OS files: `pread`-based reads over `FileBacking` with a
    /// thread-pool async engine. Requires a dataset written to disk
    /// (`gnndrive gen-data` + `--data`).
    Os,
    /// Real OS files served by the genuine `io_uring` syscall engine
    /// (`storage/uring_os.rs`). Runtime-gated: selection probes the kernel
    /// at startup and falls back to the `Os` pread path (with a one-time
    /// warning) when io_uring is unavailable. Same dataset requirements as
    /// `Os`.
    Uring,
}

impl BackendKind {
    /// Case-insensitive CLI lookup.
    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sim" | "simulated" => Some(BackendKind::Sim),
            "os" | "os-file" | "osfile" => Some(BackendKind::Os),
            "uring" | "io-uring" | "io_uring" => Some(BackendKind::Uring),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Os => "os",
            BackendKind::Uring => "uring",
        }
    }

    /// Valid CLI names, for error messages.
    pub fn names() -> &'static str {
        "sim, os, uring"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_by_name_is_case_insensitive() {
        assert_eq!(BackendKind::by_name("sim"), Some(BackendKind::Sim));
        assert_eq!(BackendKind::by_name("SIM"), Some(BackendKind::Sim));
        assert_eq!(BackendKind::by_name("Os"), Some(BackendKind::Os));
        assert_eq!(BackendKind::by_name("OS-FILE"), Some(BackendKind::Os));
        assert_eq!(BackendKind::by_name("nvme"), None);
        assert_eq!(BackendKind::by_name("uring"), Some(BackendKind::Uring));
        assert_eq!(BackendKind::by_name("IO-URING"), Some(BackendKind::Uring));
        assert_eq!(BackendKind::by_name("io_uring"), Some(BackendKind::Uring));
        assert_eq!(BackendKind::Uring.label(), "uring");
        assert_eq!(BackendKind::default(), BackendKind::Sim);
    }

    #[test]
    fn hedge_counters_snapshot_as_deltas() {
        let s = DirectIoStats::default();
        assert_eq!(s.hedge_snapshot(), (0, 0));
        s.count_hedge();
        s.count_hedge();
        s.count_hedge_win();
        assert_eq!(s.hedge_snapshot(), (2, 1));
    }
}
