//! The pluggable I/O backend API: the seam between everything that *uses*
//! storage (extractors, samplers, baselines, benches) and whatever
//! *provides* it.
//!
//! Two traits define the seam:
//!
//! * [`IoBackend`] — the synchronous read/write contract plus the charging
//!   and accounting rules. **The backend owns all charging**: a caller never
//!   touches an `SsdSim` or a page cache directly; it asks the backend to
//!   read and the backend decides what that costs (simulated device time,
//!   real `pread` latency, nothing at all). Consumers observe costs only
//!   through [`IoBackend::io_counters`] / [`IoBackend::direct_stats`].
//! * [`AsyncIoEngine`] — the submit/harvest contract of an asynchronous
//!   engine (io_uring-style). Backends mint their own engine via
//!   [`IoBackend::async_engine`]; the sim backend returns the simulated
//!   [`super::uring::Uring`], the OS-file backend a `pread` thread pool.
//!
//! What a backend must guarantee:
//!
//! * **Bytes are real.** Every read fills the destination with the true
//!   bytes of the backing store at that offset (zero-filled past EOF).
//! * **Direct reads are sector-accounted.** `read_direct*` rounds the
//!   request out to [`IoBackend::sector`] alignment and records the
//!   `useful`/`aligned` byte split in [`DirectIoStats`], whether or not the
//!   backend charges device time for the redundancy (§4.4 of the paper).
//! * **Counters balance.** `io_counters()` accumulates one `reads`
//!   increment per charged request and the *charged* byte volume. On the
//!   direct path the charged volume is the sector-aligned (possibly
//!   coalesced) size on every backend, so `EpochStats::ssd_read_bytes` is
//!   directly comparable there. Buffered accounting follows each backend's
//!   cost model: the sim backend charges page-cache *misses* at page
//!   granularity, while the OS backend charges the bytes requested (the
//!   kernel's cache is opaque, so hits cannot be discounted) — buffered
//!   volumes are backend-relative, not cross-backend comparable.
//! * **Completions are synchronized.** An [`AsyncIoEngine`] completion
//!   (harvested CQE) happens-after the destination slot write; the caller
//!   may read the staging slot without any further synchronization.

use super::engine::SimFile;
use super::ssd::SsdCounters;
use crate::membuf::SlotRef;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

/// Counters for direct-I/O alignment overhead (redundant bytes loaded when a
/// request does not fit sector granularity — §4.4 "Access Granularity").
///
/// With segment coalescing, `useful_bytes` still counts only the genuinely
/// requested row bytes while `aligned_bytes` counts the merged device span
/// (shared sectors once, bridged gaps included), so the amplification ratio
/// `aligned / useful` *drops* as coalescing merges rows.
#[derive(Debug, Default)]
pub struct DirectIoStats {
    pub requests: AtomicU64,
    pub useful_bytes: AtomicU64,
    pub aligned_bytes: AtomicU64,
}

impl DirectIoStats {
    /// `(useful, aligned)` snapshot for per-epoch deltas (these counters are
    /// process-cumulative; `reset_io_stats` intentionally leaves them alone).
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.useful_bytes.load(std::sync::atomic::Ordering::Relaxed),
            self.aligned_bytes.load(std::sync::atomic::Ordering::Relaxed),
        )
    }

    /// Alignment-overhead bytes (aligned − useful) accumulated since `snap`.
    pub fn overhead_since(&self, snap: (u64, u64)) -> u64 {
        let (useful0, aligned0) = snap;
        let (useful, aligned) = self.snapshot();
        (aligned.saturating_sub(aligned0)).saturating_sub(useful.saturating_sub(useful0))
    }
}

/// Start-of-epoch I/O bookmark: zeroes the backend's `io_counters` and pins
/// the process-cumulative [`DirectIoStats`], so the per-epoch charged totals
/// every training system reports are one `start`/`totals` pair instead of a
/// hand-rolled snapshot at each call site.
pub struct EpochIoSnapshot {
    dio: (u64, u64),
}

/// Per-epoch charged-I/O totals derived from an [`EpochIoSnapshot`]
/// (feeds `EpochStats::{ssd_read_bytes, ssd_read_requests,
/// align_overhead_bytes}`).
pub struct EpochIoTotals {
    pub reads: u64,
    pub read_bytes: u64,
    pub align_overhead_bytes: u64,
}

impl EpochIoSnapshot {
    pub fn start(backend: &dyn IoBackend) -> Self {
        backend.reset_io_stats();
        EpochIoSnapshot { dio: backend.direct_stats().snapshot() }
    }

    pub fn totals(&self, backend: &dyn IoBackend) -> EpochIoTotals {
        use std::sync::atomic::Ordering;
        let c = backend.io_counters();
        EpochIoTotals {
            reads: c.reads.load(Ordering::Relaxed),
            read_bytes: c.read_bytes.load(Ordering::Relaxed),
            align_overhead_bytes: backend.direct_stats().overhead_since(self.dio),
        }
    }
}

/// How a request travels through the I/O stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// O_DIRECT: bypass the page cache, sector-aligned charge (GNNDrive's
    /// feature-read mode).
    Direct,
    /// Through the (simulated or OS) page cache.
    Buffered,
}

/// Submission queue entry: read `len` bytes at `offset` of `file` into the
/// staging range `dst` at `dst_off`, tagging the completion with `user_data`.
///
/// A request may carry a single feature row or a whole coalesced *segment*
/// (several rows merged into one contiguous device read by the extractor's
/// planning layer). The engine never sees the segment's row table — it
/// serves one contiguous `[offset, offset+len)` read; the submitter scatters
/// rows out of the completed range itself. `useful` is the genuinely
/// requested byte count inside the range (Σ row bytes; `== len` for an
/// un-coalesced request) and feeds [`DirectIoStats::useful_bytes`], so
/// alignment-amplification accounting stays honest across merged spans.
///
/// The destination is a lock-free [`SlotRef`] into a staging arena — the
/// engine's completion path writes the range bytes directly (no mutex per
/// row). The submitter owns the range for the request's lifetime and must
/// not touch `[dst_off, dst_off + len)` until the matching CQE is harvested.
pub struct Sqe {
    pub file: SimFile,
    pub offset: u64,
    pub len: usize,
    /// Requested (non-padding, non-gap) bytes within the range; `≤ len`.
    pub useful: usize,
    pub dst: SlotRef,
    pub dst_off: usize,
    pub user_data: u64,
    pub mode: IoMode,
}

/// Completion queue event.
#[derive(Debug)]
pub struct Cqe {
    pub user_data: u64,
    pub bytes: usize,
}

/// An asynchronous I/O engine: bounded submission, unordered completion.
///
/// Contract (shared by the sim ring and the OS thread pool):
/// * `submit`/`submit_batch` block only on submission-queue backpressure;
///   the I/O itself proceeds on engine threads.
/// * completions may be harvested in any order; each CQE's `user_data`
///   matches its SQE and its slot bytes are fully written (happens-before
///   the harvest).
/// * `inflight() == 0 && pending_harvest() == 0` once every submitted
///   request has been harvested.
pub trait AsyncIoEngine: Send + Sync {
    /// Submit one request (blocks only if the submission queue is full).
    fn submit(&self, sqe: Sqe);
    /// Submit a batch with amortized locking/wakeups.
    fn submit_batch(&self, sqes: Vec<Sqe>);
    /// Harvest one completion, blocking until available.
    fn wait_cqe(&self) -> Cqe;
    /// Harvest exactly `n` completions, blocking as needed.
    fn wait_cqes(&self, n: usize) -> Vec<Cqe>;
    /// Harvest a completion if one is ready.
    fn peek_cqe(&self) -> Option<Cqe>;
    /// Outstanding requests (submitted − completed).
    fn inflight(&self) -> u64;
    /// Completions not yet harvested by the caller.
    fn pending_harvest(&self) -> u64;
    /// Quiesce after an aborted submit/harvest cycle: block until every
    /// submitted request has completed, then discard all unharvested CQEs.
    /// On return `inflight() == 0 && pending_harvest() == 0`, so the staging
    /// ranges of the abandoned requests are safe to reset or reissue — a
    /// late completion can no longer scatter into recycled arena bytes.
    /// Callers that harvested every CQE they submitted (the normal wave
    /// protocol) never need this; it exists for early-exit/abort paths.
    fn drain(&self);
}

/// A storage backend: synchronous reads/writes + charging + stats, and a
/// factory for the matching asynchronous engine.
///
/// Implementations: [`super::engine::SimBackend`] (simulated SSD + page
/// cache; timing charged by sleeping on a scaled clock) and
/// [`super::osfile::OsFileBackend`] (real `pread` over file-backed stores;
/// the OS is the device).
pub trait IoBackend: Send + Sync {
    /// Short CLI-facing name ("sim", "os").
    fn name(&self) -> &'static str;

    /// Direct-I/O alignment granularity in bytes.
    fn sector(&self) -> usize;

    /// Buffered read (mmap semantics): page-granular, through the backend's
    /// cache; sequential misses may coalesce into fewer device requests.
    fn read_buffered(&self, file: &SimFile, offset: u64, buf: &mut [u8]);

    /// Direct read (O_DIRECT semantics): bypasses the cache; the
    /// sector-aligned size is charged and recorded in `direct_stats`.
    fn read_direct(&self, file: &SimFile, offset: u64, buf: &mut [u8]);

    /// Direct-read accounting + data copy *without* the device-time charge;
    /// returns the sector-aligned byte count. Sugar for
    /// [`IoBackend::read_direct_segment_nocharge`] with every byte useful.
    fn read_direct_nocharge(&self, file: &SimFile, offset: u64, buf: &mut [u8]) -> usize {
        let useful = buf.len();
        self.read_direct_segment_nocharge(file, offset, useful, buf)
    }

    /// Segment-granular direct read: fill `buf` from `[offset,
    /// offset+buf.len())` (one contiguous, possibly multi-row span), record
    /// **one** request in `direct_stats` with `useful` useful bytes and the
    /// sector-aligned span as aligned bytes, and return that aligned span —
    /// *without* the device-time charge. Async engines pair this with
    /// [`IoBackend::charge_multi`]: one charged op per segment, so merged
    /// rows stop paying per-row IOPS and duplicate-sector redundancy.
    fn read_direct_segment_nocharge(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
    ) -> usize;

    /// Charge a coalesced batch of `ops` direct reads totalling `bytes`
    /// (pairs with `read_direct_nocharge` / `read_direct_segment_nocharge`).
    /// A no-op when `ops == 0`.
    fn charge_multi(&self, ops: u64, bytes: usize);

    /// Buffered write: cache pages become resident; device time is charged
    /// for the whole range.
    fn write_buffered(&self, file: &SimFile, offset: u64, len: usize);

    /// Direct write of an aligned range (charge only; data writes are not
    /// persisted by any backend — training never reads them back).
    fn write_direct(&self, file: &SimFile, offset: u64, len: usize);

    /// Charge one sequential read of `len` bytes with no data destination
    /// (baseline cost models: Marius partition preloads, Ginex inspect).
    fn charge_read(&self, len: usize);

    /// Charge one write of `len` bytes with no data source (Ginex's
    /// superbatch dumps).
    fn charge_write(&self, len: usize);

    /// Alignment-overhead counters for the direct path.
    fn direct_stats(&self) -> &DirectIoStats;

    /// Charged-request counters (reads/writes, charged byte volume). On the
    /// sim backend these are the `SsdSim` counters; on real backends an
    /// equivalent tally.
    fn io_counters(&self) -> &SsdCounters;

    /// Zero `io_counters` (and any latency histograms) for a fresh epoch.
    fn reset_io_stats(&self);

    /// Build this backend's asynchronous engine with `depth` max outstanding
    /// requests.
    fn async_engine(self: Arc<Self>, depth: usize) -> Box<dyn AsyncIoEngine>;
}

/// Which backend to instantiate (CLI/config selector).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Simulated SSD + simulated page cache + sim io_uring (the default:
    /// reproduces the paper's timing model).
    #[default]
    Sim,
    /// Real OS files: `pread`-based reads over `FileBacking` with a
    /// thread-pool async engine. Requires a dataset written to disk
    /// (`gnndrive gen-data` + `--data`).
    Os,
}

impl BackendKind {
    /// Case-insensitive CLI lookup.
    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sim" | "simulated" => Some(BackendKind::Sim),
            "os" | "os-file" | "osfile" => Some(BackendKind::Os),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Os => "os",
        }
    }

    /// Valid CLI names, for error messages.
    pub fn names() -> &'static str {
        "sim, os"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_by_name_is_case_insensitive() {
        assert_eq!(BackendKind::by_name("sim"), Some(BackendKind::Sim));
        assert_eq!(BackendKind::by_name("SIM"), Some(BackendKind::Sim));
        assert_eq!(BackendKind::by_name("Os"), Some(BackendKind::Os));
        assert_eq!(BackendKind::by_name("OS-FILE"), Some(BackendKind::Os));
        assert_eq!(BackendKind::by_name("nvme"), None);
        assert_eq!(BackendKind::default(), BackendKind::Sim);
    }
}
