//! Real-OS-file storage backend: `pread`-based reads with no simulated
//! device in front.
//!
//! [`OsFileBackend`] serves the same [`IoBackend`] contract as the sim stack
//! but against the host filesystem: a [`SimFile`] whose backing is a
//! [`crate::storage::FileBacking`] is read with positional `pread` (buffered
//! reads go through the *real* OS page cache — there is nothing to
//! simulate), and "charges" degrade to pure accounting so
//! `EpochStats::ssd_read_bytes` keeps meaning the charged byte volume.
//! Direct reads round out to sector alignment in the stats *and* go through
//! the backing's `O_DIRECT` path when the filesystem grants it
//! ([`crate::storage::backing::Backing::read_direct_at`]; graceful fallback
//! to cached `pread` with a one-time warning otherwise), so the `-direct`
//! ablation is real on hardware and the §4.4 redundancy analysis stays
//! comparable across backends.
//!
//! Its asynchronous engine is [`PreadPool`]: a plain thread pool draining a
//! bounded submission queue with one positional read per request — the
//! classic libaio-emulation shape. A request may be a coalesced multi-row
//! *segment*: the pool serves it as one contiguous `pread`, which is exactly
//! the mostly-sequential access pattern the coalescing planner exists to
//! produce. The SQ/CQ + counter discipline is the shared
//! [`super::engine_core::EngineCore`].

use super::api::{AsyncIoEngine, Cqe, DirectIoStats, IoBackend, IoMode, Sqe};
use super::backing::StripeSpec;
use super::engine::SimFile;
use super::engine_core::EngineCore;
use super::ssd::SsdCounters;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Default `pread` worker threads per async engine (≈ the paper's ">2×
/// cores" sizing for synchronous I/O thread pools, bounded for the CI box;
/// override with `--io-workers`).
pub const DEFAULT_POOL_THREADS: usize = 8;

pub struct OsFileBackend {
    sector: usize,
    pool_threads: usize,
    counters: SsdCounters,
    direct_stats: DirectIoStats,
    spec: StripeSpec,
    /// Per-stripe-device charged counters (`device_io_snapshot`); the
    /// aggregate `counters` above stays the `io_counters` surface. One
    /// entry per device; len 1 when unstriped.
    dev_counters: Vec<SsdCounters>,
    /// When true, `async_engine` mints the genuine io_uring submission
    /// path ([`super::uring_os::UringEngine`]) instead of [`PreadPool`].
    /// The backend surface (charging, O_DIRECT fallback accounting,
    /// per-device breakdown) is identical either way — only the syscall
    /// engine behind `async_engine` changes, so conformance and fault
    /// coverage carry over. Set only after `probe_uring()` succeeded.
    uring: bool,
}

impl OsFileBackend {
    pub fn new(sector: usize) -> Self {
        Self::with_pool_threads(sector, DEFAULT_POOL_THREADS)
    }

    pub fn with_pool_threads(sector: usize, pool_threads: usize) -> Self {
        Self::with_stripe(sector, pool_threads, StripeSpec::single())
    }

    /// Backend over a striped file set: `spec` describes the geometry the
    /// dataset's `StripedBacking` was written with. The OS is still the
    /// device; striping here drives per-device engine queues and the
    /// per-device accounting breakdown.
    pub fn with_stripe(sector: usize, pool_threads: usize, spec: StripeSpec) -> Self {
        assert!(sector > 0, "sector must be non-zero");
        OsFileBackend {
            sector,
            pool_threads: pool_threads.max(1),
            counters: SsdCounters::default(),
            direct_stats: DirectIoStats::default(),
            spec,
            dev_counters: (0..spec.devices.max(1)).map(|_| SsdCounters::default()).collect(),
            uring: false,
        }
    }

    /// Same backend, but `async_engine` mints the io_uring syscall engine.
    /// Callers must gate this behind [`super::uring_os::probe_uring`]:
    /// constructing it on a kernel without io_uring still works (workers
    /// degrade to the serve_sqe fallback with a one-time warning), but the
    /// intended selection path is probe-then-construct so `--backend uring`
    /// falls back to the pread pool *typed*, not silently degraded.
    pub fn with_stripe_uring(sector: usize, pool_threads: usize, spec: StripeSpec) -> Self {
        let mut be = Self::with_stripe(sector, pool_threads, spec);
        be.uring = true;
        be
    }

    /// Sector-aligned size of a `[offset, offset+len)` request.
    fn aligned_len(&self, offset: u64, len: usize) -> usize {
        let sector = self.sector as u64;
        let lo = offset / sector * sector;
        let hi = (offset + len as u64).div_ceil(sector) * sector;
        (hi - lo) as usize
    }

    /// Attribute `ops`/`bytes` read charges to device `dev`'s breakdown
    /// (aggregate accounting is the caller's job).
    fn tally_dev_read(&self, dev: usize, ops: u64, bytes: u64) {
        if self.spec.is_striped() {
            self.dev_counters[dev.min(self.dev_counters.len() - 1)].add_read(ops, bytes);
        }
    }
}

impl IoBackend for OsFileBackend {
    fn name(&self) -> &'static str {
        if self.uring {
            "uring"
        } else {
            "os"
        }
    }

    fn sector(&self) -> usize {
        self.sector
    }

    fn read_buffered(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        if buf.is_empty() {
            return;
        }
        // The OS page cache is the cache: a FileBacking read IS a pread and
        // the kernel decides hit vs miss. Charged volume is therefore the
        // bytes *requested* — hits cannot be discounted the way the sim
        // backend's page-cache model does (see the buffered-accounting note
        // on `IoBackend`).
        self.counters.reads.fetch_add(1, Ordering::Relaxed);
        self.counters.read_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.tally_dev_read(self.spec.device_of(offset), 1, buf.len() as u64);
        file.backing.read_at(offset, buf);
    }

    fn read_direct(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        let aligned = self.read_direct_nocharge(file, offset, buf);
        self.charge_multi_dev(self.spec.device_of(offset), u64::from(aligned > 0), aligned);
    }

    fn read_direct_segment_nocharge(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
    ) -> usize {
        self.try_read_direct_segment(file, offset, useful, buf, 0)
            .expect("os direct read failed")
    }

    fn try_read_direct_segment(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
        _attempt: u32,
    ) -> Result<usize, super::api::IoError> {
        if buf.is_empty() {
            return Ok(0);
        }
        let aligned = self.aligned_len(offset, buf.len());
        // Real O_DIRECT when the backing supports it (FileBacking on a
        // filesystem that grants the flag); cached pread fallback otherwise
        // — surfaced in `direct_stats.direct_fallbacks`, not just a one-time
        // stderr warning. Real read errors propagate typed; nothing is
        // recorded for a failed request.
        let odirect = file.backing.try_read_direct_at(offset, buf)?;
        if !odirect {
            self.direct_stats.count_fallback();
        }
        self.direct_stats.requests.fetch_add(1, Ordering::Relaxed);
        self.direct_stats.useful_bytes.fetch_add(useful as u64, Ordering::Relaxed);
        self.direct_stats.aligned_bytes.fetch_add(aligned as u64, Ordering::Relaxed);
        Ok(aligned)
    }

    fn try_read_direct(
        &self,
        file: &SimFile,
        offset: u64,
        buf: &mut [u8],
        attempt: u32,
    ) -> Result<(), super::api::IoError> {
        let useful = buf.len();
        let aligned = self.try_read_direct_segment(file, offset, useful, buf, attempt)?;
        self.charge_multi_dev(self.spec.device_of(offset), u64::from(aligned > 0), aligned);
        Ok(())
    }

    fn try_read_buffered(
        &self,
        file: &SimFile,
        offset: u64,
        buf: &mut [u8],
        _attempt: u32,
    ) -> Result<(), super::api::IoError> {
        if buf.is_empty() {
            return Ok(());
        }
        self.counters.reads.fetch_add(1, Ordering::Relaxed);
        self.counters.read_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.tally_dev_read(self.spec.device_of(offset), 1, buf.len() as u64);
        file.backing.try_read_at(offset, buf)
    }

    fn charge_multi(&self, ops: u64, bytes: usize) {
        if ops == 0 {
            return;
        }
        self.counters.reads.fetch_add(ops, Ordering::Relaxed);
        self.counters.read_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        // No offset: attribute to device 0 (legacy callers; engines use
        // `charge_multi_dev`).
        self.tally_dev_read(0, ops, bytes as u64);
    }

    fn stripe(&self) -> StripeSpec {
        self.spec
    }

    fn charge_multi_dev(&self, dev: usize, ops: u64, bytes: usize) {
        if ops == 0 {
            return;
        }
        self.counters.reads.fetch_add(ops, Ordering::Relaxed);
        self.counters.read_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.tally_dev_read(dev, ops, bytes as u64);
    }

    fn device_io_snapshot(&self) -> Vec<(u64, u64)> {
        if !self.spec.is_striped() {
            return vec![self.counters.read_snapshot()];
        }
        self.dev_counters.iter().map(|c| c.read_snapshot()).collect()
    }

    fn write_buffered(&self, _file: &SimFile, _offset: u64, len: usize) {
        if len == 0 {
            return;
        }
        self.counters.writes.fetch_add(1, Ordering::Relaxed);
        self.counters.write_bytes.fetch_add(len as u64, Ordering::Relaxed);
    }

    fn write_direct(&self, _file: &SimFile, _offset: u64, len: usize) {
        if len == 0 {
            return;
        }
        let aligned = len.div_ceil(self.sector) * self.sector;
        self.counters.writes.fetch_add(1, Ordering::Relaxed);
        self.counters.write_bytes.fetch_add(aligned as u64, Ordering::Relaxed);
    }

    fn charge_read(&self, len: usize) {
        if len == 0 {
            return;
        }
        self.counters.reads.fetch_add(1, Ordering::Relaxed);
        self.counters.read_bytes.fetch_add(len as u64, Ordering::Relaxed);
    }

    fn charge_write(&self, len: usize) {
        if len == 0 {
            return;
        }
        self.counters.writes.fetch_add(1, Ordering::Relaxed);
        self.counters.write_bytes.fetch_add(len as u64, Ordering::Relaxed);
    }

    fn direct_stats(&self) -> &DirectIoStats {
        &self.direct_stats
    }

    fn io_counters(&self) -> &SsdCounters {
        &self.counters
    }

    fn reset_io_stats(&self) {
        self.counters.reset();
        for c in &self.dev_counters {
            c.reset();
        }
    }

    fn uring_target(&self, file: &SimFile, offset: u64, len: usize) -> Option<(i32, u64)> {
        // Pure translation: the backing answers only when the whole span
        // lands inside one real OS file at a contiguous physical offset.
        // Charging stays with the engine that consumes the answer.
        file.backing.uring_target(offset, len)
    }

    fn async_engine(self: Arc<Self>, depth: usize) -> Box<dyn AsyncIoEngine> {
        let threads = self.pool_threads;
        if self.uring {
            Box::new(super::uring_os::UringEngine::new(self, depth, threads))
        } else {
            Box::new(PreadPool::new(self, depth, threads))
        }
    }
}

/// Thread-pool asynchronous engine over any [`IoBackend`]: N workers drain
/// a bounded submission queue with one positional read per request and
/// publish completions onto an unbounded completion queue. Same
/// submit/harvest contract (and shared [`EngineCore`] counter discipline)
/// as the sim ring. Each direct request — row or coalesced segment — is one
/// `pread` and one charged op.
pub struct PreadPool {
    core: EngineCore,
    workers: Vec<JoinHandle<()>>,
}

impl PreadPool {
    pub fn new(backend: Arc<dyn IoBackend>, depth: usize, threads: usize) -> Self {
        let depth = depth.max(1);
        let spec = backend.stripe();
        let core = EngineCore::new_striped("pread pool", depth, spec);
        let devices = core.device_count();
        let policy = backend.retry_policy();
        // `--io-workers` threads, at least one per stripe device so no
        // sub-queue can starve (workers bind to one device's sub-queue,
        // round-robin).
        let workers = (0..threads.max(1).min(depth).max(devices))
            .map(|w| {
                let dev = w % devices;
                let port = core.worker_port(dev);
                let backend = backend.clone();
                std::thread::spawn(move || {
                    crate::metrics::state::register(crate::metrics::state::Role::IoWorker);
                    // Poison the core if this loop unwinds past the
                    // per-request containment in serve_sqe, so harvesters
                    // fail typed instead of hanging on stranded counters.
                    let guard = port.poison_guard();
                    while let Ok(sqe) = port.pop() {
                        let (status, aligned) =
                            super::engine_core::serve_sqe(backend.as_ref(), &policy, &sqe);
                        match status {
                            Ok(bytes) => {
                                if sqe.mode == IoMode::Direct {
                                    backend.charge_multi_dev(dev, 1, aligned);
                                }
                                port.complete(sqe.user_data, bytes);
                            }
                            Err(e) => port.complete_err(sqe.user_data, e),
                        }
                    }
                    drop(guard);
                    crate::metrics::state::deregister();
                })
            })
            .collect();
        PreadPool { core, workers }
    }
}

impl AsyncIoEngine for PreadPool {
    fn submit(&self, sqe: Sqe) {
        self.core.submit(sqe)
    }

    fn submit_batch(&self, sqes: Vec<Sqe>) {
        self.core.submit_batch(sqes)
    }

    fn wait_cqe(&self) -> Cqe {
        self.core.wait_cqe()
    }

    fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        self.core.wait_cqes(n)
    }

    fn peek_cqe(&self) -> Option<Cqe> {
        self.core.peek_cqe()
    }

    fn inflight(&self) -> u64 {
        self.core.inflight()
    }

    fn pending_harvest(&self) -> u64 {
        self.core.pending_harvest()
    }

    fn drain(&self) {
        self.core.drain()
    }

    fn queue_highwater(&self) -> Vec<u64> {
        self.core.queue_highwater()
    }
}

impl Drop for PreadPool {
    fn drop(&mut self) {
        self.core.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::membuf::{SlotRef, StagingArena};
    use crate::storage::backing::{FileBacking, MemBacking};
    use crate::storage::page_cache::{DataKind, FileId};

    fn mem_file(n: u32) -> SimFile {
        let bytes: Vec<u8> = (0..n).map(|i| (i % 239) as u8).collect();
        SimFile::new(FileId::new(5, DataKind::Features), Arc::new(MemBacking::new(bytes)))
    }

    #[test]
    fn direct_reads_align_and_count() {
        let be = OsFileBackend::new(512);
        let f = mem_file(64 * 1024);
        let mut buf = vec![0u8; 100];
        IoBackend::read_direct(&be, &f, 700, &mut buf);
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b, ((700 + i) % 239) as u8);
        }
        assert_eq!(be.direct_stats.aligned_bytes.load(Ordering::Relaxed), 512);
        assert_eq!(be.direct_stats.useful_bytes.load(Ordering::Relaxed), 100);
        assert_eq!(be.counters.reads.load(Ordering::Relaxed), 1);
        assert_eq!(be.counters.read_bytes.load(Ordering::Relaxed), 512);
        be.reset_io_stats();
        assert_eq!(be.counters.read_bytes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pread_pool_completes_real_file_reads() {
        // A real on-disk file through the full async path.
        let dir = std::env::temp_dir().join("gnndrive_osfile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pool.bin");
        std::fs::write(&path, (0..8192u32).map(|i| (i % 251) as u8).collect::<Vec<u8>>())
            .unwrap();
        let file = SimFile::new(
            FileId::new(7, DataKind::Features),
            Arc::new(FileBacking::open(&path).unwrap()),
        );
        let be: Arc<dyn IoBackend> = Arc::new(OsFileBackend::new(512));
        let pool = PreadPool::new(be.clone(), 16, 4);
        let arena = StagingArena::new(1, 8 * 512);
        let dst = SlotRef::new(arena, 0);
        let sqes: Vec<Sqe> = (0..8u64)
            .map(|i| Sqe {
                file: file.clone(),
                offset: i * 512,
                len: 512,
                useful: 512,
                dst: dst.clone(),
                dst_off: (i * 512) as usize,
                user_data: i,
                mode: IoMode::Direct,
            })
            .collect();
        pool.submit_batch(sqes);
        let cqes = pool.wait_cqes(8);
        assert_eq!(cqes.len(), 8);
        assert_eq!(pool.inflight(), 0);
        assert_eq!(pool.pending_harvest(), 0);
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, (i % 251) as u8, "byte {i}");
        }
        assert_eq!(be.io_counters().reads.load(Ordering::Relaxed), 8);
        assert_eq!(be.io_counters().read_bytes.load(Ordering::Relaxed), 8 * 512);
    }

    #[test]
    fn segment_request_is_one_pread_and_one_charge() {
        // A coalesced 6-row segment over a real file: one request, one
        // charged op of the aligned span, useful bytes = only the rows.
        let dir = std::env::temp_dir().join("gnndrive_osfile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("seg_{}.bin", std::process::id()));
        std::fs::write(&path, (0..16384u32).map(|i| (i % 251) as u8).collect::<Vec<u8>>())
            .unwrap();
        let file = SimFile::new(
            FileId::new(8, DataKind::Features),
            Arc::new(FileBacking::open(&path).unwrap()),
        );
        let be: Arc<dyn IoBackend> = Arc::new(OsFileBackend::new(512));
        let pool = PreadPool::new(be.clone(), 4, 2);
        let arena = StagingArena::new(1, 3072);
        pool.submit(Sqe {
            file,
            offset: 1024,
            len: 3072, // rows at [1024,1536) and [3584,4096) plus the gap
            useful: 1024,
            dst: SlotRef::new(arena.clone(), 0),
            dst_off: 0,
            user_data: 3,
            mode: IoMode::Direct,
        });
        let cqe = pool.wait_cqe();
        assert_eq!(cqe.user_data, 3);
        let dst = SlotRef::new(arena, 0);
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, ((1024 + i) % 251) as u8, "byte {i}");
        }
        assert_eq!(be.io_counters().reads.load(Ordering::Relaxed), 1);
        assert_eq!(be.io_counters().read_bytes.load(Ordering::Relaxed), 3072);
        assert_eq!(be.direct_stats().useful_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(be.direct_stats().aligned_bytes.load(Ordering::Relaxed), 3072);
    }

    #[test]
    fn backend_factory_builds_pool_engine() {
        let be = Arc::new(OsFileBackend::new(512));
        let engine = be.clone().async_engine(8);
        let f = mem_file(4096);
        let arena = StagingArena::new(1, 1024);
        engine.submit(Sqe {
            file: f,
            offset: 100,
            len: 1024,
            useful: 1024,
            dst: SlotRef::new(arena, 0),
            dst_off: 0,
            user_data: 42,
            mode: IoMode::Direct,
        });
        let cqe = engine.wait_cqe();
        assert_eq!(cqe.user_data, 42);
        assert_eq!(cqe.bytes, 1024);
        assert_eq!(engine.inflight(), 0);
    }
}
