//! io_uring-style asynchronous I/O engine (Appendix A of the paper).
//!
//! A [`Uring`] pairs a submission queue (SQ) with a completion queue (CQ).
//! The submitting thread never blocks per request: it pushes SQEs (blocking
//! only if the ring is full — backpressure, like a full SQ), and later
//! harvests CQEs. "Kernel" service workers pull SQEs, perform the backend
//! read (on the sim backend: sleeping out the service time, so concurrency
//! up to the ring depth overlaps request latencies) and write the real bytes
//! straight into the destination staging slot — no per-row mutex anywhere on
//! the completion path. This is the substrate of GNNDrive's asynchronous
//! feature extraction: one extractor thread drives hundreds of in-flight
//! loads with no per-request context switch on its own thread.
//!
//! The ring is generic over [`IoBackend`]: it implements [`AsyncIoEngine`]
//! and the sim backend mints it from [`IoBackend::async_engine`]. (The
//! OS-file backend uses its own `pread` thread pool instead — see
//! [`super::osfile::PreadPool`].)
//!
//! Service workers are capped (default 32 per ring) — enough to saturate the
//! device model's IOPS/queue-depth ceilings, above which extra in-flight
//! requests only queue at the device, exactly as with a real drive.

use super::api::{AsyncIoEngine, IoBackend};
pub use super::api::{Cqe, IoMode, Sqe};
use crate::sim::queue::BoundedQueue;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct Uring {
    sq: Arc<BoundedQueue<Sqe>>,
    cq: Arc<BoundedQueue<Cqe>>,
    inflight: Arc<AtomicU64>,
    submitted: AtomicU64,
    harvested: AtomicU64,
    workers: Vec<JoinHandle<()>>,
}

impl Uring {
    /// `depth` is the ring size (max outstanding requests).
    pub fn new(backend: Arc<dyn IoBackend>, depth: usize) -> Self {
        let depth = depth.max(1);
        let sq = Arc::new(BoundedQueue::<Sqe>::new(depth));
        // The CQ is effectively unbounded: callers may legally submit an
        // entire mini-batch before harvesting a single completion
        // (Algorithm 1 does exactly that), so a bounded CQ would deadlock —
        // workers blocking on a full CQ stop draining the SQ, and the
        // submitter blocks on the full SQ. CQEs are small; memory is fine.
        let cq = Arc::new(BoundedQueue::<Cqe>::new(usize::MAX / 2));
        let inflight = Arc::new(AtomicU64::new(0));
        let worker_count = depth.min(32);
        // Workers drain the SQ in small chunks and charge the device once
        // per chunk (charge_multi): sustained IOPS/bandwidth are identical
        // to per-op charging, but single-core thread-coordination overhead
        // per request drops ~chunk-fold, keeping the simulation's critical
        // path honest on this 1-CPU testbed (see DESIGN.md §Perf).
        let chunk = depth.clamp(1, 8);
        let workers = (0..worker_count)
            .map(|_| {
                let sq = sq.clone();
                let cq = cq.clone();
                let backend = backend.clone();
                let inflight = inflight.clone();
                std::thread::spawn(move || {
                    crate::metrics::state::register(crate::metrics::state::Role::IoWorker);
                    while let Ok(sqes) = sq.pop_many(chunk) {
                        // Phase 1: copy data + per-request accounting,
                        // reading straight into each request's staging-slot
                        // range (this worker owns the range until the CQE
                        // is published — see the SlotRef protocol).
                        let mut direct_ops = 0u64;
                        let mut direct_bytes = 0usize;
                        for sqe in &sqes {
                            let dst = unsafe { sqe.dst.slice_mut(sqe.dst_off, sqe.len) };
                            match sqe.mode {
                                IoMode::Direct => {
                                    direct_ops += 1;
                                    direct_bytes +=
                                        backend.read_direct_nocharge(&sqe.file, sqe.offset, dst);
                                }
                                IoMode::Buffered => {
                                    // Page-cache semantics are per-request;
                                    // charge inline (no coalescing).
                                    backend.read_buffered(&sqe.file, sqe.offset, dst);
                                }
                            }
                        }
                        // Phase 2: one coalesced device charge for the
                        // chunk's direct requests.
                        backend.charge_multi(direct_ops, direct_bytes);
                        // Phase 3: publish completions.
                        for sqe in &sqes {
                            inflight.fetch_sub(1, Ordering::Relaxed);
                            // CQ is unbounded; push never blocks (see new()).
                            let _ = cq.push(Cqe { user_data: sqe.user_data, bytes: sqe.len });
                        }
                    }
                    crate::metrics::state::deregister();
                })
            })
            .collect();
        Uring {
            sq,
            cq,
            inflight,
            submitted: AtomicU64::new(0),
            harvested: AtomicU64::new(0),
            workers,
        }
    }

    /// Submit one request. Blocks only if the SQ is full (ring backpressure);
    /// the I/O itself proceeds asynchronously.
    ///
    /// Counters are incremented *before* the push (`submitted` first, see
    /// `pending_harvest`) so a worker that completes the request
    /// immediately never observes `inflight` below its own decrement. If
    /// the push fails (ring closed) the increments are unwound before
    /// panicking so the counters stay balanced for any drop-order observer.
    pub fn submit(&self, sqe: Sqe) {
        self.submitted.fetch_add(1, Ordering::SeqCst);
        self.inflight.fetch_add(1, Ordering::SeqCst);
        if self.sq.push(sqe).is_err() {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.submitted.fetch_sub(1, Ordering::SeqCst);
            panic!("uring closed");
        }
    }

    /// Submit a batch of requests with amortized locking/wakeups.
    ///
    /// On a mid-batch closure only the enqueued prefix keeps its counter
    /// increments (those requests will still be serviced and drained); the
    /// rejected remainder's increments are unwound — the pre-fix code
    /// leaked the whole batch into `inflight`/`submitted` whenever
    /// `push_all` failed on a closed queue.
    pub fn submit_batch(&self, sqes: Vec<Sqe>) {
        let n = sqes.len() as u64;
        self.submitted.fetch_add(n, Ordering::SeqCst);
        self.inflight.fetch_add(n, Ordering::SeqCst);
        if let Err(partial) = self.sq.push_all(sqes) {
            let rejected = n - partial.pushed as u64;
            self.inflight.fetch_sub(rejected, Ordering::SeqCst);
            self.submitted.fetch_sub(rejected, Ordering::SeqCst);
            panic!("uring closed");
        }
    }

    /// Harvest one completion, blocking until available.
    pub fn wait_cqe(&self) -> Cqe {
        let cqe = self.cq.pop().expect("uring closed");
        self.harvested.fetch_add(1, Ordering::Relaxed);
        cqe
    }

    /// Harvest exactly `n` completions, blocking as needed; wakeups are
    /// amortized across bursts of ready CQEs.
    pub fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let got = self.cq.pop_many(n - out.len()).expect("uring closed");
            self.harvested.fetch_add(got.len() as u64, Ordering::Relaxed);
            out.extend(got);
        }
        out
    }

    /// Harvest a completion if one is ready.
    pub fn peek_cqe(&self) -> Option<Cqe> {
        let cqe = self.cq.try_pop();
        if cqe.is_some() {
            self.harvested.fetch_add(1, Ordering::Relaxed);
        }
        cqe
    }

    /// Outstanding requests (submitted − completed).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Completions not yet harvested by the caller.
    ///
    /// The three counters cannot be read in one shot, so the load *order*
    /// is what keeps the difference non-negative: `harvested` and
    /// `inflight` are read first and `submitted` last. Whatever races in
    /// between can only grow `submitted` relative to the two snapshots
    /// (`submitted` is incremented before `inflight` on submit, and
    /// `inflight` is decremented before `harvested` is incremented on the
    /// completion path), so the subtraction never wraps — the pre-fix code
    /// read `submitted` first and could transiently report ~u64::MAX. The
    /// `saturating_sub` is a belt-and-braces floor, not the fix.
    pub fn pending_harvest(&self) -> u64 {
        let harvested = self.harvested.load(Ordering::SeqCst);
        let inflight = self.inflight.load(Ordering::SeqCst);
        let submitted = self.submitted.load(Ordering::SeqCst);
        submitted.saturating_sub(harvested + inflight)
    }
}

impl AsyncIoEngine for Uring {
    fn submit(&self, sqe: Sqe) {
        Uring::submit(self, sqe)
    }

    fn submit_batch(&self, sqes: Vec<Sqe>) {
        Uring::submit_batch(self, sqes)
    }

    fn wait_cqe(&self) -> Cqe {
        Uring::wait_cqe(self)
    }

    fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        Uring::wait_cqes(self, n)
    }

    fn peek_cqe(&self) -> Option<Cqe> {
        Uring::peek_cqe(self)
    }

    fn inflight(&self) -> u64 {
        Uring::inflight(self)
    }

    fn pending_harvest(&self) -> u64 {
        Uring::pending_harvest(self)
    }
}

impl Drop for Uring {
    fn drop(&mut self) {
        self.sq.close();
        self.cq.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::membuf::{SlotRef, StagingArena};
    use crate::sim::Clock;
    use crate::storage::backing::MemBacking;
    use crate::storage::engine::{SimFile, Storage};
    use crate::storage::mem::HostMemory;
    use crate::storage::page_cache::{DataKind, FileId, PageCache};
    use crate::storage::ssd::{SsdConfig, SsdSim};
    use std::time::Instant;

    fn setup() -> (Storage, SimFile) {
        let clock = Clock::new(0.2);
        let ssd = SsdSim::new(SsdConfig::pm883(), clock);
        let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
        let storage = Storage::new(ssd, cache);
        let bytes: Vec<u8> = (0..1u32 << 20).map(|i| (i % 241) as u8).collect();
        let file = SimFile::new(
            FileId::new(9, DataKind::Features),
            Arc::new(MemBacking::new(bytes)),
        );
        (storage, file)
    }

    #[test]
    fn completions_carry_real_bytes() {
        let (storage, file) = setup();
        let ring = Uring::new(Arc::new(storage), 16);
        let arena = StagingArena::new(1, 4 * 512);
        let dst = SlotRef::new(arena, 0);
        for i in 0..4u64 {
            ring.submit(Sqe {
                file: file.clone(),
                offset: i * 512,
                len: 512,
                dst: dst.clone(),
                dst_off: (i * 512) as usize,
                user_data: i,
                mode: IoMode::Direct,
            });
        }
        let mut seen = Vec::new();
        for _ in 0..4 {
            seen.push(ring.wait_cqe().user_data);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(ring.inflight(), 0);
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, (i % 241) as u8, "byte {i}");
        }
    }

    #[test]
    fn async_depth_beats_sync_single_thread() {
        let (storage, file) = setup();
        let n = 256usize;

        // Sync: one thread, one request at a time.
        let t0 = Instant::now();
        let mut buf = vec![0u8; 512];
        for i in 0..n {
            storage.read_direct(&file, (i * 512) as u64, &mut buf);
        }
        let sync_time = t0.elapsed();

        // Async: same requests through a depth-32 ring, batch APIs (as the
        // extractor uses them).
        let ring = Uring::new(Arc::new(storage.clone()), 32);
        let arena = StagingArena::new(1, n * 512);
        let dst = SlotRef::new(arena, 0);
        let t0 = Instant::now();
        let sqes: Vec<Sqe> = (0..n)
            .map(|i| Sqe {
                file: file.clone(),
                offset: (i * 512) as u64,
                len: 512,
                dst: dst.clone(),
                dst_off: i * 512,
                user_data: i as u64,
                mode: IoMode::Direct,
            })
            .collect();
        ring.submit_batch(sqes);
        let cqes = ring.wait_cqes(n);
        let async_time = t0.elapsed();
        assert_eq!(cqes.len(), n);

        assert!(
            async_time.as_secs_f64() < sync_time.as_secs_f64() * 0.55,
            "async {async_time:?} not ≪ sync {sync_time:?}"
        );
    }

    #[test]
    fn pending_harvest_never_underflows_under_concurrency() {
        // Regression: the old implementation read `submitted` first and
        // subtracted `harvested`/`inflight` snapshots taken later, so a
        // submit landing between the loads made `submitted − harvested −
        // inflight` wrap to ~u64::MAX. Hammer submits/harvests while a
        // monitor thread samples the counter continuously.
        let (storage, file) = setup();
        let ring = Arc::new(Uring::new(Arc::new(storage), 8));
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        const N: u64 = 400;
        // Slot i % SLOTS is in flight at most once at a time: in-flight is
        // bounded by SQ depth (8) + workers × chunk (8 × 8) ≪ SLOTS.
        const SLOTS: usize = 128;

        let monitor = {
            let ring = ring.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut max_seen = 0u64;
                while !done.load(Ordering::SeqCst) {
                    let p = ring.pending_harvest();
                    assert!(
                        p <= 2 * N,
                        "pending_harvest wrapped/overshot: {p}"
                    );
                    max_seen = max_seen.max(p);
                    std::thread::yield_now();
                }
                max_seen
            })
        };

        let submitter = {
            let ring = ring.clone();
            let file = file.clone();
            std::thread::spawn(move || {
                let arena = StagingArena::new(SLOTS, 512);
                for i in 0..N {
                    ring.submit(Sqe {
                        file: file.clone(),
                        offset: (i % 64) * 512,
                        len: 512,
                        dst: SlotRef::new(arena.clone(), i as usize % SLOTS),
                        dst_off: 0,
                        user_data: i,
                        mode: IoMode::Direct,
                    });
                }
            })
        };

        let mut harvested = 0u64;
        while harvested < N {
            ring.wait_cqe();
            harvested += 1;
            // Interleave reads from the harvester side too.
            assert!(ring.pending_harvest() <= 2 * N);
        }
        submitter.join().unwrap();
        done.store(true, Ordering::SeqCst);
        monitor.join().unwrap();
        assert_eq!(ring.pending_harvest(), 0);
        assert_eq!(ring.inflight(), 0);
    }

    #[test]
    fn submit_batch_counters_unwind_on_closed_ring() {
        // Closing the ring (worker shutdown) while a batch submit races
        // must not leak `inflight`/`submitted` for the rejected items.
        let (storage, file) = setup();
        let ring = Uring::new(Arc::new(storage), 4);
        // Drop-close the inner queues by closing them directly via Drop is
        // not observable from outside, so exercise the path with a
        // pre-closed SQ: harvest everything, close, then submit.
        ring.sq.close();
        let arena = StagingArena::new(3, 512);
        let sqes: Vec<Sqe> = (0..3u64)
            .map(|i| Sqe {
                file: file.clone(),
                offset: i * 512,
                len: 512,
                dst: SlotRef::new(arena.clone(), i as usize),
                dst_off: 0,
                user_data: i,
                mode: IoMode::Direct,
            })
            .collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ring.submit_batch(sqes);
        }));
        assert!(result.is_err(), "submitting on a closed ring panics");
        assert_eq!(ring.inflight(), 0, "inflight leaked on failed batch submit");
        assert_eq!(ring.pending_harvest(), 0, "pending_harvest leaked");
        assert_eq!(ring.submitted.load(Ordering::SeqCst), 0, "submitted leaked");
    }

    #[test]
    fn buffered_mode_populates_cache() {
        let (storage, file) = setup();
        let ring = Uring::new(Arc::new(storage.clone()), 8);
        let arena = StagingArena::new(1, 4096);
        ring.submit(Sqe {
            file: file.clone(),
            offset: 0,
            len: 4096,
            dst: SlotRef::new(arena, 0),
            dst_off: 0,
            user_data: 0,
            mode: IoMode::Buffered,
        });
        ring.wait_cqe();
        assert!(storage.cache.resident_bytes() >= 4096);
    }
}
