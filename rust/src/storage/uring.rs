//! io_uring-style asynchronous I/O engine (Appendix A of the paper).
//!
//! A [`Uring`] pairs a submission queue (SQ) with a completion queue (CQ).
//! The submitting thread never blocks per request: it pushes SQEs (blocking
//! only if the ring is full — backpressure, like a full SQ), and later
//! harvests CQEs. "Kernel" service workers pull SQEs, perform the backend
//! read (on the sim backend: sleeping out the service time, so concurrency
//! up to the ring depth overlaps request latencies) and write the real bytes
//! straight into the destination staging range — no per-row mutex anywhere
//! on the completion path. This is the substrate of GNNDrive's asynchronous
//! feature extraction: one extractor thread drives hundreds of in-flight
//! loads with no per-request context switch on its own thread.
//!
//! An SQE may be a coalesced *segment* (several feature rows merged into one
//! contiguous span by the extractor's planner): the worker serves it as one
//! device read via [`IoBackend::read_direct_segment_nocharge`], so a merged
//! run of rows costs one IOPS charge and one aligned span instead of per-row
//! sector redundancy. The row table stays with the submitter; the ring only
//! ever sees contiguous reads.
//!
//! The ring is generic over [`IoBackend`]: it implements [`AsyncIoEngine`]
//! and the sim backend mints it from [`IoBackend::async_engine`]. (The
//! OS-file backend uses its own `pread` thread pool instead — see
//! [`super::osfile::PreadPool`].) The SQ/CQ + counter discipline both
//! engines share lives in [`super::engine_core::EngineCore`].
//!
//! Service workers are capped (default 32 per ring) — enough to saturate the
//! device model's IOPS/queue-depth ceilings, above which extra in-flight
//! requests only queue at the device, exactly as with a real drive.

use super::api::{AsyncIoEngine, IoBackend};
pub use super::api::{Cqe, IoMode, Sqe};
use super::engine_core::EngineCore;
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct Uring {
    core: EngineCore,
    workers: Vec<JoinHandle<()>>,
}

impl Uring {
    /// `depth` is the ring size per stripe device (max outstanding requests
    /// on each device's sub-queue).
    pub fn new(backend: Arc<dyn IoBackend>, depth: usize) -> Self {
        let depth = depth.max(1);
        let spec = backend.stripe();
        let core = EngineCore::new_striped("uring", depth, spec);
        let devices = core.device_count();
        // At least one worker per stripe device (workers bind to one
        // device's sub-queue), capped as before so a deep ring doesn't
        // spawn useless threads.
        let worker_count = depth.min(32).max(devices);
        // Workers drain their SQ in small chunks and charge the device once
        // per chunk (charge_multi_dev): sustained IOPS/bandwidth are
        // identical to per-op charging, but single-core thread-coordination
        // overhead per request drops ~chunk-fold, keeping the simulation's
        // critical path honest on this 1-CPU testbed (see DESIGN.md §Perf).
        let chunk = depth.clamp(1, 8);
        let policy = backend.retry_policy();
        let workers = (0..worker_count)
            .map(|w| {
                // Round-robin worker→device binding: every chunk a worker
                // pops is same-device, so its coalesced charge can debit
                // that one device's budget.
                let dev = w % devices;
                let port = core.worker_port(dev);
                let backend = backend.clone();
                std::thread::spawn(move || {
                    crate::metrics::state::register(crate::metrics::state::Role::IoWorker);
                    // If this loop itself unwinds (a panic the per-request
                    // guard in serve_sqe did not contain), poison the core
                    // so harvesters fail typed instead of hanging.
                    let guard = port.poison_guard();
                    while let Ok(sqes) = port.pop_many(chunk) {
                        // Phase 1: serve each request (retry policy + panic
                        // containment live in serve_sqe), reading straight
                        // into each request's staging range (this worker
                        // owns the range until the CQE is published — see
                        // the SlotRef protocol).
                        let mut direct_ops = 0u64;
                        let mut direct_bytes = 0usize;
                        let mut statuses = Vec::with_capacity(sqes.len());
                        for sqe in &sqes {
                            let (status, aligned) =
                                super::engine_core::serve_sqe(backend.as_ref(), &policy, sqe);
                            if status.is_ok() && sqe.mode == IoMode::Direct {
                                direct_ops += 1;
                                direct_bytes += aligned;
                            }
                            statuses.push(status);
                        }
                        // Phase 2: one coalesced charge against this
                        // worker's device for the chunk's successful direct
                        // requests (one op per segment; failed attempts
                        // were charged by the backend that failed them).
                        backend.charge_multi_dev(dev, direct_ops, direct_bytes);
                        // Phase 3: publish completions — errors drain the
                        // counters exactly like successes.
                        for (sqe, status) in sqes.iter().zip(statuses) {
                            match status {
                                Ok(bytes) => port.complete(sqe.user_data, bytes),
                                Err(e) => port.complete_err(sqe.user_data, e),
                            }
                        }
                    }
                    drop(guard);
                    crate::metrics::state::deregister();
                })
            })
            .collect();
        Uring { core, workers }
    }
}

impl AsyncIoEngine for Uring {
    fn submit(&self, sqe: Sqe) {
        self.core.submit(sqe)
    }

    fn submit_batch(&self, sqes: Vec<Sqe>) {
        self.core.submit_batch(sqes)
    }

    fn wait_cqe(&self) -> Cqe {
        self.core.wait_cqe()
    }

    fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        self.core.wait_cqes(n)
    }

    fn peek_cqe(&self) -> Option<Cqe> {
        self.core.peek_cqe()
    }

    fn inflight(&self) -> u64 {
        self.core.inflight()
    }

    fn pending_harvest(&self) -> u64 {
        self.core.pending_harvest()
    }

    fn drain(&self) {
        self.core.drain()
    }

    fn queue_highwater(&self) -> Vec<u64> {
        self.core.queue_highwater()
    }
}

impl Drop for Uring {
    fn drop(&mut self) {
        self.core.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::membuf::{SlotRef, StagingArena};
    use crate::sim::Clock;
    use crate::storage::backing::MemBacking;
    use crate::storage::engine::{SimFile, Storage};
    use crate::storage::mem::HostMemory;
    use crate::storage::page_cache::{DataKind, FileId, PageCache};
    use crate::storage::ssd::{SsdConfig, SsdSim};
    use std::sync::atomic::Ordering;
    use std::time::Instant;

    fn setup() -> (Storage, SimFile) {
        let clock = Clock::new(0.2);
        let ssd = SsdSim::new(SsdConfig::pm883(), clock);
        let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
        let storage = Storage::new(ssd, cache);
        let bytes: Vec<u8> = (0..1u32 << 20).map(|i| (i % 241) as u8).collect();
        let file = SimFile::new(
            FileId::new(9, DataKind::Features),
            Arc::new(MemBacking::new(bytes)),
        );
        (storage, file)
    }

    fn row_sqe(file: &SimFile, dst: SlotRef, i: u64) -> Sqe {
        Sqe {
            file: file.clone(),
            offset: i * 512,
            len: 512,
            useful: 512,
            dst,
            dst_off: (i * 512) as usize,
            user_data: i,
            mode: IoMode::Direct,
        }
    }

    #[test]
    fn completions_carry_real_bytes() {
        let (storage, file) = setup();
        let ring = Uring::new(Arc::new(storage), 16);
        let arena = StagingArena::new(1, 4 * 512);
        let dst = SlotRef::new(arena, 0);
        for i in 0..4u64 {
            ring.submit(row_sqe(&file, dst.clone(), i));
        }
        let mut seen = Vec::new();
        for _ in 0..4 {
            seen.push(ring.wait_cqe().user_data);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(ring.inflight(), 0);
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, (i % 241) as u8, "byte {i}");
        }
    }

    #[test]
    fn segment_sqe_reads_span_and_charges_once() {
        // One multi-row segment: a single SQE covering 4 rows charges one
        // request of the merged span, with useful < aligned accounting.
        let (storage, file) = setup();
        let ring = Uring::new(Arc::new(storage.clone()), 8);
        let arena = StagingArena::new(1, 4096);
        let dst = SlotRef::new(arena, 0);
        storage.ssd.reset_stats();
        ring.submit(Sqe {
            file: file.clone(),
            offset: 256, // unaligned start: span [0, 4608) once sector-aligned
            len: 4096,
            useful: 2048, // pretend only half the span is requested rows
            dst: dst.clone(),
            dst_off: 0,
            user_data: 7,
            mode: IoMode::Direct,
        });
        let cqe = ring.wait_cqe();
        assert_eq!(cqe.user_data, 7);
        assert_eq!(cqe.bytes, 4096);
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, ((256 + i) % 241) as u8, "byte {i}");
        }
        assert_eq!(storage.ssd.counters().reads.load(Ordering::Relaxed), 1);
        assert_eq!(
            storage.ssd.counters().read_bytes.load(Ordering::Relaxed),
            4608, // [0, 4608): 256+4096 rounded out to 512
        );
        assert_eq!(storage.direct_stats().useful_bytes.load(Ordering::Relaxed), 2048);
        assert_eq!(storage.direct_stats().aligned_bytes.load(Ordering::Relaxed), 4608);
    }

    #[test]
    fn async_depth_beats_sync_single_thread() {
        let (storage, file) = setup();
        let n = 256usize;

        // Sync: one thread, one request at a time.
        let t0 = Instant::now();
        let mut buf = vec![0u8; 512];
        for i in 0..n {
            storage.read_direct(&file, (i * 512) as u64, &mut buf);
        }
        let sync_time = t0.elapsed();

        // Async: same requests through a depth-32 ring, batch APIs (as the
        // extractor uses them).
        let ring = Uring::new(Arc::new(storage.clone()), 32);
        let arena = StagingArena::new(1, n * 512);
        let dst = SlotRef::new(arena, 0);
        let t0 = Instant::now();
        let sqes: Vec<Sqe> = (0..n).map(|i| row_sqe(&file, dst.clone(), i as u64)).collect();
        ring.submit_batch(sqes);
        let cqes = ring.wait_cqes(n);
        let async_time = t0.elapsed();
        assert_eq!(cqes.len(), n);

        assert!(
            async_time.as_secs_f64() < sync_time.as_secs_f64() * 0.55,
            "async {async_time:?} not ≪ sync {sync_time:?}"
        );
    }

    #[test]
    fn pending_harvest_never_underflows_under_concurrency() {
        // Regression: an old implementation read `submitted` first and
        // subtracted `harvested`/`inflight` snapshots taken later, so a
        // submit landing between the loads made `submitted − harvested −
        // inflight` wrap to ~u64::MAX. Hammer submits/harvests while a
        // monitor thread samples the counter continuously.
        let (storage, file) = setup();
        let ring = Arc::new(Uring::new(Arc::new(storage), 8));
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        const N: u64 = 400;
        // Slot i % SLOTS is in flight at most once at a time: in-flight is
        // bounded by SQ depth (8) + workers × chunk (8 × 8) ≪ SLOTS.
        const SLOTS: usize = 128;

        let monitor = {
            let ring = ring.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut max_seen = 0u64;
                while !done.load(Ordering::SeqCst) {
                    let p = ring.pending_harvest();
                    assert!(p <= 2 * N, "pending_harvest wrapped/overshot: {p}");
                    max_seen = max_seen.max(p);
                    std::thread::yield_now();
                }
                max_seen
            })
        };

        let submitter = {
            let ring = ring.clone();
            let file = file.clone();
            std::thread::spawn(move || {
                let arena = StagingArena::new(SLOTS, 512);
                for i in 0..N {
                    ring.submit(Sqe {
                        file: file.clone(),
                        offset: (i % 64) * 512,
                        len: 512,
                        useful: 512,
                        dst: SlotRef::new(arena.clone(), i as usize % SLOTS),
                        dst_off: 0,
                        user_data: i,
                        mode: IoMode::Direct,
                    });
                }
            })
        };

        let mut harvested = 0u64;
        while harvested < N {
            ring.wait_cqe();
            harvested += 1;
            // Interleave reads from the harvester side too.
            assert!(ring.pending_harvest() <= 2 * N);
        }
        submitter.join().unwrap();
        done.store(true, Ordering::SeqCst);
        monitor.join().unwrap();
        assert_eq!(ring.pending_harvest(), 0);
        assert_eq!(ring.inflight(), 0);
    }

    #[test]
    fn submit_batch_counters_unwind_on_closed_ring() {
        // Closing the ring (worker shutdown) while a batch submit races
        // must not leak `inflight`/`submitted` for the rejected items.
        let (storage, file) = setup();
        let ring = Uring::new(Arc::new(storage), 4);
        // Exercise the path with a pre-closed SQ: close, then submit.
        ring.core.close_submission();
        let arena = StagingArena::new(3, 512);
        let sqes: Vec<Sqe> = (0..3u64)
            .map(|i| Sqe {
                file: file.clone(),
                offset: i * 512,
                len: 512,
                useful: 512,
                dst: SlotRef::new(arena.clone(), i as usize),
                dst_off: 0,
                user_data: i,
                mode: IoMode::Direct,
            })
            .collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ring.submit_batch(sqes);
        }));
        assert!(result.is_err(), "submitting on a closed ring panics");
        assert_eq!(ring.inflight(), 0, "inflight leaked on failed batch submit");
        assert_eq!(ring.pending_harvest(), 0, "pending_harvest leaked");
        assert_eq!(ring.core.submitted.load(Ordering::SeqCst), 0, "submitted leaked");
    }

    #[test]
    fn striped_ring_routes_charges_and_tracks_highwater() {
        // 3-device striped backend, 4 KiB chunks: 512 B rows at i*512 land
        // on device (i*512 / 4096) % 3 and must charge exactly that device.
        let clock = Clock::new(0.2);
        let ssds: Vec<SsdSim> =
            (0..3).map(|_| SsdSim::new(SsdConfig::pm883(), clock.clone())).collect();
        let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
        let storage = Storage::new_striped(ssds, cache, 4096);
        let bytes: Vec<u8> = (0..1u32 << 20).map(|i| (i % 241) as u8).collect();
        let file = SimFile::new(
            FileId::new(9, DataKind::Features),
            Arc::new(MemBacking::new(bytes)),
        );
        let ring = Uring::new(Arc::new(storage.clone()), 16);
        // 24 rows = 3 full chunks (8 rows each), one per device.
        let n = 24usize;
        let arena = StagingArena::new(1, n * 512);
        let dst = SlotRef::new(arena, 0);
        let sqes: Vec<Sqe> = (0..n).map(|i| row_sqe(&file, dst.clone(), i as u64)).collect();
        ring.submit_batch(sqes);
        let cqes = ring.wait_cqes(n);
        assert!(cqes.iter().all(|c| c.is_ok()));
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, (i % 241) as u8, "byte {i}");
        }
        // Each device served its 8 rows; the aggregate surface sums them.
        for d in 0..3 {
            assert_eq!(
                storage.device(d).counters().reads.load(Ordering::Relaxed),
                8,
                "device {d} request count"
            );
            assert_eq!(
                storage.device(d).counters().read_bytes.load(Ordering::Relaxed),
                8 * 512,
                "device {d} charged bytes"
            );
        }
        assert_eq!(storage.io_counters().reads.load(Ordering::Relaxed), 24);
        assert_eq!(storage.io_counters().read_bytes.load(Ordering::Relaxed), 24 * 512);
        // Queue-utilization observability: one high-water entry per device,
        // each having seen at least one in-flight request.
        let hw = ring.queue_highwater();
        assert_eq!(hw.len(), 3);
        assert!(hw.iter().all(|&h| h >= 1), "highwater never recorded: {hw:?}");
        assert!(hw.iter().all(|&h| h <= 16), "highwater above depth: {hw:?}");
    }

    #[test]
    fn buffered_mode_populates_cache() {
        let (storage, file) = setup();
        let ring = Uring::new(Arc::new(storage.clone()), 8);
        let arena = StagingArena::new(1, 4096);
        ring.submit(Sqe {
            file: file.clone(),
            offset: 0,
            len: 4096,
            useful: 4096,
            dst: SlotRef::new(arena, 0),
            dst_off: 0,
            user_data: 0,
            mode: IoMode::Buffered,
        });
        ring.wait_cqe();
        assert!(storage.cache.resident_bytes() >= 4096);
    }
}
