//! Deterministic fault injection for the I/O stack.
//!
//! [`FaultInjectBackend`] wraps any [`IoBackend`] and perturbs its *fallible*
//! read paths (`try_read_*`) according to a seeded [`FaultPlan`]: transient
//! errors, permanently bad device ranges, short reads and latency stalls.
//! The infallible read paths delegate untouched — legacy callers with no
//! error channel never see an injected panic; faults only flow where the
//! typed-error contract can carry them.
//!
//! **Determinism.** Every fault decision is a pure function of
//! `(plan.seed, stream, offset, try#)` — no global RNG, no wall clock. The
//! try number is a *cumulative per-offset counter* maintained by the wrapper:
//! an engine retrying a request consumes draws `k, k+1, …`, and a later
//! batch-level re-extract of the same offset continues the sequence rather
//! than replaying it (real transient faults don't replay per submission; a
//! pure `(offset, attempt)` key would make `--on-io-error retry`
//! deterministically useless). Per offset, the verdict sequence is identical
//! across runs with the same seed, so a fixed seed replays the same fault
//! storm across runs and backends.
//!
//! **Charging honesty.** A failed transient/short attempt still moved the
//! device: the wrapper charges the inner backend for the sector-aligned span
//! of every failed direct attempt (and the requested bytes of a failed
//! buffered attempt) before returning the error, so retried I/O shows up in
//! `io_counters` at its true device cost. `DirectIoStats` alignment counters
//! are *not* touched on failure — they record only delivered data (the inner
//! backend records them on the eventually-successful attempt).
//!
//! [`FaultInjectEngine`] is the completion-side counterpart: it wraps any
//! [`AsyncIoEngine`] and flips harvested `Ok` completions to typed errors at
//! a seeded per-`user_data` rate, letting consumer-side degradation paths be
//! tested without touching the backend at all.

use super::api::{
    AsyncIoEngine, BackendKind, Cqe, DirectIoStats, IoBackend, IoError, RetryPolicy, Sqe,
};
use super::engine::SimFile;
use super::osfile::{PreadPool, DEFAULT_POOL_THREADS};
use super::ssd::SsdCounters;
use super::uring::Uring;
use super::uring_os::UringEngine;
use crate::sim::Clock;
use crate::util::rng::hash3;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Independent decision streams so e.g. the stall roll at an offset does not
/// correlate with the transient roll at the same offset.
const STREAM_TRANSIENT: u64 = 0x7261_6e73; // "rans"
const STREAM_SHORT: u64 = 0x7368_6f72; // "shor"
const STREAM_STALL: u64 = 0x7374_616c; // "stal"

/// Seeded description of what goes wrong: the full fault storm is a pure
/// function of this plan.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Root seed of every decision stream.
    pub seed: u64,
    /// Probability a given `(offset, try#)` read fails with
    /// [`IoError::Transient`].
    pub transient_rate: f64,
    /// Probability a given `(offset, try#)` read fails with
    /// [`IoError::ShortRead`].
    pub short_rate: f64,
    /// Probability a given `(offset, try#)` read stalls for `stall_us`
    /// before being served (models device hiccups / GC pauses).
    pub stall_rate: f64,
    /// Stall duration, microseconds of *simulated* time (the wrapper sleeps
    /// through the machine clock, so a scaled sim backend stalls in scaled
    /// real time and an OS backend in plain real time).
    pub stall_us: u64,
    /// Permanently unreadable `(start, len)` byte ranges: any read
    /// overlapping one fails with [`IoError::BadRange`] on every attempt.
    pub bad_ranges: Vec<(u64, u64)>,
    /// Restrict the storm to one member of a stripe set (`--fault-device`):
    /// only reads whose *logical* offset maps to this device are perturbed.
    /// The filter is applied before a try draw is consumed, so off-target
    /// offsets never advance their draw sequence — per-offset replay
    /// determinism is exactly as without the filter. `None` = all devices.
    pub device: Option<usize>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0xFA017,
            transient_rate: 0.0,
            short_rate: 0.0,
            stall_rate: 0.0,
            stall_us: 200,
            bad_ranges: Vec::new(),
            device: None,
        }
    }
}

impl FaultPlan {
    /// Plan with only transient faults at `rate` — the common chaos-test
    /// shape.
    pub fn transient(seed: u64, rate: f64) -> Self {
        FaultPlan { seed, transient_rate: rate, ..FaultPlan::default() }
    }

    /// Whether this plan can perturb anything at all.
    pub fn is_active(&self) -> bool {
        self.transient_rate > 0.0
            || self.short_rate > 0.0
            || self.stall_rate > 0.0
            || !self.bad_ranges.is_empty()
    }

    /// Transient-stream verdict for `(offset, try#)`: would this draw fault?
    /// Public so chaos tests can *select* seeds with known fault/recovery
    /// shapes instead of asserting on probabilities.
    pub fn transient_verdict(&self, offset: u64, try_no: u32) -> bool {
        self.roll(STREAM_TRANSIENT, offset, try_no, self.transient_rate)
    }

    /// Stall-stream verdict for `(offset, try#)`: would this draw sleep?
    /// Public for the same reason as [`FaultPlan::transient_verdict`] —
    /// hedging tests *select* seeds where an original's first service draw
    /// stalls and its hedge's draw does not, instead of hoping.
    pub fn stall_verdict(&self, offset: u64, try_no: u32) -> bool {
        self.roll(STREAM_STALL, offset, try_no, self.stall_rate)
    }

    /// Deterministic Bernoulli roll on `stream` for `(offset, try#)`.
    fn roll(&self, stream: u64, offset: u64, attempt: u32, rate: f64) -> bool {
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        let h = hash3(self.seed ^ stream, offset, attempt as u64);
        // Top 53 bits → uniform f64 in [0, 1).
        ((h >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < rate
    }

    /// The first bad range overlapping `[offset, offset + len)`, if any.
    fn bad_range_hit(&self, offset: u64, len: usize) -> Option<u64> {
        let end = offset.saturating_add(len as u64);
        self.bad_ranges
            .iter()
            .find(|&&(start, rlen)| start < end && offset < start.saturating_add(rlen))
            .map(|&(start, _)| start)
    }
}

/// Fault-injecting [`IoBackend`] wrapper. Stats, counters and charging all
/// delegate to the wrapped backend (there is exactly one accounting surface);
/// only the fallible read paths grow failure modes.
pub struct FaultInjectBackend {
    inner: Arc<dyn IoBackend>,
    kind: BackendKind,
    plan: FaultPlan,
    policy: RetryPolicy,
    clock: Clock,
    /// `--io-workers` for the OS pread pool this wrapper mints.
    io_workers: usize,
    /// Cumulative tries per offset — the roll key. See the module docs:
    /// engine retries and batch-level re-extracts *continue* an offset's
    /// draw sequence instead of replaying it.
    tries: Mutex<HashMap<u64, u32>>,
}

impl FaultInjectBackend {
    /// Wrap `inner` (of CLI kind `kind`, which selects the async-engine
    /// flavor) with `plan`, serving engines the retry `policy`.
    pub fn new(
        inner: Arc<dyn IoBackend>,
        kind: BackendKind,
        plan: FaultPlan,
        policy: RetryPolicy,
        clock: Clock,
    ) -> Self {
        FaultInjectBackend {
            inner,
            kind,
            plan,
            policy,
            clock,
            io_workers: DEFAULT_POOL_THREADS,
            tries: Mutex::new(HashMap::new()),
        }
    }

    /// Thread count for the OS `pread` pool minted by `async_engine`
    /// (`--io-workers` must survive the fault wrapper).
    pub fn with_io_workers(mut self, io_workers: usize) -> Self {
        self.io_workers = io_workers.max(1);
        self
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Whether the plan's `--fault-device` filter lets a read at logical
    /// `offset` be perturbed. Checked *before* any try draw is consumed so
    /// the filter cannot shift other offsets' draw sequences.
    fn device_targeted(&self, offset: u64) -> bool {
        match self.plan.device {
            None => true,
            Some(d) => self.inner.stripe().device_of(offset) == d,
        }
    }

    /// Consume the next draw index for `offset` (0 on first try). Poison-
    /// tolerant: a panicking worker elsewhere must not wedge fault rolls.
    fn next_try(&self, offset: u64) -> u32 {
        let mut m = self.tries.lock().unwrap_or_else(|e| e.into_inner());
        let c = m.entry(offset).or_insert(0);
        let v = *c;
        *c += 1;
        v
    }

    /// Sector-aligned span of a direct request — the device cost of a failed
    /// attempt.
    fn aligned_span(&self, offset: u64, len: usize) -> usize {
        let sector = self.inner.sector() as u64;
        let lo = offset / sector * sector;
        let hi = (offset + len as u64).div_ceil(sector) * sector;
        (hi - lo) as usize
    }

    /// Run the fault plan for a direct read of `[offset, offset+len)`.
    /// `Ok(())` = serve normally; `Err` = inject. Failed attempts that
    /// plausibly moved the device (transient, short) are charged to the
    /// inner backend here. The roll key is the cumulative per-offset try
    /// counter, not the caller's per-submission attempt number.
    fn direct_fault(&self, offset: u64, len: usize) -> Result<(), IoError> {
        if !self.plan.is_active() || !self.device_targeted(offset) {
            return Ok(());
        }
        let try_no = self.next_try(offset);
        if self.plan.roll(STREAM_STALL, offset, try_no, self.plan.stall_rate) {
            self.clock.sleep(Duration::from_micros(self.plan.stall_us));
        }
        if let Some(start) = self.plan.bad_range_hit(offset, len) {
            return Err(IoError::BadRange { offset: start });
        }
        if self.plan.roll(STREAM_TRANSIENT, offset, try_no, self.plan.transient_rate) {
            self.inner.charge_multi(1, self.aligned_span(offset, len));
            return Err(IoError::Transient);
        }
        if self.plan.roll(STREAM_SHORT, offset, try_no, self.plan.short_rate) {
            self.inner.charge_multi(1, self.aligned_span(offset, len));
            let want = len.max(1);
            let got = (hash3(self.plan.seed ^ STREAM_SHORT, offset ^ 1, try_no as u64)
                as usize)
                % want;
            return Err(IoError::ShortRead { got, want });
        }
        Ok(())
    }
}

impl IoBackend for FaultInjectBackend {
    fn name(&self) -> &'static str {
        match self.inner.name() {
            "sim" => "sim+fault",
            "os" => "os+fault",
            "uring" => "uring+fault",
            _ => "fault",
        }
    }

    fn sector(&self) -> usize {
        self.inner.sector()
    }

    fn read_buffered(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        self.inner.read_buffered(file, offset, buf)
    }

    fn read_direct(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        self.inner.read_direct(file, offset, buf)
    }

    fn read_direct_segment_nocharge(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
    ) -> usize {
        self.inner.read_direct_segment_nocharge(file, offset, useful, buf)
    }

    fn try_read_direct_segment(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
        attempt: u32,
    ) -> Result<usize, IoError> {
        self.direct_fault(offset, buf.len())?;
        self.inner.try_read_direct_segment(file, offset, useful, buf, attempt)
    }

    fn try_read_direct(
        &self,
        file: &SimFile,
        offset: u64,
        buf: &mut [u8],
        attempt: u32,
    ) -> Result<(), IoError> {
        self.direct_fault(offset, buf.len())?;
        self.inner.try_read_direct(file, offset, buf, attempt)
    }

    fn try_read_buffered(
        &self,
        file: &SimFile,
        offset: u64,
        buf: &mut [u8],
        attempt: u32,
    ) -> Result<(), IoError> {
        if self.plan.is_active() && self.device_targeted(offset) {
            let try_no = self.next_try(offset);
            if self.plan.roll(STREAM_STALL, offset, try_no, self.plan.stall_rate) {
                self.clock.sleep(Duration::from_micros(self.plan.stall_us));
            }
            if let Some(start) = self.plan.bad_range_hit(offset, buf.len()) {
                return Err(IoError::BadRange { offset: start });
            }
            if self.plan.roll(STREAM_TRANSIENT, offset, try_no, self.plan.transient_rate) {
                self.inner.charge_read(buf.len());
                return Err(IoError::Transient);
            }
        }
        self.inner.try_read_buffered(file, offset, buf, attempt)
    }

    fn retry_policy(&self) -> RetryPolicy {
        self.policy
    }

    fn charge_multi(&self, ops: u64, bytes: usize) {
        self.inner.charge_multi(ops, bytes)
    }

    fn stripe(&self) -> super::backing::StripeSpec {
        self.inner.stripe()
    }

    fn charge_multi_dev(&self, dev: usize, ops: u64, bytes: usize) {
        self.inner.charge_multi_dev(dev, ops, bytes)
    }

    fn device_io_snapshot(&self) -> Vec<(u64, u64)> {
        self.inner.device_io_snapshot()
    }

    fn write_buffered(&self, file: &SimFile, offset: u64, len: usize) {
        self.inner.write_buffered(file, offset, len)
    }

    fn write_direct(&self, file: &SimFile, offset: u64, len: usize) {
        self.inner.write_direct(file, offset, len)
    }

    fn charge_read(&self, len: usize) {
        self.inner.charge_read(len)
    }

    fn charge_write(&self, len: usize) {
        self.inner.charge_write(len)
    }

    fn direct_stats(&self) -> &DirectIoStats {
        self.inner.direct_stats()
    }

    fn io_counters(&self) -> &SsdCounters {
        self.inner.io_counters()
    }

    fn reset_io_stats(&self) {
        self.inner.reset_io_stats()
    }

    fn uring_target(&self, file: &SimFile, offset: u64, len: usize) -> Option<(i32, u64)> {
        // An active plan must see every attempt: kernel-direct reads would
        // bypass the fault rolls entirely, so route everything through the
        // serve_sqe path while faults can fire. Inactive wrappers are
        // transparent.
        if self.plan.is_active() {
            None
        } else {
            self.inner.uring_target(file, offset, len)
        }
    }

    fn async_engine(self: Arc<Self>, depth: usize) -> Box<dyn AsyncIoEngine> {
        // The wrapper itself becomes the engine's backend, so every engine
        // worker read passes through the fault plan and the retry policy the
        // engine captured is `self.policy`.
        match self.kind {
            BackendKind::Sim => Box::new(Uring::new(self, depth)),
            BackendKind::Os => {
                let threads = self.io_workers;
                Box::new(PreadPool::new(self, depth, threads))
            }
            BackendKind::Uring => {
                let threads = self.io_workers;
                Box::new(UringEngine::new(self, depth, threads))
            }
        }
    }
}

/// Completion-side fault injector: wraps any [`AsyncIoEngine`] and converts
/// harvested `Ok` completions into [`IoError::Transient`] errors at a seeded
/// per-`user_data` rate. The underlying I/O really happened (and was
/// charged); only the completion status is perturbed — which is exactly what
/// a consumer-degradation test wants to exercise.
pub struct FaultInjectEngine {
    inner: Box<dyn AsyncIoEngine>,
    seed: u64,
    fail_rate: f64,
}

impl FaultInjectEngine {
    pub fn new(inner: Box<dyn AsyncIoEngine>, seed: u64, fail_rate: f64) -> Self {
        FaultInjectEngine { inner, seed, fail_rate }
    }

    fn perturb(&self, cqe: Cqe) -> Cqe {
        if cqe.status.is_err() || self.fail_rate <= 0.0 {
            return cqe;
        }
        let h = hash3(self.seed ^ STREAM_TRANSIENT, cqe.user_data, 0);
        if ((h >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < self.fail_rate {
            Cqe::err(cqe.user_data, IoError::Transient)
        } else {
            cqe
        }
    }
}

impl AsyncIoEngine for FaultInjectEngine {
    fn submit(&self, sqe: Sqe) {
        self.inner.submit(sqe)
    }

    fn submit_batch(&self, sqes: Vec<Sqe>) {
        self.inner.submit_batch(sqes)
    }

    fn wait_cqe(&self) -> Cqe {
        let cqe = self.inner.wait_cqe();
        self.perturb(cqe)
    }

    fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        self.inner.wait_cqes(n).into_iter().map(|c| self.perturb(c)).collect()
    }

    fn peek_cqe(&self) -> Option<Cqe> {
        self.inner.peek_cqe().map(|c| self.perturb(c))
    }

    fn inflight(&self) -> u64 {
        self.inner.inflight()
    }

    fn pending_harvest(&self) -> u64 {
        self.inner.pending_harvest()
    }

    fn drain(&self) {
        self.inner.drain()
    }

    fn queue_highwater(&self) -> Vec<u64> {
        self.inner.queue_highwater()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::membuf::{SlotRef, StagingArena};
    use crate::sim::Clock;
    use crate::storage::api::IoMode;
    use crate::storage::backing::MemBacking;
    use crate::storage::engine::SimBackend;
    use crate::storage::mem::HostMemory;
    use crate::storage::page_cache::{DataKind, FileId, PageCache, PAGE_SIZE};
    use crate::storage::ssd::{SsdConfig, SsdSim};
    use std::sync::atomic::Ordering;

    fn sim_parts() -> (Clock, Arc<SimBackend>, SimFile) {
        let clock = Clock::new(0.02);
        let ssd = SsdSim::new(SsdConfig::pm883(), clock.clone());
        let cache = Arc::new(PageCache::new(HostMemory::new(64 * PAGE_SIZE)));
        let storage = Arc::new(SimBackend::new(ssd, cache));
        let bytes: Vec<u8> = (0..64 * 1024u32).map(|i| (i % 251) as u8).collect();
        let file =
            SimFile::new(FileId::new(1, DataKind::Features), Arc::new(MemBacking::new(bytes)));
        (clock, storage, file)
    }

    fn wrap(
        clock: &Clock,
        storage: &Arc<SimBackend>,
        plan: FaultPlan,
        policy: RetryPolicy,
    ) -> Arc<FaultInjectBackend> {
        Arc::new(FaultInjectBackend::new(
            storage.clone(),
            BackendKind::Sim,
            plan,
            policy,
            clock.clone(),
        ))
    }

    #[test]
    fn zero_rate_plan_is_transparent() {
        let (clock, storage, file) = sim_parts();
        let faulty = wrap(&clock, &storage, FaultPlan::default(), RetryPolicy::default());
        let mut buf = vec![0u8; 1024];
        faulty
            .try_read_direct_segment(&file, 512, 1024, &mut buf, 0)
            .expect("inactive plan must not fail");
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b, ((512 + i) % 251) as u8, "byte {i}");
        }
        assert!(!faulty.plan().is_active());
        assert_eq!(faulty.name(), "sim+fault");
    }

    #[test]
    fn fault_decisions_are_deterministic_and_attempt_keyed() {
        let plan = FaultPlan::transient(42, 0.5);
        let twin = FaultPlan::transient(42, 0.5);
        let mut flips = 0;
        for off in (0..256u64).map(|i| i * 512) {
            for attempt in 0..3u32 {
                let a = plan.roll(STREAM_TRANSIENT, off, attempt, plan.transient_rate);
                let b = twin.roll(STREAM_TRANSIENT, off, attempt, twin.transient_rate);
                assert_eq!(a, b, "off={off} attempt={attempt}");
            }
            // Attempt number must matter: count offsets whose verdict flips
            // between attempt 0 and attempt 1.
            if plan.roll(STREAM_TRANSIENT, off, 0, 0.5) != plan.roll(STREAM_TRANSIENT, off, 1, 0.5)
            {
                flips += 1;
            }
        }
        assert!(flips > 0, "attempt number never changed a fault verdict");
    }

    #[test]
    fn bad_range_is_permanent_and_not_retryable() {
        let (clock, storage, file) = sim_parts();
        let plan = FaultPlan {
            bad_ranges: vec![(4096, 512)],
            ..FaultPlan::default()
        };
        let faulty = wrap(&clock, &storage, plan, RetryPolicy::default());
        let mut buf = vec![0u8; 512];
        for attempt in 0..4 {
            let err = faulty
                .try_read_direct_segment(&file, 4096, 512, &mut buf, attempt)
                .expect_err("bad range must fail every attempt");
            assert_eq!(err, IoError::BadRange { offset: 4096 });
            assert!(!err.retryable());
        }
        // A read that misses the range succeeds.
        faulty.try_read_direct_segment(&file, 8192, 512, &mut buf, 0).expect("clean offset");
    }

    #[test]
    fn engine_retries_transient_faults_to_success() {
        // 30% transient rate, default policy (3 retries): every request must
        // still complete Ok, with retries counted and zero failures. The
        // plan is deterministic, so the test *selects* a seed (rather than
        // hoping) where no offset faults on all 4 attempts but at least one
        // faults on its first — guaranteeing retries > 0 and failures == 0.
        let (clock, storage, file) = sim_parts();
        let seed = (0..1_000u64)
            .find(|&s| {
                let plan = FaultPlan::transient(s, 0.30);
                let mut any_first_fault = false;
                for off in (0..64u64).map(|i| i * 512) {
                    if (0..4).all(|a| plan.roll(STREAM_TRANSIENT, off, a, 0.30)) {
                        return false;
                    }
                    any_first_fault |= plan.roll(STREAM_TRANSIENT, off, 0, 0.30);
                }
                any_first_fault
            })
            .expect("no usable fault seed in 0..1000");
        let plan = FaultPlan::transient(seed, 0.30);
        let faulty = wrap(&clock, &storage, plan, RetryPolicy::default());
        let engine = faulty.clone().async_engine(16);

        let n = 64usize;
        let arena = StagingArena::new(1, n * 512);
        let dst = SlotRef::new(arena, 0);
        let sqes: Vec<Sqe> = (0..n)
            .map(|i| Sqe {
                file: file.clone(),
                offset: (i * 512) as u64,
                len: 512,
                useful: 512,
                dst: dst.clone(),
                dst_off: i * 512,
                user_data: i as u64,
                mode: IoMode::Direct,
            })
            .collect();
        engine.submit_batch(sqes);
        let cqes = engine.wait_cqes(n);
        assert_eq!(cqes.len(), n);
        for cqe in &cqes {
            assert!(cqe.is_ok(), "request {} failed: {:?}", cqe.user_data, cqe.status);
            assert_eq!(cqe.bytes, 512);
        }
        for (i, &b) in dst.bytes().iter().enumerate() {
            assert_eq!(b, (i % 251) as u8, "byte {i}");
        }
        let (retries, failures, _) = faulty.direct_stats().fault_snapshot();
        assert!(retries > 0, "a 30% fault rate over 64 requests must retry at least once");
        assert_eq!(failures, 0);
        // Failed attempts were charged: device ops exceed the request count.
        assert!(storage.ssd.counters().reads.load(Ordering::Relaxed) > n as u64);
    }

    #[test]
    fn fail_fast_policy_surfaces_typed_errors() {
        let (clock, storage, file) = sim_parts();
        // Rate 1.0: every attempt faults; policy none(): no retries.
        let faulty =
            wrap(&clock, &storage, FaultPlan::transient(3, 1.0), RetryPolicy::none());
        let engine = faulty.clone().async_engine(4);
        let arena = StagingArena::new(1, 512);
        let dst = SlotRef::new(arena, 0);
        engine.submit(Sqe {
            file,
            offset: 0,
            len: 512,
            useful: 512,
            dst,
            dst_off: 0,
            user_data: 9,
            mode: IoMode::Direct,
        });
        let cqe = engine.wait_cqe();
        assert_eq!(cqe.user_data, 9);
        assert_eq!(cqe.bytes, 0);
        assert_eq!(cqe.status, Err(IoError::Transient));
        let (retries, failures, _) = faulty.direct_stats().fault_snapshot();
        assert_eq!(retries, 0);
        assert_eq!(failures, 1);
        engine.drain();
        assert_eq!(engine.inflight(), 0);
        assert_eq!(engine.pending_harvest(), 0);
    }

    #[test]
    fn completion_side_injector_flips_ok_to_transient() {
        let (clock, storage, file) = sim_parts();
        let faulty = wrap(&clock, &storage, FaultPlan::default(), RetryPolicy::default());
        let engine =
            FaultInjectEngine::new(faulty.clone().async_engine(8), 11, 1.0);
        let arena = StagingArena::new(1, 512);
        let dst = SlotRef::new(arena, 0);
        engine.submit(Sqe {
            file,
            offset: 0,
            len: 512,
            useful: 512,
            dst,
            dst_off: 0,
            user_data: 5,
            mode: IoMode::Direct,
        });
        let cqe = engine.wait_cqe();
        assert_eq!(cqe.user_data, 5);
        assert_eq!(cqe.status, Err(IoError::Transient));
        assert_eq!(engine.inflight(), 0);
    }
}
