//! Shared submit/harvest core of every [`AsyncIoEngine`](super::api::AsyncIoEngine).
//!
//! The sim ring ([`super::uring::Uring`]) and the OS-file `pread` pool
//! ([`super::osfile::PreadPool`]) differ only in how their workers *serve* a
//! request (simulated device charging vs. real positional reads). Everything
//! else — the bounded SQ, the unbounded CQ, and the
//! `submitted`/`inflight`/`harvested` counter discipline whose ordering
//! invariants keep `pending_harvest` from wrapping — used to be duplicated
//! and is now this one [`EngineCore`]. Engines hold a core, spawn their own
//! worker loops over a [`WorkerPort`], and delegate the whole
//! `AsyncIoEngine` surface to the core.

use super::api::{Cqe, IoBackend, IoError, IoMode, RetryPolicy, Sqe};
use super::backing::StripeSpec;
use crate::sim::queue::BoundedQueue;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Serve one request through the backend's fallible read path, applying the
/// engine's bounded-retry policy. This is the one place the retry loop
/// lives for both engines (`Uring`, `PreadPool`):
///
/// * each attempt goes back through the backend, so a retried read is
///   re-charged honestly (device ops/bytes accrue per attempt that reached
///   the device) and deterministic fault plans see the attempt number;
/// * retries/failures are counted in the backend's [`DirectIoStats`];
/// * a panic inside the backend read is contained and classified as
///   [`IoError::Internal`] (not retried — a deterministic panic would loop);
/// * when `RetryPolicy::deadline_us` elapses mid-policy, the request gives
///   up with [`IoError::Deadline`].
///
/// Returns `(status, charged_aligned_bytes)`: the aligned byte count of the
/// *successful* direct attempt (0 for buffered or failed requests), which
/// engines batch into one [`IoBackend::charge_multi`] call per chunk.
pub(crate) fn serve_sqe(
    backend: &dyn IoBackend,
    policy: &RetryPolicy,
    sqe: &Sqe,
) -> (Result<usize, IoError>, usize) {
    let start = std::time::Instant::now();
    let mut attempt: u32 = 0;
    loop {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: this worker owns the request's staging sub-range until
            // its CQE is published (the SlotRef range protocol).
            let dst = unsafe { sqe.dst.slice_mut(sqe.dst_off, sqe.len) };
            match sqe.mode {
                IoMode::Direct => {
                    backend.try_read_direct_segment(&sqe.file, sqe.offset, sqe.useful, dst, attempt)
                }
                IoMode::Buffered => {
                    backend.try_read_buffered(&sqe.file, sqe.offset, dst, attempt).map(|()| 0)
                }
            }
        }))
        .unwrap_or(Err(IoError::Internal));
        match res {
            Ok(aligned) => return (Ok(sqe.len), aligned),
            Err(e) => {
                let over_deadline = policy
                    .deadline_us
                    .is_some_and(|d| start.elapsed().as_micros() as u64 >= d);
                if !e.retryable() || attempt >= policy.max_retries || over_deadline {
                    backend.direct_stats().count_failure();
                    let e = if over_deadline && e.retryable() { IoError::Deadline } else { e };
                    return (Err(e), 0);
                }
                attempt += 1;
                backend.direct_stats().count_retry();
                let backoff = policy.backoff_us(sqe.offset ^ sqe.user_data, attempt);
                if backoff > 0 {
                    std::thread::sleep(Duration::from_micros(backoff));
                }
            }
        }
    }
}

/// How long a blocked harvester waits on the CQ before re-checking whether
/// the engine died underneath it (poisoned worker / closed core). Purely a
/// liveness bound — on a healthy engine a pushed CQE wakes the waiter
/// immediately and the timeout never matters.
const HARVEST_POLL: Duration = Duration::from_millis(25);

/// SQ/CQ pair + counter discipline shared by every async engine.
///
/// **Striping.** The core holds one bounded submission sub-queue *per
/// stripe device*, each with the full `--io-depth` budget: a stalled device
/// fills only its own sub-queue, so submissions bound for idle devices
/// never block behind it (no head-of-line blocking across devices).
/// Requests route to sub-queues by `StripeSpec::device_of(sqe.offset)`;
/// workers bind to exactly one device's sub-queue
/// ([`EngineCore::worker_port`]). The global
/// `submitted`/`inflight`/`harvested` discipline — and therefore
/// `pending_harvest`, `drain` and the poison contract — is unchanged and
/// holds across all sub-queues; per-device in-flight counts ride alongside
/// purely for the queue-utilization high-water marks. One device collapses
/// to the historical single-queue core.
pub struct EngineCore {
    /// Engine name for panic messages ("uring", "pread pool").
    name: &'static str,
    /// One submission sub-queue per stripe device, each `depth` deep.
    sqs: Vec<Arc<BoundedQueue<Sqe>>>,
    spec: StripeSpec,
    cq: Arc<BoundedQueue<Cqe>>,
    inflight: Arc<AtomicU64>,
    pub(crate) submitted: AtomicU64,
    harvested: AtomicU64,
    /// Per-device outstanding requests (observability only; the completion
    /// contract rides on the global `inflight`).
    dev_inflight: Vec<Arc<AtomicU64>>,
    /// Per-device in-flight high-water marks since construction.
    dev_highwater: Vec<Arc<AtomicU64>>,
    /// Set when a worker thread died outside its per-request panic guard:
    /// the counters may never balance again, so harvesters stop trusting
    /// them and synthesize [`IoError::EnginePoisoned`] completions instead
    /// of blocking forever.
    poisoned: Arc<AtomicBool>,
}

/// A worker's handle into the core: pop submissions from *its device's*
/// sub-queue, publish completions to the shared CQ. Cheap to clone into
/// each worker thread. Binding a worker to one device is what lets the
/// completion path decrement the right per-device in-flight counter
/// without CQEs having to carry offsets.
#[derive(Clone)]
pub struct WorkerPort {
    sq: Arc<BoundedQueue<Sqe>>,
    cq: Arc<BoundedQueue<Cqe>>,
    inflight: Arc<AtomicU64>,
    dev_inflight: Arc<AtomicU64>,
    poisoned: Arc<AtomicBool>,
}

impl WorkerPort {
    /// Pull one request; `Err` once the core is closed and drained.
    pub fn pop(&self) -> Result<Sqe, crate::sim::queue::Closed> {
        self.sq.pop()
    }

    /// Pull up to `n` requests in one wakeup.
    pub fn pop_many(&self, n: usize) -> Result<Vec<Sqe>, crate::sim::queue::Closed> {
        self.sq.pop_many(n)
    }

    /// Publish a successful completion. The CQ is effectively unbounded
    /// (see [`EngineCore::new`]), so this never blocks the worker.
    pub fn complete(&self, user_data: u64, bytes: usize) {
        self.dec_inflight();
        let _ = self.cq.push(Cqe::ok(user_data, bytes));
    }

    /// Publish a failed completion: counters drain exactly as on success,
    /// only the status differs — an I/O error must never strand `inflight`.
    pub fn complete_err(&self, user_data: u64, err: IoError) {
        self.dec_inflight();
        let _ = self.cq.push(Cqe::err(user_data, err));
    }

    /// Mark the engine dead (worker lost outside its per-request guard).
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
        // Wake blocked harvesters so they observe the poisoning: closing
        // the CQ is the only broadcast we have, and a poisoned engine is
        // done publishing real completions anyway.
        self.cq.close();
    }

    /// RAII guard a worker holds for its whole loop: if the thread unwinds
    /// past it (a panic the per-request guard did not contain), the core is
    /// poisoned so harvesters fail fast instead of hanging.
    pub fn poison_guard(&self) -> PoisonGuard {
        PoisonGuard { port: self.clone() }
    }

    fn dec_inflight(&self) {
        // Saturating: a late completion racing a dead-engine counter
        // reconcile (`EngineCore::drain`) must not wrap the counter.
        let _ = self
            .inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
        let _ = self
            .dev_inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }
}

/// See [`WorkerPort::poison_guard`].
pub struct PoisonGuard {
    port: WorkerPort,
}

impl Drop for PoisonGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.port.poison();
        }
    }
}

impl EngineCore {
    /// Single-device core: `depth` is the submission-queue size (max
    /// outstanding requests before submitters block on backpressure).
    pub fn new(name: &'static str, depth: usize) -> Self {
        EngineCore::new_striped(name, depth, StripeSpec::single())
    }

    /// Core with one `depth`-deep submission sub-queue per device of
    /// `spec`. Each device gets the *full* depth budget — `--io-depth` is
    /// per device, so adding devices adds aggregate submission headroom.
    pub fn new_striped(name: &'static str, depth: usize, spec: StripeSpec) -> Self {
        let depth = depth.max(1);
        let devices = spec.devices.max(1);
        // The CQ is effectively unbounded: callers may legally submit an
        // entire mini-batch before harvesting a single completion
        // (Algorithm 1 does exactly that), so a bounded CQ would deadlock —
        // workers blocking on a full CQ stop draining the SQ, and the
        // submitter blocks on the full SQ. CQEs are small; memory is fine.
        EngineCore {
            name,
            sqs: (0..devices).map(|_| Arc::new(BoundedQueue::<Sqe>::new(depth))).collect(),
            spec,
            cq: Arc::new(BoundedQueue::<Cqe>::new(usize::MAX / 2)),
            inflight: Arc::new(AtomicU64::new(0)),
            submitted: AtomicU64::new(0),
            harvested: AtomicU64::new(0),
            dev_inflight: (0..devices).map(|_| Arc::new(AtomicU64::new(0))).collect(),
            dev_highwater: (0..devices).map(|_| Arc::new(AtomicU64::new(0))).collect(),
            poisoned: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Number of per-device sub-queues.
    pub fn device_count(&self) -> usize {
        self.sqs.len()
    }

    /// Which sub-queue serves `sqe` (by the logical offset's stripe chunk).
    fn route(&self, sqe: &Sqe) -> usize {
        self.spec.device_of(sqe.offset).min(self.sqs.len() - 1)
    }

    /// Handle for a worker thread bound to device `dev`'s sub-queue.
    pub fn worker_port(&self, dev: usize) -> WorkerPort {
        WorkerPort {
            sq: self.sqs[dev].clone(),
            cq: self.cq.clone(),
            inflight: self.inflight.clone(),
            dev_inflight: self.dev_inflight[dev].clone(),
            poisoned: self.poisoned.clone(),
        }
    }

    /// Per-device in-flight high-water marks since construction.
    pub fn queue_highwater(&self) -> Vec<u64> {
        self.dev_highwater.iter().map(|h| h.load(Ordering::Relaxed)).collect()
    }

    /// Record `added` new in-flight requests on `dev`, updating that
    /// device's high-water mark.
    fn note_dev_inflight(&self, dev: usize, added: u64) {
        let now = self.dev_inflight[dev].fetch_add(added, Ordering::Relaxed) + added;
        let hw = &self.dev_highwater[dev];
        let mut cur = hw.load(Ordering::Relaxed);
        while now > cur {
            match hw.compare_exchange_weak(cur, now, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Close every submission sub-queue (but not the CQ) — test hook for
    /// exercising the closed-ring submit paths.
    #[cfg(test)]
    pub(crate) fn close_submission(&self) {
        for sq in &self.sqs {
            sq.close();
        }
    }

    /// Whether a worker died outside its panic guard (see [`WorkerPort::poison`]).
    pub fn poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// The engine can no longer produce completions for outstanding work:
    /// poisoned, or shut down with every submission sub-queue closed.
    fn dead(&self) -> bool {
        self.poisoned() || self.sqs.iter().all(|sq| sq.is_closed())
    }

    /// Synthetic completion minted when the engine is dead with requests
    /// outstanding: harvesters get a typed [`IoError::EnginePoisoned`]
    /// instead of a hang or a panic. Tagged [`Cqe::POISON_USER_DATA`]
    /// because it stands in for *some* lost request, not a specific one.
    fn poisoned_cqe(&self) -> Cqe {
        self.harvested.fetch_add(1, Ordering::Relaxed);
        Cqe::err(Cqe::POISON_USER_DATA, IoError::EnginePoisoned)
    }

    /// Submit one request. Blocks only if the SQ is full (ring
    /// backpressure); the I/O itself proceeds asynchronously.
    ///
    /// Counters are incremented *before* the push (`submitted` first, see
    /// `pending_harvest`) so a worker that completes the request
    /// immediately never observes `inflight` below its own decrement. If
    /// the push fails (core closed) the increments are unwound before
    /// panicking so the counters stay balanced for any drop-order observer.
    pub fn submit(&self, sqe: Sqe) {
        let dev = self.route(&sqe);
        self.submitted.fetch_add(1, Ordering::SeqCst);
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.note_dev_inflight(dev, 1);
        if self.sqs[dev].push(sqe).is_err() {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.submitted.fetch_sub(1, Ordering::SeqCst);
            let _ = self.dev_inflight[dev]
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
            panic!("{} closed", self.name);
        }
    }

    /// Submit a batch of requests with amortized locking/wakeups. With a
    /// striped core the batch is partitioned by owning device and each
    /// device's group is pushed to its own sub-queue — the caller's
    /// round-robin interleave decides how evenly the groups fill.
    ///
    /// On a mid-batch closure only the enqueued prefix keeps its counter
    /// increments (those requests will still be serviced and drained); the
    /// rejected remainder's increments are unwound.
    pub fn submit_batch(&self, sqes: Vec<Sqe>) {
        if self.sqs.len() == 1 {
            let n = sqes.len() as u64;
            self.submitted.fetch_add(n, Ordering::SeqCst);
            self.inflight.fetch_add(n, Ordering::SeqCst);
            self.note_dev_inflight(0, n);
            if let Err(partial) = self.sqs[0].push_all(sqes) {
                let rejected = n - partial.pushed as u64;
                self.inflight.fetch_sub(rejected, Ordering::SeqCst);
                self.submitted.fetch_sub(rejected, Ordering::SeqCst);
                let _ = self.dev_inflight[0].fetch_update(
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                    |v| Some(v.saturating_sub(rejected)),
                );
                panic!("{} closed", self.name);
            }
            return;
        }
        let mut groups: Vec<Vec<Sqe>> = (0..self.sqs.len()).map(|_| Vec::new()).collect();
        for sqe in sqes {
            let dev = self.route(&sqe);
            groups[dev].push(sqe);
        }
        for (dev, group) in groups.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let n = group.len() as u64;
            self.submitted.fetch_add(n, Ordering::SeqCst);
            self.inflight.fetch_add(n, Ordering::SeqCst);
            self.note_dev_inflight(dev, n);
            if let Err(partial) = self.sqs[dev].push_all(group) {
                let rejected = n - partial.pushed as u64;
                self.inflight.fetch_sub(rejected, Ordering::SeqCst);
                self.submitted.fetch_sub(rejected, Ordering::SeqCst);
                let _ = self.dev_inflight[dev].fetch_update(
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                    |v| Some(v.saturating_sub(rejected)),
                );
                panic!("{} closed", self.name);
            }
        }
    }

    /// Harvest one completion, blocking until available.
    ///
    /// Never hangs and never panics on a dead engine: if the core is
    /// poisoned or closed while completions are still owed, a synthetic
    /// [`IoError::EnginePoisoned`] CQE is returned instead — the caller
    /// learns its request is lost through the same typed channel as any
    /// other I/O failure.
    pub fn wait_cqe(&self) -> Cqe {
        loop {
            match self.cq.pop_timeout(HARVEST_POLL) {
                Ok(Some(cqe)) => {
                    self.harvested.fetch_add(1, Ordering::Relaxed);
                    return cqe;
                }
                // Timed out with the engine still alive: keep waiting (a
                // healthy engine will push and wake us).
                Ok(None) => {
                    if self.dead() {
                        return self.poisoned_cqe();
                    }
                }
                // CQ closed and drained: no real completion is coming.
                Err(_) => return self.poisoned_cqe(),
            }
        }
    }

    /// Harvest exactly `n` completions, blocking as needed; ready bursts
    /// are drained non-blockingly between waits. On a dead engine the
    /// remainder is filled with synthetic poisoned CQEs (see
    /// [`EngineCore::wait_cqe`]) so the call always returns `n` entries.
    pub fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            out.push(self.wait_cqe());
            while out.len() < n {
                match self.peek_cqe() {
                    Some(cqe) => out.push(cqe),
                    None => break,
                }
            }
        }
        out
    }

    /// Harvest a completion if one is ready.
    pub fn peek_cqe(&self) -> Option<Cqe> {
        let cqe = self.cq.try_pop();
        if cqe.is_some() {
            self.harvested.fetch_add(1, Ordering::Relaxed);
        }
        cqe
    }

    /// Outstanding requests (submitted − completed).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Completions not yet harvested by the caller.
    ///
    /// The three counters cannot be read in one shot, so the load *order*
    /// is what keeps the difference non-negative: `harvested` and
    /// `inflight` are read first and `submitted` last. Whatever races in
    /// between can only grow `submitted` relative to the two snapshots
    /// (`submitted` is incremented before `inflight` on submit, and
    /// `inflight` is decremented before `harvested` is incremented on the
    /// completion path), so the subtraction never wraps. The
    /// `saturating_sub` is a belt-and-braces floor, not the fix.
    pub fn pending_harvest(&self) -> u64 {
        let harvested = self.harvested.load(Ordering::SeqCst);
        let inflight = self.inflight.load(Ordering::SeqCst);
        let submitted = self.submitted.load(Ordering::SeqCst);
        submitted.saturating_sub(harvested + inflight)
    }

    /// Quiesce the core after an aborted cycle: wait out every in-flight
    /// request and swallow every unharvested completion, leaving
    /// `inflight() == pending_harvest() == 0`.
    ///
    /// Loop shape: ready CQEs are consumed non-blockingly first; only when
    /// none are ready *and* requests are still in flight does the call
    /// block on the CQ — each such in-flight request is guaranteed to push
    /// a CQE (workers complete even requests popped from a closed SQ), so
    /// the blocking pop always terminates. The exit check re-reads both
    /// counters after the CQ is observed empty, closing the race where a
    /// completion lands between the peek and the check (`inflight` is
    /// decremented *before* the CQE push, so `inflight == 0 &&
    /// pending_harvest == 0` proves both the writes and the bookkeeping of
    /// every submitted request have finished).
    pub fn drain(&self) {
        loop {
            if self.peek_cqe().is_some() {
                continue;
            }
            if self.inflight() == 0 && self.pending_harvest() == 0 {
                return;
            }
            if self.dead() {
                // Poisoned or closed with requests outstanding: no further
                // CQEs can arrive. Reconcile the counters to "quiesced" so
                // callers (e.g. the extractor's drain-on-entry guard) stop
                // re-entering, and return instead of hanging. Late
                // completions from a surviving worker are tolerated: the
                // inflight decrement saturates and stray CQEs are swallowed
                // by the next drain.
                self.inflight.store(0, Ordering::SeqCst);
                for d in &self.dev_inflight {
                    d.store(0, Ordering::SeqCst);
                }
                self.harvested.store(self.submitted.load(Ordering::SeqCst), Ordering::SeqCst);
                return;
            }
            // Block briefly for the next completion, then re-check liveness
            // — this is what turns the old "hang forever on a dead engine"
            // failure mode into a bounded wait.
            if let Ok(Some(_)) = self.cq.pop_timeout(HARVEST_POLL) {
                self.harvested.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Close all queues (engine shutdown; workers drain and exit).
    pub fn close(&self) {
        for sq in &self.sqs {
            sq.close();
        }
        self.cq.close();
    }
}
