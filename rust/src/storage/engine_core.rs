//! Shared submit/harvest core of every [`AsyncIoEngine`](super::api::AsyncIoEngine).
//!
//! The sim ring ([`super::uring::Uring`]) and the OS-file `pread` pool
//! ([`super::osfile::PreadPool`]) differ only in how their workers *serve* a
//! request (simulated device charging vs. real positional reads). Everything
//! else — the bounded SQ, the unbounded CQ, and the
//! `submitted`/`inflight`/`harvested` counter discipline whose ordering
//! invariants keep `pending_harvest` from wrapping — used to be duplicated
//! and is now this one [`EngineCore`]. Engines hold a core, spawn their own
//! worker loops over a [`WorkerPort`], and delegate the whole
//! `AsyncIoEngine` surface to the core.

use super::api::{Cqe, Sqe};
use crate::sim::queue::BoundedQueue;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// SQ/CQ pair + counter discipline shared by every async engine.
pub struct EngineCore {
    /// Engine name for panic messages ("uring", "pread pool").
    name: &'static str,
    pub(crate) sq: Arc<BoundedQueue<Sqe>>,
    cq: Arc<BoundedQueue<Cqe>>,
    inflight: Arc<AtomicU64>,
    pub(crate) submitted: AtomicU64,
    harvested: AtomicU64,
}

/// A worker's handle into the core: pop submissions, publish completions.
/// Cheap to clone into each worker thread.
#[derive(Clone)]
pub struct WorkerPort {
    sq: Arc<BoundedQueue<Sqe>>,
    cq: Arc<BoundedQueue<Cqe>>,
    inflight: Arc<AtomicU64>,
}

impl WorkerPort {
    /// Pull one request; `Err` once the core is closed and drained.
    pub fn pop(&self) -> Result<Sqe, crate::sim::queue::Closed> {
        self.sq.pop()
    }

    /// Pull up to `n` requests in one wakeup.
    pub fn pop_many(&self, n: usize) -> Result<Vec<Sqe>, crate::sim::queue::Closed> {
        self.sq.pop_many(n)
    }

    /// Publish a completion. The CQ is effectively unbounded (see
    /// [`EngineCore::new`]), so this never blocks the worker.
    pub fn complete(&self, user_data: u64, bytes: usize) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = self.cq.push(Cqe { user_data, bytes });
    }
}

impl EngineCore {
    /// `depth` is the submission-queue size (max outstanding requests before
    /// submitters block on backpressure).
    pub fn new(name: &'static str, depth: usize) -> Self {
        let depth = depth.max(1);
        // The CQ is effectively unbounded: callers may legally submit an
        // entire mini-batch before harvesting a single completion
        // (Algorithm 1 does exactly that), so a bounded CQ would deadlock —
        // workers blocking on a full CQ stop draining the SQ, and the
        // submitter blocks on the full SQ. CQEs are small; memory is fine.
        EngineCore {
            name,
            sq: Arc::new(BoundedQueue::<Sqe>::new(depth)),
            cq: Arc::new(BoundedQueue::<Cqe>::new(usize::MAX / 2)),
            inflight: Arc::new(AtomicU64::new(0)),
            submitted: AtomicU64::new(0),
            harvested: AtomicU64::new(0),
        }
    }

    /// Handle for a worker thread.
    pub fn worker_port(&self) -> WorkerPort {
        WorkerPort {
            sq: self.sq.clone(),
            cq: self.cq.clone(),
            inflight: self.inflight.clone(),
        }
    }

    /// Submit one request. Blocks only if the SQ is full (ring
    /// backpressure); the I/O itself proceeds asynchronously.
    ///
    /// Counters are incremented *before* the push (`submitted` first, see
    /// `pending_harvest`) so a worker that completes the request
    /// immediately never observes `inflight` below its own decrement. If
    /// the push fails (core closed) the increments are unwound before
    /// panicking so the counters stay balanced for any drop-order observer.
    pub fn submit(&self, sqe: Sqe) {
        self.submitted.fetch_add(1, Ordering::SeqCst);
        self.inflight.fetch_add(1, Ordering::SeqCst);
        if self.sq.push(sqe).is_err() {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.submitted.fetch_sub(1, Ordering::SeqCst);
            panic!("{} closed", self.name);
        }
    }

    /// Submit a batch of requests with amortized locking/wakeups.
    ///
    /// On a mid-batch closure only the enqueued prefix keeps its counter
    /// increments (those requests will still be serviced and drained); the
    /// rejected remainder's increments are unwound.
    pub fn submit_batch(&self, sqes: Vec<Sqe>) {
        let n = sqes.len() as u64;
        self.submitted.fetch_add(n, Ordering::SeqCst);
        self.inflight.fetch_add(n, Ordering::SeqCst);
        if let Err(partial) = self.sq.push_all(sqes) {
            let rejected = n - partial.pushed as u64;
            self.inflight.fetch_sub(rejected, Ordering::SeqCst);
            self.submitted.fetch_sub(rejected, Ordering::SeqCst);
            panic!("{} closed", self.name);
        }
    }

    /// Harvest one completion, blocking until available.
    pub fn wait_cqe(&self) -> Cqe {
        let cqe = self.cq.pop().unwrap_or_else(|_| panic!("{} closed", self.name));
        self.harvested.fetch_add(1, Ordering::Relaxed);
        cqe
    }

    /// Harvest exactly `n` completions, blocking as needed; wakeups are
    /// amortized across bursts of ready CQEs.
    pub fn wait_cqes(&self, n: usize) -> Vec<Cqe> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let got = self
                .cq
                .pop_many(n - out.len())
                .unwrap_or_else(|_| panic!("{} closed", self.name));
            self.harvested.fetch_add(got.len() as u64, Ordering::Relaxed);
            out.extend(got);
        }
        out
    }

    /// Harvest a completion if one is ready.
    pub fn peek_cqe(&self) -> Option<Cqe> {
        let cqe = self.cq.try_pop();
        if cqe.is_some() {
            self.harvested.fetch_add(1, Ordering::Relaxed);
        }
        cqe
    }

    /// Outstanding requests (submitted − completed).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Completions not yet harvested by the caller.
    ///
    /// The three counters cannot be read in one shot, so the load *order*
    /// is what keeps the difference non-negative: `harvested` and
    /// `inflight` are read first and `submitted` last. Whatever races in
    /// between can only grow `submitted` relative to the two snapshots
    /// (`submitted` is incremented before `inflight` on submit, and
    /// `inflight` is decremented before `harvested` is incremented on the
    /// completion path), so the subtraction never wraps. The
    /// `saturating_sub` is a belt-and-braces floor, not the fix.
    pub fn pending_harvest(&self) -> u64 {
        let harvested = self.harvested.load(Ordering::SeqCst);
        let inflight = self.inflight.load(Ordering::SeqCst);
        let submitted = self.submitted.load(Ordering::SeqCst);
        submitted.saturating_sub(harvested + inflight)
    }

    /// Quiesce the core after an aborted cycle: wait out every in-flight
    /// request and swallow every unharvested completion, leaving
    /// `inflight() == pending_harvest() == 0`.
    ///
    /// Loop shape: ready CQEs are consumed non-blockingly first; only when
    /// none are ready *and* requests are still in flight does the call
    /// block on the CQ — each such in-flight request is guaranteed to push
    /// a CQE (workers complete even requests popped from a closed SQ), so
    /// the blocking pop always terminates. The exit check re-reads both
    /// counters after the CQ is observed empty, closing the race where a
    /// completion lands between the peek and the check (`inflight` is
    /// decremented *before* the CQE push, so `inflight == 0 &&
    /// pending_harvest == 0` proves both the writes and the bookkeeping of
    /// every submitted request have finished).
    pub fn drain(&self) {
        loop {
            if self.peek_cqe().is_some() {
                continue;
            }
            if self.inflight() == 0 && self.pending_harvest() == 0 {
                return;
            }
            self.wait_cqe();
        }
    }

    /// Close both queues (engine shutdown; workers drain and exit).
    pub fn close(&self) {
        self.sq.close();
        self.cq.close();
    }
}
