//! The simulated storage backend: synchronous I/O engines over the simulated
//! device — buffered (page-cache, mmap-style) and direct (O_DIRECT-style,
//! sector-aligned, cache-bypassing) — behind the [`IoBackend`] seam.
//!
//! GNNDrive reads *topology* through the buffered path (the paper mmaps the
//! CSC index array and lets the page cache hold it) and *features* through
//! the direct path; PyG+ reads both through the buffered path, which is what
//! makes the two working sets contend (D1).

use super::api::{AsyncIoEngine, DirectIoStats, IoBackend};
use super::backing::{BackingRef, StripeSpec};
use super::page_cache::{FileId, PageCache, PAGE_SIZE};
use super::ssd::{SsdCounters, SsdSim};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// A "file" on the simulated SSD: identity for the page cache + real bytes.
/// (The OS-file backend reuses the same handle type with a `FileBacking`
/// behind it — the `FileId` is simply unused there.)
#[derive(Clone)]
pub struct SimFile {
    pub id: FileId,
    pub backing: BackingRef,
}

impl SimFile {
    pub fn new(id: FileId, backing: BackingRef) -> Self {
        SimFile { id, backing }
    }

    pub fn len(&self) -> u64 {
        self.backing.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backing.is_empty()
    }
}

/// The simulated I/O stack: one *or more* simulated devices + one page
/// cache, shared by every training system in an experiment (as on a real
/// machine).
///
/// With `--devices N > 1` the backend holds N independent [`SsdSim`]
/// instances in a RAID-0 arrangement described by a [`StripeSpec`]: each
/// device has its own IOPS/bandwidth token buckets and queue-depth
/// semaphore, so charged latency reflects N ceilings that can be saturated
/// concurrently. Charges route by the *logical* offset of the request
/// (`StripeSpec::device_of`); per-device engines charge through
/// [`IoBackend::charge_multi_dev`]. `io_counters` reports the aggregate
/// across devices (`device_io_snapshot` has the per-device breakdown); with
/// one device everything collapses to the historical single-`SsdSim` model,
/// byte-for-byte.
///
/// This is the [`IoBackend`] the simulator uses; the inherent methods remain
/// available for sim-only experiments that poke `ssd`/`cache` directly.
#[derive(Clone)]
pub struct SimBackend {
    /// Device 0 of the stripe set (the only device when unstriped — the
    /// historical public field sim-only experiments poke directly).
    pub ssd: SsdSim,
    pub cache: Arc<PageCache>,
    direct_stats: Arc<DirectIoStats>,
    /// Devices 1..N of the stripe set; empty when unstriped.
    extra: Vec<SsdSim>,
    spec: StripeSpec,
    /// Aggregate charged counters across all devices — the `io_counters`
    /// surface when striped (each member's own counters also accrue, for
    /// `device_io_snapshot`). Unused when unstriped.
    totals: Arc<SsdCounters>,
}

/// Historical name: the concrete sim stack predates the backend seam and
/// most of the codebase knows it as `Storage`.
pub type Storage = SimBackend;

impl SimBackend {
    pub fn new(ssd: SsdSim, cache: Arc<PageCache>) -> Self {
        SimBackend {
            ssd,
            cache,
            direct_stats: Arc::new(DirectIoStats::default()),
            extra: Vec::new(),
            spec: StripeSpec::single(),
            totals: Arc::new(SsdCounters::default()),
        }
    }

    /// Striped stack: `ssds[d]` serves stripe device `d` under a
    /// `stripe_bytes` chunk layout. One device degenerates to [`Self::new`].
    pub fn new_striped(mut ssds: Vec<SsdSim>, cache: Arc<PageCache>, stripe_bytes: u64) -> Self {
        assert!(!ssds.is_empty(), "striped sim backend needs at least one device");
        let spec = StripeSpec::new(ssds.len(), stripe_bytes);
        let extra = ssds.split_off(1);
        let ssd = ssds.pop().expect("device 0");
        SimBackend {
            ssd,
            cache,
            direct_stats: Arc::new(DirectIoStats::default()),
            extra,
            spec,
            totals: Arc::new(SsdCounters::default()),
        }
    }

    pub fn direct_stats(&self) -> &DirectIoStats {
        &self.direct_stats
    }

    /// Stripe member `d` (0-based).
    pub fn device(&self, d: usize) -> &SsdSim {
        if d == 0 {
            &self.ssd
        } else {
            &self.extra[d - 1]
        }
    }

    /// Number of stripe members.
    pub fn device_count(&self) -> usize {
        1 + self.extra.len()
    }

    /// Charge one read of the logical range `[offset, offset+len)`,
    /// splitting at chunk boundaries so each touched device pays its own
    /// op. Unstriped: exactly one `ssd.read(len)` — the historical charge.
    fn charge_read_at(&self, offset: u64, len: usize) {
        if !self.spec.is_striped() {
            self.ssd.read(len);
            return;
        }
        for (dev, _local, run) in self.spec.split(offset, len) {
            self.device(dev).read(run);
            self.totals.add_read(1, run as u64);
        }
    }

    /// Charge one write of the logical range, split like `charge_read_at`.
    fn charge_write_at(&self, offset: u64, len: usize) {
        if !self.spec.is_striped() {
            self.ssd.write(len);
            return;
        }
        for (dev, _local, run) in self.spec.split(offset, len) {
            self.device(dev).write(run);
            self.totals.add_write(1, run as u64);
        }
    }

    /// Buffered read (mmap semantics): page-granular, through the page
    /// cache. Contiguous missing pages coalesce into one device request, so
    /// sequential scans are bandwidth-bound while random row accesses are
    /// IOPS-bound — both behaviours the experiments rely on.
    pub fn read_buffered(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        if buf.is_empty() {
            return;
        }
        let first = offset / PAGE_SIZE;
        let last = (offset + buf.len() as u64 - 1) / PAGE_SIZE;
        let mut pending: u64 = 0; // contiguous missing pages to fetch
        let mut run_start: u64 = first; // first page of the pending run
        for page in first..=last {
            if self.cache.access(file.id, page) {
                if pending > 0 {
                    self.charge_read_at(run_start * PAGE_SIZE, (pending * PAGE_SIZE) as usize);
                    pending = 0;
                }
                run_start = page + 1;
            } else {
                pending += 1;
            }
        }
        if pending > 0 {
            self.charge_read_at(run_start * PAGE_SIZE, (pending * PAGE_SIZE) as usize);
        }
        file.backing.read_at(offset, buf);
    }

    /// Direct read (O_DIRECT semantics): bypasses the page cache; offset and
    /// length are rounded out to sector alignment and the *aligned* size is
    /// charged to the device, so sub-sector feature rows pay redundancy
    /// (§4.4) unless callers batch neighbors jointly.
    pub fn read_direct(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        if buf.is_empty() {
            return;
        }
        let sector = self.ssd.config().sector as u64;
        let lo = offset / sector * sector;
        let hi = (offset + buf.len() as u64).div_ceil(sector) * sector;
        let aligned = (hi - lo) as usize;
        self.direct_stats.requests.fetch_add(1, Ordering::Relaxed);
        self.direct_stats.useful_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.direct_stats.aligned_bytes.fetch_add(aligned as u64, Ordering::Relaxed);
        self.charge_read_at(lo, aligned);
        file.backing.read_at(offset, buf);
    }

    /// Direct-read accounting + data copy *without* charging device time;
    /// returns the sector-aligned byte count. The async engine uses this to
    /// coalesce several requests into one [`SsdSim::read_multi`] charge.
    pub fn read_direct_nocharge(&self, file: &SimFile, offset: u64, buf: &mut [u8]) -> usize {
        let useful = buf.len();
        self.read_direct_segment_nocharge(file, offset, useful, buf)
    }

    /// Segment-granular variant: one request covering a contiguous
    /// (possibly multi-row) span of which only `useful` bytes are genuinely
    /// requested rows — the sector-aligned *span* is what the device serves
    /// and what `aligned_bytes` records, so coalesced runs stop
    /// double-counting shared sectors (§4.4).
    pub fn read_direct_segment_nocharge(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
    ) -> usize {
        if buf.is_empty() {
            return 0;
        }
        let sector = self.ssd.config().sector as u64;
        let lo = offset / sector * sector;
        let hi = (offset + buf.len() as u64).div_ceil(sector) * sector;
        let aligned = (hi - lo) as usize;
        self.direct_stats.requests.fetch_add(1, Ordering::Relaxed);
        self.direct_stats.useful_bytes.fetch_add(useful as u64, Ordering::Relaxed);
        self.direct_stats.aligned_bytes.fetch_add(aligned as u64, Ordering::Relaxed);
        file.backing.read_at(offset, buf);
        aligned
    }

    /// Buffered write: pages become resident (they'd be dirty in a real
    /// cache); device time is charged for the whole range (write-through
    /// keeps the model simple; Ginex's superbatch dumps are large and
    /// sequential either way).
    pub fn write_buffered(&self, file: &SimFile, offset: u64, len: usize) {
        if len == 0 {
            return;
        }
        let first = offset / PAGE_SIZE;
        let last = (offset + len as u64 - 1) / PAGE_SIZE;
        for page in first..=last {
            self.cache.access(file.id, page);
        }
        self.charge_write_at(offset, len);
    }

    /// Direct write of an aligned range.
    pub fn write_direct(&self, _file: &SimFile, offset: u64, len: usize) {
        if len == 0 {
            return;
        }
        let sector = self.ssd.config().sector;
        let aligned = len.div_ceil(sector) * sector;
        self.charge_write_at(offset / sector as u64 * sector as u64, aligned);
    }
}

impl IoBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn sector(&self) -> usize {
        self.ssd.config().sector
    }

    fn read_buffered(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        SimBackend::read_buffered(self, file, offset, buf)
    }

    fn read_direct(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        SimBackend::read_direct(self, file, offset, buf)
    }

    fn read_direct_segment_nocharge(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
    ) -> usize {
        SimBackend::read_direct_segment_nocharge(self, file, offset, useful, buf)
    }

    fn charge_multi(&self, ops: u64, bytes: usize) {
        // No offset information: device 0 absorbs the charge (legacy
        // callers; striped engines use `charge_multi_dev`).
        self.ssd.read_multi(ops, bytes);
        if self.spec.is_striped() && ops > 0 {
            self.totals.add_read(ops, bytes as u64);
        }
    }

    fn stripe(&self) -> StripeSpec {
        self.spec
    }

    fn charge_multi_dev(&self, dev: usize, ops: u64, bytes: usize) {
        self.device(dev).read_multi(ops, bytes);
        if self.spec.is_striped() && ops > 0 {
            self.totals.add_read(ops, bytes as u64);
        }
    }

    fn device_io_snapshot(&self) -> Vec<(u64, u64)> {
        (0..self.device_count()).map(|d| self.device(d).counters().read_snapshot()).collect()
    }

    fn write_buffered(&self, file: &SimFile, offset: u64, len: usize) {
        SimBackend::write_buffered(self, file, offset, len)
    }

    fn write_direct(&self, file: &SimFile, offset: u64, len: usize) {
        SimBackend::write_direct(self, file, offset, len)
    }

    fn charge_read(&self, len: usize) {
        self.ssd.read(len);
        if self.spec.is_striped() {
            self.totals.add_read(1, len as u64);
        }
    }

    fn charge_write(&self, len: usize) {
        self.ssd.write(len);
        if self.spec.is_striped() {
            self.totals.add_write(1, len as u64);
        }
    }

    fn direct_stats(&self) -> &DirectIoStats {
        &self.direct_stats
    }

    fn io_counters(&self) -> &SsdCounters {
        if self.spec.is_striped() {
            &self.totals
        } else {
            self.ssd.counters()
        }
    }

    fn reset_io_stats(&self) {
        self.ssd.reset_stats();
        for d in &self.extra {
            d.reset_stats();
        }
        self.totals.reset();
    }

    fn async_engine(self: Arc<Self>, depth: usize) -> Box<dyn AsyncIoEngine> {
        Box::new(super::uring::Uring::new(self, depth))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Clock;
    use crate::storage::backing::MemBacking;
    use crate::storage::mem::HostMemory;
    use crate::storage::page_cache::DataKind;
    use crate::storage::ssd::SsdConfig;

    fn setup(cache_pages: u64) -> (Storage, SimFile) {
        let clock = Clock::new(0.02);
        let ssd = SsdSim::new(SsdConfig::pm883(), clock);
        let hm = HostMemory::new(cache_pages * PAGE_SIZE);
        let cache = Arc::new(PageCache::new(hm));
        let storage = Storage::new(ssd, cache);
        let bytes: Vec<u8> = (0..64 * 1024u32).map(|i| (i % 251) as u8).collect();
        let file = SimFile::new(
            FileId::new(1, DataKind::Features),
            Arc::new(MemBacking::new(bytes)),
        );
        (storage, file)
    }

    #[test]
    fn buffered_read_returns_bytes_and_caches() {
        let (st, f) = setup(64);
        let mut buf = vec![0u8; 100];
        st.read_buffered(&f, 1000, &mut buf);
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b, ((1000 + i) % 251) as u8);
        }
        let reads_before = st.ssd.counters().reads.load(Ordering::Relaxed);
        st.read_buffered(&f, 1000, &mut buf); // same page: hit, no device read
        assert_eq!(st.ssd.counters().reads.load(Ordering::Relaxed), reads_before);
    }

    #[test]
    fn buffered_coalesces_sequential_misses() {
        let (st, f) = setup(64);
        let mut buf = vec![0u8; 8 * PAGE_SIZE as usize];
        st.read_buffered(&f, 0, &mut buf);
        // 8 missing contiguous pages = ONE device request.
        assert_eq!(st.ssd.counters().reads.load(Ordering::Relaxed), 1);
        assert_eq!(
            st.ssd.counters().read_bytes.load(Ordering::Relaxed),
            8 * PAGE_SIZE
        );
    }

    #[test]
    fn direct_read_bypasses_cache_and_aligns() {
        let (st, f) = setup(64);
        let mut buf = vec![0u8; 100]; // sub-sector
        st.read_direct(&f, 700, &mut buf);
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b, ((700 + i) % 251) as u8);
        }
        // 100 B at offset 700 spans sectors [512,1024) → 512-aligned = 512 B,
        // but range [700, 800) fits in one sector? 700..800 ⊂ [512,1024) → 512 B.
        assert_eq!(st.direct_stats().aligned_bytes.load(Ordering::Relaxed), 512);
        assert_eq!(st.direct_stats().useful_bytes.load(Ordering::Relaxed), 100);
        // No page cached.
        assert_eq!(st.cache.resident_bytes(), 0);
        // Re-read pays again (no cache).
        let reads_before = st.ssd.counters().reads.load(Ordering::Relaxed);
        st.read_direct(&f, 700, &mut buf);
        assert_eq!(st.ssd.counters().reads.load(Ordering::Relaxed), reads_before + 1);
    }

    #[test]
    fn buffered_write_charges_device() {
        let (st, f) = setup(64);
        st.write_buffered(&f, 0, 10 * PAGE_SIZE as usize);
        assert_eq!(st.ssd.counters().writes.load(Ordering::Relaxed), 1);
        // Pages are now resident: reading them back is free.
        let reads_before = st.ssd.counters().reads.load(Ordering::Relaxed);
        let mut buf = vec![0u8; PAGE_SIZE as usize];
        st.read_buffered(&f, 0, &mut buf);
        assert_eq!(st.ssd.counters().reads.load(Ordering::Relaxed), reads_before);
    }

    #[test]
    fn striped_charges_route_to_owning_device_and_aggregate() {
        let clock = Clock::new(0.02);
        let ssds: Vec<SsdSim> =
            (0..3).map(|_| SsdSim::new(SsdConfig::pm883(), clock.clone())).collect();
        let cache = Arc::new(PageCache::new(HostMemory::new(64 * PAGE_SIZE)));
        let st = Storage::new_striped(ssds, cache, 4096);
        let bytes: Vec<u8> = (0..64 * 1024u32).map(|i| (i % 251) as u8).collect();
        let f = SimFile::new(
            FileId::new(1, DataKind::Features),
            Arc::new(MemBacking::new(bytes)),
        );
        let io: &dyn IoBackend = &st;
        assert_eq!(io.stripe(), crate::storage::backing::StripeSpec::new(3, 4096));
        // Logical chunk 1 ([4096, 8192)) lives on device 1: a sub-sector
        // read inside it charges device 1 only, and the aggregate mirrors.
        let mut buf = vec![0u8; 100];
        io.read_direct(&f, 4096 + 700, &mut buf);
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b, ((4796 + i) % 251) as u8, "byte {i}");
        }
        assert_eq!(st.device(1).counters().reads.load(Ordering::Relaxed), 1);
        assert_eq!(st.device(0).counters().reads.load(Ordering::Relaxed), 0);
        assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 1);
        assert_eq!(io.io_counters().read_bytes.load(Ordering::Relaxed), 512);
        // Per-device engine charge routes to device 2, aggregate accrues.
        io.charge_multi_dev(2, 3, 4096);
        assert_eq!(st.device(2).counters().reads.load(Ordering::Relaxed), 3);
        assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 4);
        let snap = io.device_io_snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[1], (1, 512));
        assert_eq!(snap[2], (3, 4096));
        io.reset_io_stats();
        assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 0);
        assert_eq!(st.device(2).counters().reads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn trait_surface_matches_inherent_accounting() {
        // The IoBackend impl must charge exactly like the inherent methods
        // (the acceptance bar for `--backend sim` reproducing old outputs).
        let (st, f) = setup(64);
        let io: &dyn IoBackend = &st;
        let mut buf = vec![0u8; 100];
        io.read_direct(&f, 700, &mut buf);
        assert_eq!(io.direct_stats().aligned_bytes.load(Ordering::Relaxed), 512);
        assert_eq!(io.io_counters().read_bytes.load(Ordering::Relaxed), 512);
        io.charge_multi(3, 4096);
        assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 4);
        assert_eq!(io.io_counters().read_bytes.load(Ordering::Relaxed), 512 + 4096);
        io.reset_io_stats();
        assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 0);
        assert_eq!(io.sector(), 512);
        assert_eq!(io.name(), "sim");
    }
}
