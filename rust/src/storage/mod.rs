//! Storage substrate, organized around the pluggable [`IoBackend`] seam.
//!
//! Layering (top to bottom):
//!
//! * **Consumers** — extractors, samplers, the pipeline engine, every
//!   baseline — speak only [`api::IoBackend`] / [`api::AsyncIoEngine`].
//!   They never touch a device model or a cache directly; *the backend owns
//!   all charging* and consumers observe costs through
//!   [`IoBackend::io_counters`] / [`IoBackend::direct_stats`].
//! * **Backends** —
//!   [`engine::SimBackend`] (the default, `--backend sim`): simulated SSD
//!   ([`ssd::SsdSim`]) + simulated page cache ([`page_cache::PageCache`]),
//!   with the sim io_uring ([`uring::Uring`]) as its async engine; timing is
//!   charged by sleeping on a scaled clock, bytes are real.
//!   [`osfile::OsFileBackend`] (`--backend os`): real `pread` over
//!   [`backing::FileBacking`], the OS page cache as the buffered path, and a
//!   `pread` thread pool ([`osfile::PreadPool`]) as its async engine;
//!   charges degrade to pure accounting.
//! * **Backings** — where bytes live ([`backing`]): a real file, process
//!   memory, or a deterministic procedural generator. Both backends read
//!   through the same [`SimFile`] handle, so a dataset can move between
//!   them unchanged.
//!
//! What a backend must guarantee (alignment accounting, counter balance,
//! completion synchronization) is specified on [`api::IoBackend`] and
//! enforced for both implementations by `tests/backend_conformance.rs`.
//! Memory budgets ([`mem`]) and the PCIe link model ([`pcie`]) are
//! backend-independent substrate.

pub mod api;
pub mod backing;
pub mod engine;
pub mod mem;
pub mod osfile;
pub mod page_cache;
pub mod pcie;
pub mod ssd;
pub mod uring;

pub use api::{
    AsyncIoEngine, BackendKind, Cqe, DirectIoStats, IoBackend, IoMode, Sqe,
};
pub use backing::{Backing, BackingRef, FileBacking, MemBacking, ProceduralBacking};
pub use engine::{SimBackend, SimFile, Storage};
pub use mem::{DeviceMemory, HostMemory, OutOfMemory, Reservation};
pub use osfile::{OsFileBackend, PreadPool};
pub use page_cache::{DataKind, FileId, PageCache, PAGE_SIZE};
pub use pcie::{Pcie, PcieConfig};
pub use ssd::{SsdConfig, SsdCounters, SsdSim};
pub use uring::Uring;
