//! Storage substrate: simulated SSD + page cache + I/O engines + memory
//! budgets. Timing is simulated; bytes are real. See DESIGN.md §3.

pub mod backing;
pub mod engine;
pub mod mem;
pub mod page_cache;
pub mod pcie;
pub mod ssd;
pub mod uring;

pub use backing::{Backing, BackingRef, FileBacking, MemBacking, ProceduralBacking};
pub use engine::{SimFile, Storage};
pub use mem::{DeviceMemory, HostMemory, OutOfMemory, Reservation};
pub use page_cache::{DataKind, FileId, PageCache, PAGE_SIZE};
pub use pcie::{Pcie, PcieConfig};
pub use ssd::{SsdConfig, SsdSim};
pub use uring::{Cqe, IoBuf, IoMode, Sqe, Uring};
