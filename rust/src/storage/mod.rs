//! Storage substrate, organized around the pluggable [`IoBackend`] seam.
//!
//! Layering (top to bottom):
//!
//! * **Consumers** — extractors, samplers, the pipeline engine, every
//!   baseline — speak only [`api::IoBackend`] / [`api::AsyncIoEngine`].
//!   They never touch a device model or a cache directly; *the backend owns
//!   all charging* and consumers observe costs through
//!   [`IoBackend::io_counters`] / [`IoBackend::direct_stats`].
//! * **Backends** —
//!   [`engine::SimBackend`] (the default, `--backend sim`): simulated SSD
//!   ([`ssd::SsdSim`]) + simulated page cache ([`page_cache::PageCache`]),
//!   with the sim io_uring ([`uring::Uring`]) as its async engine; timing is
//!   charged by sleeping on a scaled clock, bytes are real.
//!   [`osfile::OsFileBackend`] (`--backend os`): real `pread` over
//!   [`backing::FileBacking`], the OS page cache as the buffered path
//!   (direct reads use a real `O_DIRECT` descriptor where the filesystem
//!   grants it, with graceful cached fallback), and a `pread` thread pool
//!   ([`osfile::PreadPool`]) as its async engine; charges degrade to pure
//!   accounting. Both async engines share one submit/harvest core
//!   ([`engine_core::EngineCore`]), so the SQ/CQ + counter ordering
//!   invariants live in exactly one place.
//! * **Backings** — where bytes live ([`backing`]): a real file, process
//!   memory, or a deterministic procedural generator. Both backends read
//!   through the same [`SimFile`] handle, so a dataset can move between
//!   them unchanged.
//!
//! ## Segment-granular requests
//!
//! Async requests ([`api::Sqe`]) are **segment-granular**: one SQE names a
//! single contiguous `[offset, offset+len)` span that may cover several
//! feature rows merged by the extractor's coalescing planner
//! ([`crate::extract::coalesce`]). Ownership is split deliberately:
//!
//! * **The submitter owns the row table.** Engines never see which rows
//!   live inside a segment — they serve one contiguous read into one
//!   staging range and complete it; the extractor scatters rows out of the
//!   completed range. This keeps the engine contract minimal (and a future
//!   real-io_uring engine trivial).
//! * **The backend owns segment accounting.** A direct segment goes through
//!   [`IoBackend::read_direct_segment_nocharge`], which records one
//!   request, `Sqe::useful` useful bytes (Σ row bytes) and the
//!   sector-aligned span as aligned bytes; the engine then pairs it with
//!   one [`IoBackend::charge_multi`] op. So merged rows pay one IOPS and
//!   one span — duplicate-sector redundancy disappears from both the
//!   charges and [`api::DirectIoStats`], and bridged gap bytes show up
//!   honestly as alignment overhead.
//!
//! What a backend must guarantee (alignment accounting, counter balance,
//! completion synchronization) is specified on [`api::IoBackend`] and
//! enforced for both implementations by `tests/backend_conformance.rs`
//! (including the coalescing suite: byte parity, strictly fewer charged
//! requests, gap-boundary behavior). Memory budgets ([`mem`]) and the PCIe
//! link model ([`pcie`]) are backend-independent substrate.

pub mod api;
pub mod backing;
pub mod engine;
pub mod engine_core;
pub mod mem;
pub mod osfile;
pub mod page_cache;
pub mod pcie;
pub mod ssd;
pub mod uring;

pub use api::{
    AsyncIoEngine, BackendKind, Cqe, DirectIoStats, EpochIoSnapshot, EpochIoTotals, IoBackend,
    IoMode, Sqe,
};
pub use backing::{Backing, BackingRef, FileBacking, MemBacking, ProceduralBacking};
pub use engine::{SimBackend, SimFile, Storage};
pub use engine_core::{EngineCore, WorkerPort};
pub use mem::{DeviceMemory, HostMemory, OutOfMemory, Reservation};
pub use osfile::{OsFileBackend, PreadPool};
pub use page_cache::{DataKind, FileId, PageCache, PAGE_SIZE};
pub use pcie::{Pcie, PcieConfig};
pub use ssd::{SsdConfig, SsdCounters, SsdSim};
pub use uring::Uring;
