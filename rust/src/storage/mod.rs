//! Storage substrate, organized around the pluggable [`IoBackend`] seam.
//!
//! Layering (top to bottom):
//!
//! * **Consumers** — extractors, samplers, the pipeline engine, every
//!   baseline — speak only [`api::IoBackend`] / [`api::AsyncIoEngine`].
//!   They never touch a device model or a cache directly; *the backend owns
//!   all charging* and consumers observe costs through
//!   [`IoBackend::io_counters`] / [`IoBackend::direct_stats`].
//! * **Backends** —
//!   [`engine::SimBackend`] (the default, `--backend sim`): simulated SSD
//!   ([`ssd::SsdSim`]) + simulated page cache ([`page_cache::PageCache`]),
//!   with the sim io_uring ([`uring::Uring`]) as its async engine; timing is
//!   charged by sleeping on a scaled clock, bytes are real.
//!   [`osfile::OsFileBackend`] (`--backend os`): real `pread` over
//!   [`backing::FileBacking`], the OS page cache as the buffered path
//!   (direct reads use a real `O_DIRECT` descriptor where the filesystem
//!   grants it, with graceful cached fallback), and a `pread` thread pool
//!   ([`osfile::PreadPool`]) as its async engine; charges degrade to pure
//!   accounting. `--backend uring` is the same `OsFileBackend` surface with
//!   the genuine kernel ring ([`uring_os::UringEngine`]) as its async
//!   engine. All three async engines share one submit/harvest core
//!   ([`engine_core::EngineCore`]), so the SQ/CQ + counter ordering
//!   invariants live in exactly one place.
//!
//! ## The three-engine contract (`--backend sim | os | uring`)
//!
//! Every async engine is [`engine_core::EngineCore`] plus a worker policy;
//! the engine-specific part is only *how a popped SQE turns into bytes*:
//!
//! * [`uring::Uring`] (sim) — workers sleep out simulated device time and
//!   copy from the backing.
//! * [`osfile::PreadPool`] (os) — workers issue one positional `pread` per
//!   SQE through `serve_sqe` (bounded retries, deadline, panic containment).
//! * [`uring_os::UringEngine`] (uring) — workers batch SQEs into a real
//!   kernel io_uring: raw `io_uring_setup`/`enter`/`register` syscalls
//!   (inline asm; the build links no libc), mmap'd SQ/CQ rings, one private
//!   ring per worker.
//!
//! Ownership and fallback rules for the kernel engine:
//!
//! * **Ring memory is worker-owned.** Each worker thread creates, mmaps,
//!   and drops its own ring; `EngineCore` never sees kernel memory. Engine
//!   drop closes the core, joins workers, and the rings unmap with them.
//! * **fd translation is backend-owned.** [`IoBackend::uring_target`] maps
//!   `(file, offset, len)` to a real `(fd, physical_offset)` only when the
//!   whole span lies in one OS file; the fd stays owned by the backing.
//!   `None` (sim files, fault wrappers with an active plan, spans
//!   straddling stripe members) routes that SQE through the `serve_sqe`
//!   fallback inside the same worker — per-request, not per-engine.
//! * **Registered buffers borrow the staging arena.** The extractor
//!   advertises the arena range via
//!   [`api::AsyncIoEngine::register_buffer_range`]; workers register it as
//!   fixed buffer 0 and use `READ_FIXED` when a destination lies inside.
//!   The caller guarantees the arena outlives the engine (it does: the
//!   extractor drops engines before buffers). Registration failure
//!   (`RLIMIT_MEMLOCK`) is sticky and silently downgrades to plain `READ`.
//! * **Probe, then fall back typed.** `--backend uring` is gated by
//!   [`uring_os::probe_uring`] (ring setup + NOP round-trip) at machine
//!   build; a failed probe warns once and builds the `os` pread stack
//!   instead, so CI on kernels without io_uring passes identically. A ring
//!   that fails *after* a good probe (seccomp, fd limits) degrades that
//!   worker to the pread loop with a one-time warning — the engine
//!   contract, accounting, and fault matrix are engine-path-independent.
//! * **Backings** — where bytes live ([`backing`]): a real file, process
//!   memory, or a deterministic procedural generator. Both backends read
//!   through the same [`SimFile`] handle, so a dataset can move between
//!   them unchanged.
//!
//! ## Striping (`--devices N`, `--stripe-bytes B`)
//!
//! The stack stripes a logical byte range RAID-0-style across `N` physical
//! devices in `B`-byte chunks. [`backing::StripeSpec`] is the *single owner
//! of offset translation* — every layer asks it, none re-derives the math:
//!
//! * **Backings route bytes.** [`backing::StripedBacking`] holds `N` member
//!   backings and splits a logical read at chunk boundaries
//!   (`StripeSpec::split`), delegating each run to the owning member at its
//!   device-local offset. Consumers and the `SimFile` handle still see one
//!   flat logical file.
//! * **Backends route charges.** A backend advertises its geometry via
//!   [`IoBackend::stripe`] and accepts device-attributed charges via
//!   [`IoBackend::charge_multi_dev`]; `charge_multi` remains the
//!   device-agnostic form (and the two are identical at `--devices 1`).
//!   [`engine::SimBackend`] holds one [`ssd::SsdSim`] *per device*, so
//!   charged latency reflects `N` independent IOPS/queue-depth ceilings;
//!   [`osfile::OsFileBackend`] keeps per-device [`ssd::SsdCounters`]
//!   breakdowns. Aggregate counters stay the `io_counters` surface;
//!   [`IoBackend::device_io_snapshot`] exposes the per-device split.
//! * **Engines route SQEs.** [`engine_core::EngineCore`] keeps one
//!   submission sub-queue per device, each with the *full* `--io-depth`
//!   budget, and routes each [`api::Sqe`] by `StripeSpec::device_of` on its
//!   logical offset. Workers bind to one device's sub-queue, so a slow or
//!   faulted device backs up only its own queue. The submit/inflight/
//!   harvest counter discipline and poison/drain guarantees hold globally
//!   *and* per device.
//! * **The planner keeps segments inside one chunk.** The coalescing
//!   planner ([`crate::extract::coalesce`]) refuses to merge rows across a
//!   `StripeSpec::chunk_end` boundary, so a planned segment maps to exactly
//!   one device and the engine pairs its completion with one
//!   `charge_multi_dev(dev, ..)` on that device. The one exception is a
//!   *single row* wider than a chunk: it becomes its own segment spanning
//!   the minimal run of devices, served through the (striped) backing, and
//!   its charge lands on the device owning its starting offset — an
//!   accepted approximation, flagged in the planner docs. Per-device
//!   segment lists are interleaved round-robin at submit so all queues fill
//!   concurrently instead of device 0 first.
//!
//! `--devices 1` is the degenerate stripe (`StripeSpec::single()`): chunk
//! boundaries vanish (`chunk_end = u64::MAX`), every offset maps to device
//! 0, and charging/planning are byte-for-byte identical to the pre-striping
//! stack — `benches/stripe_scaling.rs` gates on that parity.
//!
//! ## Segment-granular requests
//!
//! Async requests ([`api::Sqe`]) are **segment-granular**: one SQE names a
//! single contiguous `[offset, offset+len)` span that may cover several
//! feature rows merged by the extractor's coalescing planner
//! ([`crate::extract::coalesce`]). Ownership is split deliberately:
//!
//! * **The submitter owns the row table.** Engines never see which rows
//!   live inside a segment — they serve one contiguous read into one
//!   staging range and complete it; the extractor scatters rows out of the
//!   completed range. This keeps the engine contract minimal — it is what
//!   let the real-io_uring engine slot in as just another worker loop.
//! * **The backend owns segment accounting.** A direct segment goes through
//!   [`IoBackend::read_direct_segment_nocharge`], which records one
//!   request, `Sqe::useful` useful bytes (Σ row bytes) and the
//!   sector-aligned span as aligned bytes; the engine then pairs it with
//!   one [`IoBackend::charge_multi`] op. So merged rows pay one IOPS and
//!   one span — duplicate-sector redundancy disappears from both the
//!   charges and [`api::DirectIoStats`], and bridged gap bytes show up
//!   honestly as alignment overhead.
//!
//! ## Packed layout (`pack` → `train --packed`)
//!
//! The packed on-disk layout ([`crate::layout`]) changes *what* is read,
//! never *how* — the storage stack is unchanged and unaware of it. The
//! ownership split:
//!
//! * **`layout/` owns the pack index.** [`crate::layout::PackedLayout`]
//!   maps `(epoch, batch_id, node)` to byte offsets in the pack file
//!   (`packs.bin[.d]`, opened as one [`SimFile`] over a [`backing::FileBacking`]
//!   or [`backing::StripedBacking`]) and the hot file (`hot.bin`). The
//!   stripe geometry the pack was written under is recorded in `meta.toml`
//!   and handshaken at load — exactly the dataset geometry contract.
//! * **Packed segments charge like any other segment.** The extractor plans
//!   a packed batch's run with the same stripe-aware planner (wide-gap
//!   config over the run's contiguous offsets), and each resulting SQE
//!   names the pack/hot `SimFile` instead of the feature table. Engines and
//!   backends see ordinary segment-granular direct reads: one request, one
//!   `charge_multi_dev` on the owning device, useful = Σ row bytes, aligned
//!   span as alignment overhead. Run starts are pre-aligned to the stripe
//!   chunk (striped) or sector (unstriped) by the packer, so packed
//!   segments carry less alignment overhead than the online plan's
//!   scattered rows — the bench gate in `benches/layout_pack.rs`.
//! * **Hot-tier pins charge sequential reads.** [`crate::layout::pin_hot`]
//!   loads `hot.bin` front to back at attach time through
//!   [`IoBackend::charge_read`] — large sequential charges, once per run,
//!   not per epoch. Under `--tier gpu` the hottest rows are pinned into the
//!   device tier first ([`crate::layout::pin_hot_gpu`]): the SSD read is
//!   still charged here, and the host→device upload is charged separately
//!   to [`pcie`] by the tier layer.
//!
//! ## Tiered placement (`--tier`, [`crate::tier`])
//!
//! The GPU hot tier sits entirely *above* this substrate; the charging
//! contract:
//!
//! * **Backends never see the tier.** A GPU-tier hit performs no storage
//!   operation at all — nothing lands in [`IoBackend::io_counters`] or
//!   [`api::DirectIoStats`]. Only host-tier misses reach the backend, as
//!   ordinary (segment-granular, striped, retried) reads.
//! * **The tier layer owns PCIe charging.** Promotions, pinned-layout
//!   uploads, and `--gpu-oversub` fault migrations charge the [`pcie`] link
//!   model directly and accrue in the tier's own snapshot
//!   (`pcie_tier_bytes`), never in storage counters; avoided host→device
//!   batch transfers accrue as `pcie_saved_bytes`.
//! * **`--tier host` is charge-identical.** With the host tier selected the
//!   store delegates every call, so charged requests, bytes, and
//!   buffer-reuse counters are exactly those of the pre-tier stack — the
//!   parity gate in `benches/tier_placement.rs`.
//!
//! ## Error contract
//!
//! I/O failure is a *typed completion*, never a panic and never a hang.
//! The contract, layer by layer:
//!
//! * **A [`api::Cqe`] error means "bytes undefined, ownership unchanged".**
//!   `Cqe::status` is `Ok(bytes)` (the staging range holds the true backing
//!   bytes) or `Err(`[`api::IoError`]`)` (the range contents must not be
//!   decoded). Either way the submitter still owns the staging range and
//!   must release/recycle it through the normal wave protocol — an error
//!   frees no resources by itself.
//! * **Engines own retries.** The shared service loop
//!   (`engine_core::serve_sqe`) re-issues failed attempts per the backend's
//!   [`api::RetryPolicy`] (bounded retries, exponential backoff with
//!   deterministic jitter, optional per-request deadline). Only the *final*
//!   verdict reaches the CQE; consumers never retry individual SQEs — they
//!   decide batch-level policy (retry the batch, drop the rows, abort) via
//!   `--on-io-error`.
//! * **Retried I/O is re-charged honestly.** Each attempt goes back through
//!   the backend's read path, so device ops/bytes in
//!   [`IoBackend::io_counters`] accrue *per attempt* (the fault wrapper
//!   charges failed attempts itself). [`api::DirectIoStats`] alignment
//!   counters record only *delivered* data; `retries`/`failures`/
//!   `direct_fallbacks` on the same struct count policy re-issues, given-up
//!   requests and `O_DIRECT`→cached fallbacks, and flow per-epoch into
//!   `EpochStats`.
//! * **Worker panics are contained.** A panic while serving one SQE becomes
//!   [`api::IoError::Internal`] on that completion and the engine keeps
//!   serving. A worker unwinding past its loop *poisons* the engine:
//!   harvesters and [`api::AsyncIoEngine::drain`] then return synthetic
//!   [`api::IoError::EnginePoisoned`] completions (tagged
//!   [`api::Cqe::POISON_USER_DATA`]) instead of hanging, and counters
//!   reconcile so `drain` always quiesces.
//! * **Faults are injectable and deterministic.** [`fault::FaultInjectBackend`]
//!   wraps either backend with a seeded [`fault::FaultPlan`] (transient
//!   errors, bad ranges, short reads, stalls) keyed on `(offset, cumulative
//!   try#)` — engine retries and batch-level re-extracts continue an
//!   offset's draw sequence — so chaos tests replay exactly; `--fault-*`
//!   CLI flags construct it. On a striped array, `--fault-device i`
//!   restricts the storm to reads whose *logical* offset maps to device
//!   `i`; the filter runs before a try draw is consumed, so the plan stays
//!   keyed on logical `(offset, try#)` and replay determinism is unchanged.
//!
//! What a backend must guarantee (alignment accounting, counter balance,
//! completion synchronization) is specified on [`api::IoBackend`] and
//! enforced for both implementations by `tests/backend_conformance.rs`
//! (including the coalescing suite: byte parity, strictly fewer charged
//! requests, gap-boundary behavior) and `tests/fault_injection.rs` (the
//! chaos suite: seeded fault storms end-to-end). Memory budgets ([`mem`])
//! and the PCIe link model ([`pcie`]) are backend-independent substrate.

pub mod api;
pub mod backing;
pub mod engine;
pub mod engine_core;
pub mod fault;
pub mod mem;
pub mod osfile;
pub mod page_cache;
pub mod pcie;
pub mod ssd;
pub mod uring;
pub mod uring_os;

pub use api::{
    AsyncIoEngine, BackendKind, Cqe, DirectIoStats, EpochIoSnapshot, EpochIoTotals, IoBackend,
    IoError, IoMode, RetryPolicy, Sqe,
};
pub use fault::{FaultInjectBackend, FaultInjectEngine, FaultPlan};
pub use backing::{
    Backing, BackingRef, FileBacking, MemBacking, ProceduralBacking, StripeSpec, StripedBacking,
};
pub use engine::{SimBackend, SimFile, Storage};
pub use engine_core::{EngineCore, WorkerPort};
pub use mem::{DeviceMemory, HostMemory, OutOfMemory, Reservation};
pub use osfile::{OsFileBackend, PreadPool};
pub use page_cache::{DataKind, FileId, PageCache, PAGE_SIZE};
pub use pcie::{Pcie, PcieConfig};
pub use ssd::{SsdConfig, SsdCounters, SsdSim};
pub use uring::Uring;
pub use uring_os::{probe_uring, UringEngine};
