//! PCIe transfer model: host↔device copies (staging buffer → GPU feature
//! buffer). Supports synchronous transfers and CUDA-style asynchronous
//! transfers executed by a small copy-engine pool, so an extractor can
//! overlap the transfer of node *i* with the SSD load of node *i+1*
//! (the paper's two-phase asynchronous extraction, §4.2 / Fig 5).

use crate::sim::queue::BoundedQueue;
use crate::sim::{Clock, TokenBucket};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct PcieConfig {
    /// Effective host→device bandwidth, bytes/second.
    pub bandwidth: f64,
    /// Per-transfer launch latency (driver + DMA setup).
    pub latency: Duration,
    /// Copy-engine concurrency (CUDA GPUs expose 1–2 copy engines).
    pub engines: usize,
}

impl PcieConfig {
    /// PCIe 3.0 x16 as on the paper's RTX 3090 box (~12 GB/s effective).
    pub fn gen3_x16() -> Self {
        PcieConfig { bandwidth: 12e9, latency: Duration::from_micros(10), engines: 2 }
    }

    /// The K80 machine of Fig 13 (shared, older topology; ~8 GB/s).
    pub fn k80() -> Self {
        PcieConfig { bandwidth: 8e9, latency: Duration::from_micros(15), engines: 1 }
    }
}

struct Job {
    bytes: usize,
    /// Runs after the simulated transfer time has been charged — performs
    /// the real memcpy and any completion bookkeeping (e.g. valid-bit set).
    on_done: Box<dyn FnOnce() + Send>,
}

/// Shared state between the `Pcie` handle and its copy-engine threads.
struct Link {
    cfg: PcieConfig,
    clock: Clock,
    bw: TokenBucket,
    queue: BoundedQueue<Job>,
    transferred: AtomicU64,
    transfers: AtomicU64,
}

impl Link {
    fn charge(&self, bytes: usize) {
        let _io = crate::metrics::state::enter(crate::metrics::state::State::Io);
        self.bw.acquire(bytes as f64);
        self.clock.sleep(self.cfg.latency);
        self.transferred.fetch_add(bytes as u64, Ordering::Relaxed);
        self.transfers.fetch_add(1, Ordering::Relaxed);
    }
}

/// Shared PCIe link + asynchronous copy engines.
pub struct Pcie {
    link: Arc<Link>,
    engines: Vec<JoinHandle<()>>,
}

impl Pcie {
    pub fn new(cfg: PcieConfig, clock: Clock) -> Arc<Self> {
        let link = Arc::new(Link {
            bw: TokenBucket::new(clock.clone(), cfg.bandwidth, 4.0 * 1024.0 * 1024.0),
            queue: BoundedQueue::new(4096),
            transferred: AtomicU64::new(0),
            transfers: AtomicU64::new(0),
            cfg: cfg.clone(),
            clock,
        });
        let engines = (0..cfg.engines.max(1))
            .map(|_| {
                let link = link.clone();
                std::thread::spawn(move || {
                    crate::metrics::state::register(crate::metrics::state::Role::IoWorker);
                    while let Ok(job) = link.queue.pop() {
                        link.charge(job.bytes);
                        (job.on_done)();
                    }
                    crate::metrics::state::deregister();
                })
            })
            .collect();
        Arc::new(Pcie { link, engines })
    }

    /// Synchronous transfer: blocks the caller for the simulated time.
    pub fn transfer_sync(&self, bytes: usize) {
        self.link.charge(bytes);
    }

    /// Asynchronous transfer: enqueue; `on_done` runs on a copy engine after
    /// the transfer time has elapsed (performing the real copy).
    pub fn transfer_async(&self, bytes: usize, on_done: impl FnOnce() + Send + 'static) {
        self.link
            .queue
            .push(Job { bytes, on_done: Box::new(on_done) })
            .expect("pcie engine stopped");
    }

    pub fn bytes_transferred(&self) -> u64 {
        self.link.transferred.load(Ordering::Relaxed)
    }

    pub fn transfer_count(&self) -> u64 {
        self.link.transfers.load(Ordering::Relaxed)
    }

    /// Close the engine queue and join workers (tests; normally process-long).
    pub fn shutdown(&self) {
        self.link.queue.close();
    }
}

impl Drop for Pcie {
    fn drop(&mut self) {
        self.link.queue.close();
        for h in self.engines.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Latch;
    use std::time::Instant;

    #[test]
    fn sync_transfer_charges_time() {
        let clock = Clock::new(1.0);
        let pcie = Pcie::new(
            PcieConfig { bandwidth: 1e6, latency: Duration::from_millis(1), engines: 1 },
            clock,
        );
        let t0 = Instant::now();
        pcie.transfer_sync(100_000); // 0.1 s at 1 MB/s... minus 4 MiB burst
        pcie.transfer_sync(100_000);
        // The burst covers the first transfers; do enough to exceed it.
        for _ in 0..8 {
            pcie.transfer_sync(1_000_000);
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 1.0, "dt={dt}");
        assert_eq!(pcie.transfer_count(), 10);
    }

    #[test]
    fn async_transfers_complete_and_run_callbacks() {
        let clock = Clock::new(1.0);
        let pcie = Pcie::new(PcieConfig::gen3_x16(), clock);
        let latch = Arc::new(Latch::new(16));
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..16 {
            let latch = latch.clone();
            let hits = hits.clone();
            pcie.transfer_async(512, move || {
                hits.fetch_add(1, Ordering::Relaxed);
                latch.count_down();
            });
        }
        latch.wait();
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        assert_eq!(pcie.bytes_transferred(), 16 * 512);
    }

    #[test]
    fn async_overlaps_with_caller() {
        // The caller should be able to enqueue N slow transfers in far less
        // time than they take to execute.
        let clock = Clock::new(1.0);
        let pcie = Pcie::new(
            PcieConfig { bandwidth: 50e6, latency: Duration::from_millis(2), engines: 1 },
            clock,
        );
        let latch = Arc::new(Latch::new(10));
        let t0 = Instant::now();
        for _ in 0..10 {
            let latch = latch.clone();
            pcie.transfer_async(4096, move || latch.count_down());
        }
        let enqueue_time = t0.elapsed();
        latch.wait();
        let total_time = t0.elapsed();
        assert!(enqueue_time < total_time / 2, "{enqueue_time:?} vs {total_time:?}");
    }
}
