//! SSD device model.
//!
//! Timing-accurate simulation of a SATA/NVMe SSD in front of a real
//! [`Backing`](super::backing::Backing) store. Three limits shape every
//! request, mirroring how a real device behaves under fio (Appendix B of the
//! paper):
//!
//! * **per-request latency** — media + interface time, charged by sleeping;
//!   overlapped requests hide it, which is the async-I/O win;
//! * **IOPS ceiling** — a token bucket in operations/second (random small
//!   reads are IOPS-bound: 512 B feature rows on a PM883-class disk);
//! * **bandwidth ceiling** — a token bucket in bytes/second (large/sequential
//!   reads are bandwidth-bound);
//!
//! plus a bounded **device queue depth** (NCQ) limiting in-flight requests.
//! Defaults approximate the paper's SAMSUNG PM883 (§5); `k80_machine` in
//! [`crate::config`] models the older Intel DC S3510 of Fig 13.

use crate::sim::{Clock, Semaphore, TokenBucket};
use crate::util::stats::LatencyHist;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct SsdConfig {
    /// Read bandwidth ceiling, bytes/second.
    pub read_bw: f64,
    /// Write bandwidth ceiling, bytes/second.
    pub write_bw: f64,
    /// Per-request service latency (media + interface).
    pub latency: Duration,
    /// Random-read IOPS ceiling.
    pub iops: f64,
    /// Device queue depth (max in-flight requests).
    pub queue_depth: usize,
    /// Sector size; direct I/O must align to this.
    pub sector: usize,
}

impl SsdConfig {
    /// SAMSUNG PM883-class SATA SSD (the paper's testbed drive).
    pub fn pm883() -> Self {
        SsdConfig {
            read_bw: 520e6,
            write_bw: 480e6,
            latency: Duration::from_micros(90),
            iops: 97_000.0,
            queue_depth: 32,
            sector: 512,
        }
    }

    /// Intel DC S3510-class SATA SSD (the Fig 13 multi-GPU machine).
    pub fn s3510() -> Self {
        SsdConfig {
            read_bw: 500e6,
            write_bw: 440e6,
            latency: Duration::from_micros(110),
            iops: 68_000.0,
            queue_depth: 32,
            sector: 512,
        }
    }
}

/// Running counters, attributable per data kind (topology vs features),
/// which the memory-contention analysis of Fig 2 relies on.
#[derive(Debug, Default)]
pub struct SsdCounters {
    pub reads: AtomicU64,
    pub read_bytes: AtomicU64,
    pub writes: AtomicU64,
    pub write_bytes: AtomicU64,
}

impl SsdCounters {
    /// Tally `ops` reads totalling `bytes` (striped backends mirror member
    /// charges into an aggregate counter through this).
    pub fn add_read(&self, ops: u64, bytes: u64) {
        self.reads.fetch_add(ops, Ordering::Relaxed);
        self.read_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Tally `ops` writes totalling `bytes`.
    pub fn add_write(&self, ops: u64, bytes: u64) {
        self.writes.fetch_add(ops, Ordering::Relaxed);
        self.write_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Zero every counter.
    pub fn reset(&self) {
        self.reads.store(0, Ordering::Relaxed);
        self.read_bytes.store(0, Ordering::Relaxed);
        self.writes.store(0, Ordering::Relaxed);
        self.write_bytes.store(0, Ordering::Relaxed);
    }

    /// `(reads, read_bytes)` snapshot.
    pub fn read_snapshot(&self) -> (u64, u64) {
        (self.reads.load(Ordering::Relaxed), self.read_bytes.load(Ordering::Relaxed))
    }
}

/// The simulated device. Cheap to clone (shared state).
#[derive(Clone)]
pub struct SsdSim {
    inner: Arc<Inner>,
}

struct Inner {
    cfg: SsdConfig,
    clock: Clock,
    slots: Semaphore,
    read_bw: TokenBucket,
    write_bw: TokenBucket,
    iops: TokenBucket,
    counters: SsdCounters,
    lat_hist: Mutex<LatencyHist>,
}

impl SsdSim {
    pub fn new(cfg: SsdConfig, clock: Clock) -> Self {
        let read_bw = TokenBucket::new(clock.clone(), cfg.read_bw, 256.0 * 1024.0);
        let write_bw = TokenBucket::new(clock.clone(), cfg.write_bw, 256.0 * 1024.0);
        // IOPS burst ≈ one queue depth's worth keeps short bursts cheap while
        // sustained load converges to the ceiling.
        let iops = TokenBucket::new(clock.clone(), cfg.iops, cfg.queue_depth as f64);
        SsdSim {
            inner: Arc::new(Inner {
                slots: Semaphore::new(cfg.queue_depth),
                read_bw,
                write_bw,
                iops,
                counters: SsdCounters::default(),
                lat_hist: Mutex::new(LatencyHist::default()),
                cfg,
                clock,
            }),
        }
    }

    pub fn config(&self) -> &SsdConfig {
        &self.inner.cfg
    }

    pub fn clock(&self) -> &Clock {
        &self.inner.clock
    }

    pub fn counters(&self) -> &SsdCounters {
        &self.inner.counters
    }

    pub fn latency_hist(&self) -> LatencyHist {
        self.inner.lat_hist.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        self.inner.counters.reset();
        *self.inner.lat_hist.lock().unwrap() = LatencyHist::default();
    }

    /// Charge the time for one read of `len` bytes. Blocks the calling
    /// thread for the simulated service duration. The caller copies the data
    /// from the backing store itself (the device model is timing-only).
    pub fn read(&self, len: usize) -> Duration {
        let t0 = Instant::now();
        {
            let _state = crate::metrics::state::enter(crate::metrics::state::State::Io);
            let _slot = self.inner.slots.guard();
            self.inner.iops.acquire(1.0);
            self.inner.read_bw.acquire(len as f64);
            self.inner.clock.sleep(self.inner.cfg.latency);
        }
        let sim = self.inner.clock.to_sim(t0.elapsed());
        self.inner.counters.reads.fetch_add(1, Ordering::Relaxed);
        self.inner.counters.read_bytes.fetch_add(len as u64, Ordering::Relaxed);
        self.inner.lat_hist.lock().unwrap().record(sim);
        sim
    }

    /// Charge the time for a coalesced batch of `ops` reads totalling
    /// `bytes`. One device slot and one latency period cover the batch
    /// (NCQ-style coalescing used by the async engine to amortize
    /// bookkeeping); the IOPS and bandwidth buckets are charged in full, so
    /// sustained throughput is identical to per-op charging.
    pub fn read_multi(&self, ops: u64, bytes: usize) -> Duration {
        if ops == 0 {
            return Duration::ZERO;
        }
        let t0 = Instant::now();
        {
            let _state = crate::metrics::state::enter(crate::metrics::state::State::Io);
            let _slot = self.inner.slots.guard();
            self.inner.iops.acquire(ops as f64);
            self.inner.read_bw.acquire(bytes as f64);
            self.inner.clock.sleep(self.inner.cfg.latency);
        }
        let sim = self.inner.clock.to_sim(t0.elapsed());
        self.inner.counters.reads.fetch_add(ops, Ordering::Relaxed);
        self.inner.counters.read_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.inner.lat_hist.lock().unwrap().record(sim);
        sim
    }

    /// Charge the time for one write of `len` bytes.
    pub fn write(&self, len: usize) -> Duration {
        let t0 = Instant::now();
        {
            let _state = crate::metrics::state::enter(crate::metrics::state::State::Io);
            let _slot = self.inner.slots.guard();
            self.inner.iops.acquire(1.0);
            self.inner.write_bw.acquire(len as f64);
            self.inner.clock.sleep(self.inner.cfg.latency);
        }
        let sim = self.inner.clock.to_sim(t0.elapsed());
        self.inner.counters.writes.fetch_add(1, Ordering::Relaxed);
        self.inner.counters.write_bytes.fetch_add(len as u64, Ordering::Relaxed);
        self.inner.lat_hist.lock().unwrap().record(sim);
        sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_ssd() -> SsdSim {
        // Compressed time so tests are quick but the ratios hold.
        let clock = Clock::new(0.2);
        SsdSim::new(SsdConfig::pm883(), clock)
    }

    #[test]
    fn single_thread_sync_is_latency_bound() {
        let ssd = fast_ssd();
        let n = 100;
        let t0 = Instant::now();
        for _ in 0..n {
            ssd.read(512);
        }
        let sim = ssd.clock().to_sim(t0.elapsed());
        let per_req = sim / n;
        // ~latency per request (90us) once the IOPS burst is used up;
        // single-core scheduling noise allows a generous upper band.
        assert!(per_req >= Duration::from_micros(55), "per_req={per_req:?}");
        assert!(per_req < Duration::from_micros(500), "per_req={per_req:?}");
    }

    #[test]
    fn parallel_requests_hide_latency_until_iops_cap() {
        // Comparative (robust to single-core scheduling noise): the same
        // request count with 16 threads must be much faster than with one,
        // and aggregate throughput must not exceed the device IOPS ceiling.
        // Runs at scale 1.0: compressed time amplifies the (real) per-op
        // bookkeeping cost relative to (scaled) device time.
        let ssd = SsdSim::new(SsdConfig::pm883(), Clock::new(1.0));
        let total = 160usize;

        let t0 = Instant::now();
        for _ in 0..total {
            ssd.read(512);
        }
        let serial = ssd.clock().to_sim(t0.elapsed());

        let threads = 16;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let ssd = ssd.clone();
                std::thread::spawn(move || {
                    for _ in 0..total / 16 {
                        ssd.read(512);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let parallel = ssd.clock().to_sim(t0.elapsed());
        let iops = total as f64 / parallel.as_secs_f64();

        assert!(
            parallel.as_secs_f64() < serial.as_secs_f64() * 0.55,
            "parallel {parallel:?} not ≪ serial {serial:?}"
        );
        assert!(iops < 140_000.0, "iops above device ceiling: {iops}");
    }

    #[test]
    fn large_reads_are_bandwidth_bound() {
        let ssd = fast_ssd();
        let n = 10;
        let chunk = 4 << 20; // 4 MiB
        let t0 = Instant::now();
        for _ in 0..n {
            ssd.read(chunk);
        }
        let sim = ssd.clock().to_sim(t0.elapsed()).as_secs_f64();
        let bw = (n * chunk) as f64 / sim;
        assert!(bw < 620e6, "bw={bw}");
        assert!(bw > 300e6, "bw={bw}");
    }

    #[test]
    fn counters_accumulate() {
        let ssd = fast_ssd();
        ssd.read(512);
        ssd.write(1024);
        assert_eq!(ssd.counters().reads.load(Ordering::Relaxed), 1);
        assert_eq!(ssd.counters().read_bytes.load(Ordering::Relaxed), 512);
        assert_eq!(ssd.counters().write_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(ssd.latency_hist().count(), 2);
        ssd.reset_stats();
        assert_eq!(ssd.counters().reads.load(Ordering::Relaxed), 0);
    }
}
