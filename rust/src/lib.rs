//! GNNDrive — a reproduction of *Reducing Memory Contention and I/O
//! Congestion for Disk-based GNN Training* (ICPP '24) as a three-layer
//! Rust + JAX + Pallas system. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod baselines;
pub mod bench;
pub mod config;
pub mod experiments;
pub mod graph;
pub mod layout;
pub mod metrics;
pub mod extract;
pub mod membuf;
pub mod parallel;
pub mod pipeline;
pub mod runtime;
pub mod sample;
pub mod serve;
pub mod tier;
pub mod train;
pub mod sim;
pub mod storage;
pub mod util;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
