//! Thread-state registry backing the CPU/GPU-utilization and I/O-wait
//! timelines (paper Figures 3 and 11).
//!
//! Worker threads register themselves with a role; the storage and compute
//! substrates flip the calling thread's state (`Busy` ⇄ `Io` ⇄ `Idle`)
//! through RAII scopes. A sampler thread (see [`crate::metrics::timeline`])
//! periodically snapshots all registered threads to produce the utilization
//! traces. Unregistered threads (tests, main) are no-ops.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum State {
    /// Blocked on a queue or waiting for work.
    Idle = 0,
    /// Doing CPU work (sampling, bookkeeping, training-side CPU work).
    Busy = 1,
    /// Blocked on (simulated) storage or PCIe.
    Io = 2,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Sampler,
    Extractor,
    Trainer,
    Releaser,
    IoWorker,
    /// Serving-frontend worker (sample → extract → forward for inference
    /// micro-batches); counts as ordinary CPU/I-O in utilization snapshots.
    Server,
    Other,
}

struct ThreadSlot {
    state: Arc<AtomicU8>,
    role: Role,
}

#[derive(Default)]
pub struct Registry {
    slots: Mutex<Vec<ThreadSlot>>,
    /// Set while the (simulated) accelerator is executing a kernel.
    gpu_busy: AtomicBool,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::default)
}

thread_local! {
    static MY_STATE: std::cell::RefCell<Option<Arc<AtomicU8>>> =
        const { std::cell::RefCell::new(None) };
}

/// Register the current thread under `role`. Threads created by the pipeline
/// call this once at startup; the handle lives until process exit (worker
/// counts are small and bounded).
pub fn register(role: Role) {
    let cell = Arc::new(AtomicU8::new(State::Busy as u8));
    registry().slots.lock().unwrap().push(ThreadSlot { state: cell.clone(), role });
    MY_STATE.with(|s| *s.borrow_mut() = Some(cell));
}

/// Deregister: mark the slot idle so a finished epoch's threads do not count.
pub fn deregister() {
    MY_STATE.with(|s| {
        if let Some(cell) = s.borrow_mut().take() {
            cell.store(State::Idle as u8, Ordering::Relaxed);
            let mut slots = registry().slots.lock().unwrap();
            slots.retain(|t| !Arc::ptr_eq(&t.state, &cell));
        }
    });
}

/// RAII scope setting the current thread's state, restoring on drop.
pub struct Scope {
    cell: Option<Arc<AtomicU8>>,
    prev: u8,
}

pub fn enter(state: State) -> Scope {
    MY_STATE.with(|s| {
        if let Some(cell) = s.borrow().as_ref() {
            let prev = cell.swap(state as u8, Ordering::Relaxed);
            Scope { cell: Some(cell.clone()), prev }
        } else {
            Scope { cell: None, prev: 0 }
        }
    })
}

impl Drop for Scope {
    fn drop(&mut self) {
        if let Some(cell) = &self.cell {
            cell.store(self.prev, Ordering::Relaxed);
        }
    }
}

/// RAII marker for simulated-GPU kernel execution.
pub struct GpuScope;

pub fn gpu_enter() -> GpuScope {
    registry().gpu_busy.store(true, Ordering::Relaxed);
    GpuScope
}

impl Drop for GpuScope {
    fn drop(&mut self) {
        registry().gpu_busy.store(false, Ordering::Relaxed);
    }
}

/// A snapshot of the registry: per-role busy/io/idle counts + GPU busy flag.
#[derive(Clone, Copy, Debug, Default)]
pub struct Snapshot {
    pub busy: usize,
    pub io: usize,
    pub idle: usize,
    pub gpu_busy: bool,
}

pub fn snapshot() -> Snapshot {
    let slots = registry().slots.lock().unwrap();
    let mut snap = Snapshot { gpu_busy: registry().gpu_busy.load(Ordering::Relaxed), ..Default::default() };
    for t in slots.iter() {
        // IoWorker threads are bookkeeping threads of the async engine; they
        // count as I/O wait when busy (they sleep out simulated device time),
        // never as CPU.
        match (t.role, t.state.load(Ordering::Relaxed)) {
            (Role::IoWorker, s) if s != State::Idle as u8 => snap.io += 1,
            (Role::IoWorker, _) => {}
            (_, s) if s == State::Busy as u8 => snap.busy += 1,
            (_, s) if s == State::Io as u8 => snap.io += 1,
            _ => snap.idle += 1,
        }
    }
    snap
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unregistered_thread_is_noop() {
        let _scope = enter(State::Io);
        // No panic, no effect.
    }

    #[test]
    fn register_enter_snapshot_deregister() {
        std::thread::spawn(|| {
            register(Role::Sampler);
            {
                let _io = enter(State::Io);
                let snap = snapshot();
                assert!(snap.io >= 1, "snap={snap:?}");
            }
            let snap = snapshot();
            assert!(snap.busy >= 1, "snap={snap:?}");
            deregister();
        })
        .join()
        .unwrap();
    }

    #[test]
    fn gpu_flag() {
        {
            let _g = gpu_enter();
            assert!(snapshot().gpu_busy);
        }
        assert!(!snapshot().gpu_busy);
    }
}
