//! Metrics: thread-state registry, counters and utilization timelines.

pub mod state;
pub mod timeline;

pub use timeline::{bucketize, render, Sample, TimelineRecorder};
