//! Utilization timelines (paper Figs 3 & 11): a background sampler polls
//! the thread-state registry and the GPU-busy flag, producing (time, CPU %,
//! GPU %, iowait %) series in simulated time.

use super::state;
use crate::sim::Clock;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub t: Duration,
    /// Fraction of registered worker threads doing CPU work.
    pub cpu: f64,
    /// Accelerator busy (0/1 sampled, smoothed by bucketing).
    pub gpu: f64,
    /// Fraction of registered worker threads blocked on (simulated) I/O.
    pub iowait: f64,
}

pub struct TimelineRecorder {
    stop: Arc<AtomicBool>,
    samples: Arc<Mutex<Vec<Sample>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TimelineRecorder {
    /// Poll every `period` (simulated time).
    pub fn start(clock: Clock, period: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let samples = Arc::new(Mutex::new(Vec::new()));
        let handle = {
            let stop = stop.clone();
            let samples = samples.clone();
            std::thread::spawn(move || {
                let t0 = clock.now();
                while !stop.load(Ordering::Relaxed) {
                    let snap = state::snapshot();
                    let denom = (snap.busy + snap.io + snap.idle).max(1) as f64;
                    samples.lock().unwrap().push(Sample {
                        t: clock.now().saturating_sub(t0),
                        cpu: snap.busy as f64 / denom,
                        gpu: if snap.gpu_busy { 1.0 } else { 0.0 },
                        iowait: snap.io as f64 / denom,
                    });
                    clock.sleep(period);
                }
            })
        };
        TimelineRecorder { stop, samples, handle: Some(handle) }
    }

    /// Stop polling and return the series.
    pub fn finish(mut self) -> Vec<Sample> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        std::mem::take(&mut *self.samples.lock().unwrap())
    }
}

impl Drop for TimelineRecorder {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Downsample a series into `buckets` averaged windows — the paper-style
/// "% over a window of three epochs" plot rows.
pub fn bucketize(samples: &[Sample], buckets: usize) -> Vec<Sample> {
    if samples.is_empty() || buckets == 0 {
        return Vec::new();
    }
    let t_end = samples.last().unwrap().t;
    let width = t_end.as_secs_f64() / buckets as f64;
    let mut out = Vec::with_capacity(buckets);
    for b in 0..buckets {
        let lo = width * b as f64;
        let hi = width * (b + 1) as f64;
        let window: Vec<&Sample> = samples
            .iter()
            .filter(|s| s.t.as_secs_f64() >= lo && s.t.as_secs_f64() < hi)
            .collect();
        if window.is_empty() {
            continue;
        }
        let n = window.len() as f64;
        out.push(Sample {
            t: Duration::from_secs_f64((lo + hi) / 2.0),
            cpu: window.iter().map(|s| s.cpu).sum::<f64>() / n,
            gpu: window.iter().map(|s| s.gpu).sum::<f64>() / n,
            iowait: window.iter().map(|s| s.iowait).sum::<f64>() / n,
        });
    }
    out
}

/// Render the series as TSV rows (`t_s cpu% gpu% iowait%`).
pub fn render(samples: &[Sample]) -> String {
    let mut out = String::from("t_s\tcpu%\tgpu%\tiowait%\n");
    for s in samples {
        out.push_str(&format!(
            "{:.2}\t{:.0}\t{:.0}\t{:.0}\n",
            s.t.as_secs_f64(),
            s.cpu * 100.0,
            s.gpu * 100.0,
            s.iowait * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::state::{self, Role, State};

    #[test]
    fn records_state_transitions() {
        let clock = Clock::new(1.0);
        let rec = TimelineRecorder::start(clock.clone(), Duration::from_millis(2));
        let h = std::thread::spawn(|| {
            state::register(Role::Sampler);
            {
                let _io = state::enter(State::Io);
                std::thread::sleep(Duration::from_millis(30));
            }
            state::deregister();
        });
        h.join().unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let samples = rec.finish();
        assert!(samples.len() >= 5, "only {} samples", samples.len());
        assert!(
            samples.iter().any(|s| s.iowait > 0.0),
            "io wait never observed"
        );
    }

    #[test]
    fn bucketize_averages() {
        let samples: Vec<Sample> = (0..100)
            .map(|i| Sample {
                t: Duration::from_millis(i * 10),
                cpu: if i < 50 { 1.0 } else { 0.0 },
                gpu: 0.5,
                iowait: 0.0,
            })
            .collect();
        let b = bucketize(&samples, 2);
        assert_eq!(b.len(), 2);
        assert!(b[0].cpu > 0.9);
        assert!(b[1].cpu < 0.1);
        let text = render(&b);
        assert!(text.contains("cpu%"));
    }
}
