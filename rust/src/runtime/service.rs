//! Train service: PJRT objects are `!Send` (Rc-backed FFI handles), but the
//! pipeline's trainer runs on its own thread. The service owns the PJRT
//! client + executable + parameters on one dedicated thread for the process
//! lifetime; [`TrainHandle`] is a `Send` façade implementing
//! [`TrainStep`] that ships batches over a channel. Parameters persist in
//! the service across epochs.

use super::pjrt::{PjrtRuntime, PjrtTrainStep};
use crate::sample::PaddedSubgraph;
use crate::train::{StepResult, TrainStep};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;

enum Req {
    Step { padded: Arc<PaddedSubgraph>, feats: Vec<f32>, resp: mpsc::Sender<StepResult> },
    Eval { padded: Arc<PaddedSubgraph>, feats: Vec<f32>, resp: mpsc::Sender<Result<StepResult>> },
    Shutdown,
}

/// `Send` handle to the PJRT train service.
pub struct TrainHandle {
    tx: mpsc::Sender<Req>,
    caps: Vec<usize>,
    fanouts: Vec<usize>,
    dim: usize,
    _thread: std::thread::JoinHandle<()>,
}

impl TrainHandle {
    /// Spawn the service thread, loading artifact `<name>` from `dir`.
    pub fn spawn(dir: PathBuf, name: String) -> Result<TrainHandle> {
        let (tx, rx) = mpsc::channel::<Req>();
        let (init_tx, init_rx) = mpsc::channel::<Result<(Vec<usize>, Vec<usize>, usize)>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-train".into())
            .spawn(move || {
                let mut step = match PjrtRuntime::cpu()
                    .and_then(|rt| PjrtTrainStep::load(&rt, &dir, &name))
                {
                    Ok(s) => {
                        let _ = init_tx.send(Ok((
                            s.caps().to_vec(),
                            s.fanouts().to_vec(),
                            s.dim(),
                        )));
                        s
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Step { padded, feats, resp } => {
                            let r = step.step(&padded, &feats);
                            let _ = resp.send(r);
                        }
                        Req::Eval { padded, feats, resp } => {
                            let _ = resp.send(step.evaluate(&padded, &feats));
                        }
                        Req::Shutdown => break,
                    }
                }
            })?;
        let (caps, fanouts, dim) = init_rx.recv()??;
        Ok(TrainHandle { tx, caps, fanouts, dim, _thread: thread })
    }

    /// Evaluate without a parameter update (uses the `_eval` artifact).
    pub fn evaluate(&self, padded: Arc<PaddedSubgraph>, feats: Vec<f32>) -> Result<StepResult> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::Eval { padded, feats, resp })
            .map_err(|_| anyhow::anyhow!("train service stopped"))?;
        rx.recv()?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

impl TrainStep for TrainHandle {
    fn caps(&self) -> &[usize] {
        &self.caps
    }

    fn fanouts(&self) -> &[usize] {
        &self.fanouts
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn step(&mut self, batch: &PaddedSubgraph, features: &[f32]) -> StepResult {
        let (resp, rx) = mpsc::channel();
        // One copy of the feature block crosses the channel — the same
        // H2D-ish copy a real accelerator pays.
        let padded = Arc::new(batch.clone());
        self.tx
            .send(Req::Step { padded, feats: features.to_vec(), resp })
            .expect("train service stopped");
        rx.recv().expect("train service died")
    }

    /// Serving-path inference: routed through `Req::Eval`, which executes
    /// the `_eval` artifact — a pure forward pass that never touches the
    /// service's resident parameters. Requires the eval artifact to have
    /// been compiled alongside the train artifact (`aot.py` emits both).
    fn forward(&mut self, batch: &PaddedSubgraph, features: &[f32]) -> StepResult {
        self.evaluate(Arc::new(batch.clone()), features.to_vec())
            .expect("train service eval failed (is the _eval artifact present?)")
    }

    fn is_real(&self) -> bool {
        true
    }
}
