//! Runtime: AOT artifact loading/execution over PJRT, plus the roofline
//! cost model used by large sweeps.

pub mod artifacts;
pub mod pjrt;
pub mod service;
pub mod simcompute;

pub use artifacts::ArtifactMeta;
pub use pjrt::{LoadedArtifact, PjrtRuntime, PjrtTrainStep};
pub use service::TrainHandle;
