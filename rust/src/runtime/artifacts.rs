//! AOT artifact registry: each artifact is `<name>.hlo.txt` (the lowered
//! module) + `<name>.meta.json` (shapes/param layout, written by
//! `python/compile/aot.py`) + `<base>.params.bin` (initial parameters).
//! This module parses the sidecars; `pjrt.rs` loads and executes.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String, // "train" | "eval"
    pub model: String,
    pub caps: Vec<usize>,
    pub fanouts: Vec<usize>,
    pub dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub lr: f64,
    pub n_params: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_path: PathBuf,
    pub params_path: PathBuf,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("spec missing name"))?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow!("spec missing shape"))?,
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

impl ArtifactMeta {
    /// Load `<dir>/<name>.meta.json` and resolve the sibling paths.
    pub fn load(dir: &Path, name: &str) -> Result<ArtifactMeta> {
        let meta_path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`?)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{meta_path:?}: {e}"))?;
        let get_usize = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("meta missing {k}"))
        };
        let base = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("meta missing name"))?
            .to_string();
        Ok(ArtifactMeta {
            kind: j.get("kind").and_then(Json::as_str).unwrap_or("train").to_string(),
            model: j.get("model").and_then(Json::as_str).unwrap_or("?").to_string(),
            caps: j
                .get("caps")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("meta missing caps"))?,
            fanouts: j
                .get("fanouts")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("meta missing fanouts"))?,
            dim: get_usize("dim")?,
            hidden: get_usize("hidden")?,
            classes: get_usize("classes")?,
            lr: j.get("lr").and_then(Json::as_f64).unwrap_or(0.05),
            n_params: get_usize("n_params")?,
            inputs: tensor_specs(j.get("inputs").ok_or_else(|| anyhow!("meta missing inputs"))?)?,
            outputs: tensor_specs(
                j.get("outputs").ok_or_else(|| anyhow!("meta missing outputs"))?,
            )?,
            hlo_path: dir.join(format!("{name}.hlo.txt")),
            params_path: dir.join(format!("{base}.params.bin")),
            name: name.to_string(),
        })
    }

    /// Read the initial parameters (concatenated little-endian f32 arrays in
    /// input order).
    pub fn load_params(&self) -> Result<Vec<Vec<f32>>> {
        let bytes = std::fs::read(&self.params_path)
            .with_context(|| format!("reading {:?}", self.params_path))?;
        let mut params = Vec::with_capacity(self.n_params);
        let mut off = 0usize;
        for spec in self.inputs.iter().take(self.n_params) {
            let n = spec.elements();
            let end = off + n * 4;
            if end > bytes.len() {
                return Err(anyhow!(
                    "params.bin too short: need {end}, have {}",
                    bytes.len()
                ));
            }
            params.push(
                bytes[off..end]
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect(),
            );
            off = end;
        }
        if off != bytes.len() {
            return Err(anyhow!("params.bin has {} trailing bytes", bytes.len() - off));
        }
        Ok(params)
    }

    /// Default artifacts directory: `$GNNDRIVE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("GNNDRIVE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("t.meta.json"),
            r#"{
              "name": "t", "kind": "train", "model": "graphsage",
              "caps": [2, 4, 8], "fanouts": [2, 2],
              "dim": 4, "hidden": 4, "classes": 2, "lr": 0.05, "n_params": 1,
              "inputs": [
                {"name": "w", "shape": [2, 3], "dtype": "f32"},
                {"name": "feats", "shape": [8, 4], "dtype": "f32"},
                {"name": "idx_0", "shape": [2, 2], "dtype": "i32"},
                {"name": "idx_1", "shape": [4, 2], "dtype": "i32"},
                {"name": "labels", "shape": [2], "dtype": "i32"}
              ],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
            }"#,
        )
        .unwrap();
        let vals: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("t.params.bin"), bytes).unwrap();
    }

    #[test]
    fn parses_meta_and_params() {
        let dir = std::env::temp_dir().join("gnndrive_artifact_test");
        write_fixture(&dir);
        let meta = ArtifactMeta::load(&dir, "t").unwrap();
        assert_eq!(meta.caps, vec![2, 4, 8]);
        assert_eq!(meta.inputs.len(), 5);
        assert_eq!(meta.inputs[1].name, "feats");
        assert_eq!(meta.inputs[1].elements(), 32);
        let params = meta.load_params().unwrap();
        assert_eq!(params.len(), 1);
        assert_eq!(params[0], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn missing_meta_is_helpful() {
        let dir = std::env::temp_dir().join("gnndrive_artifact_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let err = ArtifactMeta::load(&dir, "nope").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn short_params_rejected() {
        let dir = std::env::temp_dir().join("gnndrive_artifact_short");
        write_fixture(&dir);
        std::fs::write(dir.join("t.params.bin"), [0u8; 8]).unwrap();
        let meta = ArtifactMeta::load(&dir, "t").unwrap();
        assert!(meta.load_params().is_err());
    }
}
