//! PJRT execution of AOT artifacts.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`: the Rust hot path runs the JAX/Pallas-authored computation
//! with no Python anywhere near it. One [`PjrtTrainStep`] owns the compiled
//! executable and the current parameters; each `step` packs the padded
//! mini-batch into literals, executes, and keeps the updated parameters for
//! the next step.

use super::artifacts::ArtifactMeta;
use crate::sample::PaddedSubgraph;
use crate::train::StepResult;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Shared CPU PJRT client (compilation is per-artifact; the client is
/// process-wide).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<dir>/<name>.hlo.txt`.
    pub fn load(&self, dir: &Path, name: &str) -> Result<LoadedArtifact> {
        let meta = ArtifactMeta::load(dir, name)?;
        let path = meta
            .hlo_path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {:?}", meta.hlo_path))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        Ok(LoadedArtifact { exe, meta })
    }
}

pub struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl LoadedArtifact {
    /// Execute with the given literals; unpacks the 1-tuple output into its
    /// elements (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Real-numerics training step backed by the compiled artifact.
pub struct PjrtTrainStep {
    train: LoadedArtifact,
    eval: Option<LoadedArtifact>,
    params: Vec<xla::Literal>,
    caps: Vec<usize>,
    fanouts: Vec<usize>,
    dim: usize,
    steps_done: u64,
}

impl PjrtTrainStep {
    /// Load `<name>` (+ `<name>_eval` if present) and its initial params.
    pub fn load(runtime: &PjrtRuntime, dir: &Path, name: &str) -> Result<Self> {
        let train = runtime.load(dir, name)?;
        let eval = runtime.load(dir, &format!("{name}_eval")).ok();
        let raw = train.meta.load_params()?;
        let mut params = Vec::with_capacity(raw.len());
        for (vals, spec) in raw.iter().zip(&train.meta.inputs) {
            params.push(lit_f32(vals, &spec.shape)?);
        }
        Ok(PjrtTrainStep {
            caps: train.meta.caps.clone(),
            fanouts: train.meta.fanouts.clone(),
            dim: train.meta.dim,
            train,
            eval,
            params,
            steps_done: 0,
        })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.train.meta
    }

    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    fn pack_batch(
        &self,
        batch: &PaddedSubgraph,
        features: &[f32],
    ) -> Result<Vec<xla::Literal>> {
        let n_params = self.params.len();
        let mut inputs = Vec::with_capacity(n_params + 2 + self.fanouts.len());
        // Parameters are cheap to clone? Literals are host buffers; cloning
        // copies — instead pass borrows via execute's Borrow bound.
        // pack_batch returns only the non-param literals; see step().
        let feats_spec = &self.train.meta.inputs[n_params];
        let want = feats_spec.elements();
        if features.len() < want {
            return Err(anyhow!("features slice too short: {} < {want}", features.len()));
        }
        inputs.push(lit_f32(&features[..want], &feats_spec.shape)?);
        for (i, adj) in batch.adjs.iter().enumerate() {
            let spec = &self.train.meta.inputs[n_params + 1 + i];
            if adj.idx.len() != spec.elements() {
                return Err(anyhow!(
                    "idx_{i} has {} entries, artifact expects {}",
                    adj.idx.len(),
                    spec.elements()
                ));
            }
            inputs.push(lit_i32(&adj.idx, &spec.shape)?);
        }
        inputs.push(lit_i32(&batch.labels, &[batch.labels.len()])?);
        Ok(inputs)
    }

    /// Evaluate without updating parameters (requires the `_eval` artifact).
    pub fn evaluate(&self, batch: &PaddedSubgraph, features: &[f32]) -> Result<StepResult> {
        let eval = self
            .eval
            .as_ref()
            .ok_or_else(|| anyhow!("no eval artifact for {}", self.train.meta.name))?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        let rest = self.pack_batch(batch, features)?;
        let rest_refs: Vec<&xla::Literal> = rest.iter().collect();
        inputs.extend(rest_refs);
        let result = eval.exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let loss = outs[0].get_first_element::<f32>()?;
        let correct = outs[1].get_first_element::<f32>()? as usize;
        Ok(StepResult { loss, correct, examples: batch.real_seeds })
    }
}

// NOTE: `TrainStep` requires `Send`, which PJRT's Rc-backed FFI handles are
// not. PjrtTrainStep therefore exposes the same surface as inherent methods
// and is driven by [`super::service::TrainHandle`], whose dedicated thread
// owns it for the process lifetime.
impl PjrtTrainStep {
    pub fn caps(&self) -> &[usize] {
        &self.caps
    }

    pub fn fanouts(&self) -> &[usize] {
        &self.fanouts
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn step(&mut self, batch: &PaddedSubgraph, features: &[f32]) -> StepResult {
        // The CPU PJRT execution *is* the accelerator here; flag it for the
        // utilization timeline.
        let _gpu = crate::metrics::state::gpu_enter();
        let rest = match self.pack_batch(batch, features) {
            Ok(r) => r,
            Err(e) => panic!("pack_batch: {e}"),
        };
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend(rest.iter());
        let result = self
            .train
            .exe
            .execute::<&xla::Literal>(&inputs)
            .and_then(|r| r[0][0].to_literal_sync())
            .unwrap_or_else(|e| panic!("PJRT execute: {e}"));
        let mut outs = result.to_tuple().expect("tuple output");
        let correct_lit = outs.pop().expect("correct");
        let loss_lit = outs.pop().expect("loss");
        self.params = outs; // updated parameters
        self.steps_done += 1;
        StepResult {
            loss: loss_lit.get_first_element::<f32>().unwrap_or(f32::NAN),
            correct: correct_lit.get_first_element::<f32>().unwrap_or(0.0) as usize,
            examples: batch.real_seeds,
        }
    }
}
