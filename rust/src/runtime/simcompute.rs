//! Roofline cost model for the train stage (sweep substitute for the GPU).
//!
//! The paper's evaluation machines train on RTX 3090 / K80 GPUs; the sweeps
//! here charge a simulated step time `max(flops/peak, bytes/mem_bw) × ineff
//! + launch` derived from the same model definitions the AOT path uses, so
//! the train stage occupies a realistic share of the pipeline (it is never
//! the bottleneck in the paper — extract is 97.3 % of epoch time — but it
//! must overlap correctly). Loss/accuracy are NaN/0: numerics only flow
//! through the real PJRT path.

use crate::config::GpuModel;
use crate::sample::PaddedSubgraph;
use crate::sim::Clock;
use crate::train::{StepResult, TrainStep};
use std::time::Duration;

/// Which GNN the paper trains (§5 "GNN Models").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    GraphSage,
    Gcn,
    Gat,
}

impl ModelKind {
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "sage" | "graphsage" => Some(ModelKind::GraphSage),
            "gcn" => Some(ModelKind::Gcn),
            "gat" => Some(ModelKind::Gat),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::GraphSage => "graphsage",
            ModelKind::Gcn => "gcn",
            ModelKind::Gat => "gat",
        }
    }

    /// Extra aggregation work relative to mean-aggregation (GAT computes
    /// per-edge attention scores + softmax).
    fn agg_multiplier(&self) -> f64 {
        match self {
            ModelKind::GraphSage => 1.0,
            ModelKind::Gcn => 1.0,
            ModelKind::Gat => 2.5,
        }
    }
}

/// Analytic FLOP/byte counts for one training step (forward + backward ≈ 3×
/// forward) over the padded shapes.
#[derive(Clone, Debug)]
pub struct StepCost {
    pub flops: f64,
    pub bytes: f64,
}

pub fn step_cost(
    model: ModelKind,
    caps: &[usize],
    fanouts: &[usize],
    dim: usize,
    hidden: usize,
    classes: usize,
) -> StepCost {
    assert_eq!(caps.len(), fanouts.len() + 1);
    let levels = fanouts.len();
    let mut flops = 0f64;
    let mut bytes = 0f64;
    for i in 0..levels {
        let dst = caps[i] as f64;
        let fan = fanouts[i] as f64;
        // GNN step consuming adjacency level i: inputs are level-(i+1)
        // hidden states. The deepest step (i = levels-1) reads raw features.
        let d_in = if i == levels - 1 { dim } else { hidden } as f64;
        let d_out = if i == 0 { classes } else { hidden } as f64;
        // Aggregation: gather + reduce over fanout neighbors.
        flops += dst * fan * d_in * model.agg_multiplier();
        // Combination: self + neighbor dense matmuls.
        flops += 2.0 * 2.0 * dst * d_in * d_out;
        // Activations in and out (fp32).
        bytes += (caps[i + 1] as f64 * d_in + dst * d_out) * 4.0;
    }
    // Forward + backward + SGD ≈ 3× forward.
    StepCost { flops: flops * 3.0, bytes: bytes * 3.0 }
}

/// A simulated-GPU training step.
pub struct SimTrainStep {
    gpu: GpuModel,
    clock: Clock,
    caps: Vec<usize>,
    fanouts: Vec<usize>,
    dim: usize,
    step_time: Duration,
    /// Inference-only cost: the model charges forward+backward+SGD as 3×
    /// forward, so a read-only forward pass (serving) pays one third of the
    /// roofline term plus the same launch overhead.
    forward_time: Duration,
}

impl SimTrainStep {
    pub fn new(
        gpu: GpuModel,
        clock: Clock,
        model: ModelKind,
        caps: Vec<usize>,
        fanouts: Vec<usize>,
        dim: usize,
        hidden: usize,
        classes: usize,
    ) -> Self {
        let cost = step_cost(model, &caps, &fanouts, dim, hidden, classes);
        // Achieved efficiency on small irregular kernels is far below peak;
        // 0.25 matches measured GNN training utilization on consumer GPUs.
        let eff = 0.25;
        let t = (cost.flops / (gpu.peak_flops() * eff))
            .max(cost.bytes / gpu.mem_bw())
            .max(0.0);
        let step_time = gpu.launch_overhead() + Duration::from_secs_f64(t);
        let forward_time = gpu.launch_overhead() + Duration::from_secs_f64(t / 3.0);
        SimTrainStep { gpu, clock, caps, fanouts, dim, step_time, forward_time }
    }

    pub fn step_time(&self) -> Duration {
        self.step_time
    }

    pub fn forward_time(&self) -> Duration {
        self.forward_time
    }

    /// Charge `dur` on the right resource (CPU-busy for CPU training, GPU
    /// occupancy otherwise) — shared by `step` and `forward`.
    fn charge(&self, dur: Duration) {
        if self.gpu == GpuModel::CpuOnly {
            let _busy = crate::metrics::state::enter(crate::metrics::state::State::Busy);
            self.clock.sleep(dur);
        } else {
            let _idle = crate::metrics::state::enter(crate::metrics::state::State::Idle);
            let _gpu = crate::metrics::state::gpu_enter();
            self.clock.sleep(dur);
        }
    }
}

impl TrainStep for SimTrainStep {
    fn caps(&self) -> &[usize] {
        &self.caps
    }

    fn fanouts(&self) -> &[usize] {
        &self.fanouts
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn step(&mut self, _batch: &PaddedSubgraph, _features: &[f32]) -> StepResult {
        // The GPU is busy; the trainer thread itself just waits (it is not
        // CPU-busy, it is not I/O) — unless this is CPU training.
        self.charge(self.step_time);
        StepResult { loss: f32::NAN, correct: 0, examples: _batch.real_seeds }
    }

    fn forward(&mut self, batch: &PaddedSubgraph, _features: &[f32]) -> StepResult {
        self.charge(self.forward_time);
        StepResult { loss: f32::NAN, correct: 0, examples: batch.real_seeds }
    }

    fn is_real(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_scales_with_shapes() {
        let small = step_cost(ModelKind::GraphSage, &[64, 384, 2048], &[5, 5], 64, 64, 16);
        let big = step_cost(ModelKind::GraphSage, &[1000, 6000, 24000], &[10, 10], 128, 256, 172);
        assert!(big.flops > small.flops * 10.0);
        assert!(small.flops > 1e6);
        let gat = step_cost(ModelKind::Gat, &[64, 384, 2048], &[5, 5], 64, 64, 16);
        assert!(gat.flops > small.flops);
    }

    #[test]
    fn gpu_beats_cpu_and_gat_is_heavier() {
        let clock = Clock::new(1.0);
        let mk = |gpu, model| {
            SimTrainStep::new(
                gpu,
                clock.clone(),
                model,
                vec![1000, 6000, 24000],
                vec![10, 10],
                128,
                256,
                172,
            )
            .step_time()
        };
        let gpu_sage = mk(GpuModel::Rtx3090, ModelKind::GraphSage);
        let cpu_sage = mk(GpuModel::CpuOnly, ModelKind::GraphSage);
        let cpu_gat = mk(GpuModel::CpuOnly, ModelKind::Gat);
        assert!(cpu_sage > gpu_sage, "{cpu_sage:?} vs {gpu_sage:?}");
        assert!(cpu_gat > cpu_sage);
    }

    #[test]
    fn sim_step_sleeps_and_reports_examples() {
        let clock = Clock::new(0.1);
        let mut step = SimTrainStep::new(
            GpuModel::Rtx3090,
            clock,
            ModelKind::GraphSage,
            vec![4, 8, 16],
            vec![2, 2],
            8,
            8,
            4,
        );
        let padded = crate::sample::SampledSubgraph {
            batch_id: 0,
            nodes: vec![1, 2, 3, 4],
            cum: vec![2, 3, 4],
            adjs: vec![
                crate::sample::LayerAdj { fanout: 2, idx: vec![2, -1, 3, -1] },
                crate::sample::LayerAdj { fanout: 2, idx: vec![-1; 6] },
            ],
            labels: vec![0, 1],
        }
        .pad(&[4, 8, 16], &[2, 2]);
        let r = step.step(&padded, &[]);
        assert!(r.loss.is_nan());
        assert_eq!(r.examples, 2);
        assert!(!step.is_real());
        let f = step.forward(&padded, &[]);
        assert_eq!(f.examples, 2);
        // `<=` not `<`: both collapse to the bare launch overhead when the
        // roofline term rounds to zero nanoseconds.
        assert!(
            step.forward_time() <= step.step_time(),
            "inference must not cost more than a training step"
        );
    }
}
