//! GNNDrive's feature-buffer manager (paper §4.2, Fig 6, Algorithm 1),
//! re-architected as a sharded coordinator with a *lock-free* slot
//! allocation and release path.
//!
//! The feature buffer lives in device memory (host memory for CPU-based
//! training) and holds one slot per extracted node row. The paper's four
//! structures are all here, but arranged for concurrency:
//!
//! * **mapping table** — node → (slot, generation); *sharded by node-id
//!   hash* so concurrent extractors planning different batches take
//!   different locks (`begin_batch` groups its node list per shard and takes
//!   each shard mutex at most once on the fast path). Entries are validated
//!   on use by a generation-checked CAS, so a stale entry (its slot was
//!   claimed since) is detected and dropped lazily instead of being evicted
//!   under a lock;
//! * **reverse mapping** — slot → node (or −1), per-slot atomics;
//! * **standby "list"** — *implicit*: any slot whose packed word shows zero
//!   references is reusable. A [`super::shard::FreeStack`] (Treiber stack)
//!   hands out never-tenanted slots with one CAS pop, and a
//!   [`super::shard::ClockHand`] second-chance sweep over the packed
//!   `AtomicU64` slot words claims tenanted zero-reference slots with a
//!   generation-bumping CAS — approximate LRU (a slot survives one full
//!   sweep after its last use), with **no mutex anywhere on the allocation
//!   or release path**;
//! * **node alias list** — per-batch slot indexes handed to the trainer,
//!   and since the lock-free path landed also the *release* currency:
//!   [`FeatureBuffer::release_aliases`] drops references by slot index
//!   directly, skipping the node→slot map (and its shard locks) entirely.
//!
//! Row payloads live in one contiguous flat arena instead of
//! `Vec<Mutex<Box<[f32]>>>`; a packed per-slot `AtomicU64`
//! (`refcount | valid | generation | clock`, see [`super::slot_state`])
//! carries the slot's lifecycle. `publish` is write-row + release-store of
//! the valid bit + targeted wakeup; `gather` is an acquire load +
//! `copy_nonoverlapping` per row — no per-row locks anywhere. Condvar
//! broadcasts are replaced by [`EventCount`]s whose signal side is one
//! atomic load when nobody waits.
//!
//! State machine per entry is unchanged from the paper: `(slot=-1,
//! valid=0)` absent → `(slot=s, valid=0, ref>0)` being extracted →
//! `(slot=s, valid=1)` ready; a ready node with `ref=0` is *evictable* and
//! can be either *reused* (hit) or *claimed* (slot reassigned, generation
//! bumped, the old entry turned stale). Extractors that find a node
//! mid-extraction by a peer alias its slot, join the wait list, and re-check
//! validity at the end (`wait_valid`/`wait_plan`) — sharing I/O instead of
//! duplicating it.
//!
//! Earlier coordinator generations are preserved for
//! `benches/micro_hotpath.rs`: the single-global-mutex original as
//! [`super::single_mutex::SingleMutexFeatureBuffer`] and the PR-1 sharded
//! mutex-LRU design as [`super::mutex_lru::MutexLruFeatureBuffer`].

use super::arena::Arena;
use super::shard::{
    self, ClockHand, EventCount, FreeStack, MapEntry, Shard, ShardState,
};
use super::slot_state::{self, SlotStates};
use crate::storage::{DeviceMemory, HostMemory, Reservation};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Where the buffer's memory is charged.
pub enum BufferHome {
    Device(Reservation),
    Host(Reservation),
}

/// Wait-group fan-out for publish wakeups (power of two; a waiter parks on
/// `slot % WAIT_GROUPS`, so a publish wakes only the waiters hashed to its
/// group instead of every waiter in the system).
const WAIT_GROUPS: usize = 64;

/// Stale-handle ticket for one awaited slot: resolved once at plan time so
/// `wait_plan` never re-locks a shard.
#[derive(Clone, Copy, Debug)]
pub struct WaitHandle {
    pub node: u32,
    pub slot: u32,
    pub generation: u32,
}

/// The extraction plan for one mini-batch (outcome of Algorithm 1 lines
/// 1–30, before I/O).
#[derive(Debug)]
pub struct BatchPlan {
    /// Slot alias per batch node (parallel to the node list).
    pub aliases: Vec<i32>,
    /// (node, slot) pairs whose rows must be loaded from SSD.
    pub to_load: Vec<(u32, u32)>,
    /// Nodes being extracted by peer extractors; wait for their valid bits.
    pub wait_list: Vec<u32>,
    /// Pre-resolved (slot, generation) tickets for `wait_list` — lets
    /// `wait_plan` spin on the packed slot words without shard locks.
    pub wait_handles: Vec<WaitHandle>,
}

/// Outcome of resolving one node inside its shard.
enum Resolved {
    /// Ready in the buffer (hit): alias this slot.
    Alias(u32),
    /// Being extracted by a peer: alias + wait for its valid bit.
    Wait(u32, u32),
    /// Newly allocated: caller must load the row, then publish.
    Load(u32),
    /// Nothing allocatable anywhere right now; take the blocking path.
    Dry,
}

/// One clock eviction's deferred bookkeeping: the old tenant's mapping
/// entry (in the tenant's home shard) is now stale and is swept out at the
/// end of the batch — off the allocation fast path.
#[derive(Clone, Copy)]
struct Evicted {
    node: u32,
    slot: u32,
    generation: u32,
}

pub struct FeatureBuffer {
    pub n_slots: usize,
    pub dim: usize,
    shards: Vec<Shard>,
    /// `shards.len() - 1`; shard count is a power of two.
    shard_mask: usize,
    states: SlotStates,
    /// slot → tenant node id or -1.
    reverse: Vec<AtomicI64>,
    arena: Arena,
    /// Treiber stack of untenanted slots: the whole arena at cold start,
    /// plus slots handed back by raced clock claims.
    free: FreeStack,
    /// Second-chance eviction cursor over the slot words.
    clock: ClockHand,
    /// Signalled when a slot's reference count returns to zero and
    /// allocators are waiting.
    free_event: EventCount,
    /// Publish wakeups, fanned out by `slot % WAIT_GROUPS`.
    valid_events: Vec<EventCount>,
    /// Diagnostics.
    hits: AtomicU64,
    shared: AtomicU64,
    steals: AtomicU64,
    loads: AtomicU64,
    _home: BufferHome,
}

impl FeatureBuffer {
    /// Reserve `n_slots × dim` f32 slots in device memory.
    pub fn in_device(
        dev: &DeviceMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = dev.reserve("feature buffer", bytes)?;
        Ok(Self::build(n_slots, dim, BufferHome::Device(res)))
    }

    /// CPU-training variant: the buffer is charged to host memory (§4.4).
    pub fn in_host(
        host: &HostMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = host.reserve("feature buffer (cpu)", bytes)?;
        Ok(Self::build(n_slots, dim, BufferHome::Host(res)))
    }

    fn build(n_slots: usize, dim: usize, home: BufferHome) -> Self {
        // Shards only partition the mapping table now — allocation is
        // global and lock-free — so the count trades map-lock contention
        // against per-batch grouping work.
        let n_shards = shard::shard_count_for(n_slots);
        let shards: Vec<Shard> =
            (0..n_shards).map(|_| Shard::new(n_slots / n_shards + 1)).collect();
        let free = FreeStack::new(n_slots);
        // Push descending so pops hand out ascending slot ids (diagnostic
        // friendliness only; any order is correct).
        for s in (0..n_slots as u32).rev() {
            free.push(s);
        }
        FeatureBuffer {
            n_slots,
            dim,
            shard_mask: n_shards - 1,
            shards,
            states: SlotStates::new(n_slots),
            reverse: (0..n_slots).map(|_| AtomicI64::new(-1)).collect(),
            arena: Arena::new(n_slots * dim),
            free,
            clock: ClockHand::new(),
            free_event: EventCount::new(),
            valid_events: (0..WAIT_GROUPS.min(n_slots.max(1))).map(|_| EventCount::new()).collect(),
            hits: AtomicU64::new(0),
            shared: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            loads: AtomicU64::new(0),
            _home: home,
        }
    }

    /// Number of mapping-table shards (diagnostics / benches).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn node_shard(&self, node: u32) -> usize {
        // Fibonacci mix; the low bits of raw node ids correlate with batch
        // layout, which would unbalance the shards.
        let h = (node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) & self.shard_mask
    }

    #[inline]
    fn valid_event(&self, slot: u32) -> &EventCount {
        &self.valid_events[slot as usize % self.valid_events.len()]
    }

    /// Bounded second-chance sweep over the packed slot words: claim one
    /// zero-reference tenanted slot, evicting its tenant with a single
    /// generation-bumping CAS — no lock, and *called outside every shard
    /// lock* (the O(n_slots) worst-case sweep must never extend a mutex
    /// critical section). Returns
    /// `(slot, new_generation, old_tenant, old_generation)`, or `None`
    /// after two full passes found nothing claimable (caller blocks on the
    /// free event).
    fn clock_claim(&self) -> Option<(u32, u32, u32, u32)> {
        if self.n_slots == 0 {
            return None;
        }
        // Two passes: the first may do nothing but strip clock bits from
        // recently-used slots (their second chance).
        for _ in 0..2 * self.n_slots + 1 {
            let s = self.clock.next(self.n_slots) as u32;
            let word = self.states.load(s);
            if slot_state::refs(word) != 0 {
                continue;
            }
            if !slot_state::is_valid(word)
                && self.reverse[s as usize].load(Ordering::SeqCst) < 0
            {
                // Free-stack slot (or one mid-activation): the stack hands
                // those out; the claim path only evicts tenants.
                continue;
            }
            if slot_state::has_clock(word) {
                self.states.clear_clock(s);
                continue;
            }
            if let Some(new_gen) = self.states.try_claim(s, word) {
                // Exclusive owner now. The old tenant (still in `reverse`
                // until install overwrites it) keeps a stale map entry that
                // the deferred sweep removes.
                let tenant = self.reverse[s as usize].load(Ordering::SeqCst);
                debug_assert!(tenant >= 0, "claimed slot {s} had no tenant");
                self.steals.fetch_add(1, Ordering::Relaxed);
                // A waiter parked on the old generation must re-check and
                // bail (its handle is stale).
                self.valid_event(s).signal();
                return Some((s, new_gen, tenant as u32, slot_state::generation(word)));
            }
        }
        None
    }

    /// Resolve one node against its own shard (`st` is the state of the
    /// shard `id` hashes to). Takes one reference on every outcome except
    /// `Dry`. The only allocation attempted here is the O(1) Treiber-stack
    /// pop; clock eviction — whose bounded sweep can touch every slot word —
    /// happens in `alloc_slow`, *outside* the shard mutex, so a miss storm
    /// never stretches this critical section.
    fn resolve_in_shard(&self, st: &mut ShardState, id: u32) -> Resolved {
        if let Some(e) = st.map.get(&id).copied() {
            match self.states.try_ref(e.slot, e.generation) {
                Ok(prev) => {
                    if slot_state::is_valid(prev) {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return Resolved::Alias(e.slot);
                    }
                    // Being extracted by a peer (ref>0, invalid): share it.
                    debug_assert!(
                        slot_state::refs(prev) > 0,
                        "invalid zero-ref entry leaked"
                    );
                    self.shared.fetch_add(1, Ordering::Relaxed);
                    return Resolved::Wait(e.slot, e.generation);
                }
                Err(_) => {
                    // The slot was claimed since this entry was written:
                    // the entry is stale. Drop it and allocate fresh.
                    st.map.remove(&id);
                }
            }
        }
        if let Some(slot) = self.free.pop() {
            // Never-tenanted (or handed-back) slot: one CAS pop, exclusive
            // ownership.
            let generation = self.states.activate(slot);
            self.reverse[slot as usize].store(id as i64, Ordering::SeqCst);
            st.map.insert(id, MapEntry { slot, generation });
            self.loads.fetch_add(1, Ordering::Relaxed);
            return Resolved::Load(slot);
        }
        Resolved::Dry
    }

    /// Install a clock-claimed slot for `id`, re-checking the mapping under
    /// the home shard lock (a peer may have mapped the node while the sweep
    /// ran lock-free). On a race, the claimed slot is handed back to the
    /// free stack as never-tenanted — references held by the raced outcome
    /// are already correct. Either way the evicted tenant's stale entry is
    /// recorded for the deferred sweep.
    fn install_claimed(
        &self,
        home: usize,
        id: u32,
        claimed: (u32, u32, u32, u32),
        evicted: &mut Vec<Evicted>,
    ) -> Resolved {
        let (slot, generation, old_node, old_gen) = claimed;
        evicted.push(Evicted { node: old_node, slot, generation: old_gen });
        {
            let mut st = self.shards[home].state.lock().unwrap();
            match self.resolve_in_shard(&mut st, id) {
                Resolved::Dry => {
                    self.reverse[slot as usize].store(id as i64, Ordering::SeqCst);
                    st.map.insert(id, MapEntry { slot, generation });
                    self.loads.fetch_add(1, Ordering::Relaxed);
                    return Resolved::Load(slot);
                }
                r => {
                    drop(st);
                    // Raced: the node resolved some other way. Hand the
                    // claimed slot back. Order matters against concurrent
                    // clock probes: clear the tenant while the claim's
                    // reference still parks the word (probes skip refs>0),
                    // then zero the word, then publish it on the stack —
                    // a probe between the last two steps sees an invalid
                    // untenanted word and skips it.
                    self.reverse[slot as usize].store(-1, Ordering::SeqCst);
                    self.states.reset(slot, 0, false, generation);
                    self.free.push(slot);
                    self.free_event.signal();
                    r
                }
            }
        }
    }

    /// Batch positions grouped per shard (see [`shard::group_positions`]).
    fn group_positions(&self, node_ids: &[u32]) -> (Vec<u32>, Vec<u32>) {
        shard::group_positions(self.shards.len(), node_ids, |id| self.node_shard(id))
    }

    /// Algorithm 1, planning phase: resolve every batch node to a slot,
    /// reusing valid data, sharing in-flight extractions, and allocating
    /// free or clock-evicted slots for the rest (blocking if none are free
    /// anywhere — the engine sizes the buffer ≥ (queue depth + extractors)
    /// × batch cap so waiting always terminates). Reference counts of all
    /// batch nodes are incremented here and dropped by `release` /
    /// `release_aliases`.
    pub fn begin_batch(&self, node_ids: &[u32]) -> BatchPlan {
        let mut aliases = vec![-1i32; node_ids.len()];
        let mut to_load = Vec::new();
        let mut wait_list = Vec::new();
        let mut wait_handles = Vec::new();
        let mut deferred: Vec<usize> = Vec::new();
        let mut evicted: Vec<Evicted> = Vec::new();

        let apply = |i: usize,
                         r: Resolved,
                         aliases: &mut Vec<i32>,
                         to_load: &mut Vec<(u32, u32)>,
                         wait_list: &mut Vec<u32>,
                         wait_handles: &mut Vec<WaitHandle>|
         -> bool {
            let id = node_ids[i];
            match r {
                Resolved::Alias(slot) => aliases[i] = slot as i32,
                Resolved::Wait(slot, generation) => {
                    aliases[i] = slot as i32;
                    wait_list.push(id);
                    wait_handles.push(WaitHandle { node: id, slot, generation });
                }
                Resolved::Load(slot) => {
                    aliases[i] = slot as i32;
                    to_load.push((id, slot));
                }
                Resolved::Dry => return false,
            }
            true
        };

        if self.shards.len() == 1 {
            // Single shard: one lock for the whole batch, original order.
            let mut st = self.shards[0].state.lock().unwrap();
            for (i, &id) in node_ids.iter().enumerate() {
                let r = self.resolve_in_shard(&mut st, id);
                if !apply(i, r, &mut aliases, &mut to_load, &mut wait_list, &mut wait_handles) {
                    deferred.push(i);
                }
            }
        } else {
            // Group the batch per shard so each shard lock is taken at most
            // once on this fast path (within a shard, batch order holds).
            let (order, ends) = self.group_positions(node_ids);
            let mut start = 0usize;
            for (sx, &end) in ends.iter().enumerate() {
                let end = end as usize;
                if end > start {
                    let mut st = self.shards[sx].state.lock().unwrap();
                    for &pos in &order[start..end] {
                        let i = pos as usize;
                        let r = self.resolve_in_shard(&mut st, node_ids[i]);
                        if !apply(
                            i,
                            r,
                            &mut aliases,
                            &mut to_load,
                            &mut wait_list,
                            &mut wait_handles,
                        ) {
                            deferred.push(i);
                        }
                    }
                }
                start = end;
            }
            deferred.sort_unstable(); // re-establish batch order across shards
        }

        // Slow path: the free stack was dry — evict via the clock (outside
        // every shard lock), blocking on the free event only when nothing
        // anywhere is claimable.
        for i in deferred {
            let r = self.alloc_slow(node_ids[i], &mut evicted);
            let ok =
                apply(i, r, &mut aliases, &mut to_load, &mut wait_list, &mut wait_handles);
            debug_assert!(ok, "alloc_slow cannot return Dry");
        }

        self.cleanup_evicted(&mut evicted);
        BatchPlan { aliases, to_load, wait_list, wait_handles }
    }

    /// Eviction/blocking allocation. The clock sweep runs with no lock
    /// held; the home shard is only locked for the map re-check + install
    /// (`install_claimed`) or the quick re-resolve between waits. The
    /// begin_wait/re-check/wait dance keeps the free-event wakeup
    /// race-free: a release landing after a failed sweep is observed by the
    /// re-check made after registration.
    fn alloc_slow(&self, id: u32, evicted: &mut Vec<Evicted>) -> Resolved {
        let home = self.node_shard(id);
        loop {
            // Resolve first, claim second: a peer may have mapped the node
            // (or handed a slot back) while this allocation was queued, and
            // an eviction destroys a resident row irreversibly — don't pay
            // that price when the node no longer needs a slot.
            {
                let mut st = self.shards[home].state.lock().unwrap();
                match self.resolve_in_shard(&mut st, id) {
                    Resolved::Dry => {}
                    r => return r,
                }
            }
            if let Some(claimed) = self.clock_claim() {
                return self.install_claimed(home, id, claimed, evicted);
            }
            let seen = self.free_event.begin_wait();
            {
                let mut st = self.shards[home].state.lock().unwrap();
                match self.resolve_in_shard(&mut st, id) {
                    Resolved::Dry => {}
                    r => {
                        self.free_event.cancel_wait();
                        return r;
                    }
                }
            }
            if let Some(claimed) = self.clock_claim() {
                self.free_event.cancel_wait();
                return self.install_claimed(home, id, claimed, evicted);
            }
            self.free_event.wait(seen);
        }
    }

    /// Deferred stale-entry sweep: after the batch is planned (all shard
    /// locks dropped), remove the mapping entries of tenants evicted by the
    /// clock this batch — grouped so each touched shard is locked once.
    /// Removal is conditional on (slot, generation) still matching: the
    /// tenant may have been re-resolved and re-installed elsewhere
    /// meanwhile, and that live entry must survive.
    fn cleanup_evicted(&self, evicted: &mut Vec<Evicted>) {
        if evicted.is_empty() {
            return;
        }
        if self.shards.len() > 1 {
            evicted.sort_unstable_by_key(|ev| self.node_shard(ev.node));
        }
        let mut i = 0;
        while i < evicted.len() {
            let sx = self.node_shard(evicted[i].node);
            let mut st = self.shards[sx].state.lock().unwrap();
            while i < evicted.len() && self.node_shard(evicted[i].node) == sx {
                let ev = evicted[i];
                if let Some(e) = st.map.get(&ev.node) {
                    if e.slot == ev.slot && e.generation == ev.generation {
                        st.map.remove(&ev.node);
                    }
                }
                i += 1;
            }
        }
        evicted.clear();
    }

    /// Write a loaded row into its slot and publish the valid bit
    /// (Algorithm 1 L36; called from the transfer-completion path). The
    /// caller is the slot's unique loader (it holds a reference and planned
    /// the load), so the row write is race-free by protocol.
    pub fn publish(&self, node: u32, slot: u32, row: &[f32]) {
        let n = self.dim.min(row.len());
        unsafe {
            std::ptr::copy_nonoverlapping(row.as_ptr(), self.arena.row(slot as usize, self.dim), n);
        }
        self.finish_publish(node, slot);
    }

    /// `publish` from little-endian raw bytes (the staging buffer's wire
    /// format) — decodes straight into the arena with no intermediate
    /// `Vec<f32>` per row.
    pub fn publish_le_bytes(&self, node: u32, slot: u32, bytes: &[u8]) {
        let n = self.dim.min(bytes.len() / 4);
        let dst = self.arena.row(slot as usize, self.dim);
        for (i, chunk) in bytes.chunks_exact(4).take(n).enumerate() {
            let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            unsafe {
                *dst.add(i) = v;
            }
        }
        self.finish_publish(node, slot);
    }

    fn finish_publish(&self, node: u32, slot: u32) {
        debug_assert_eq!(
            self.reverse[slot as usize].load(Ordering::SeqCst),
            node as i64,
            "publish into a slot node {node} does not own"
        );
        let word = self.states.set_valid(slot);
        debug_assert!(slot_state::refs(word) > 0, "publish into zero-ref slot {slot}");
        self.valid_event(slot).signal();
    }

    /// Wait until `slot`'s valid bit is set — or until the slot is claimed
    /// out from under a stale handle (generation moved), which mirrors the
    /// old "entry vanished from the map" tolerance.
    fn wait_slot(&self, slot: u32, generation: u32) {
        let done = |word: u64| {
            slot_state::is_valid(word) || slot_state::generation(word) != generation
        };
        loop {
            if done(self.states.load(slot)) {
                return;
            }
            let ev = self.valid_event(slot);
            let seen = ev.begin_wait();
            if done(self.states.load(slot)) {
                ev.cancel_wait();
                return;
            }
            ev.wait(seen);
        }
    }

    /// Block until every node in `nodes` has a set valid bit (end of
    /// Algorithm 1: the wait-list check). Nodes no longer mapped — or
    /// mapped through a stale entry — are skipped, as before.
    pub fn wait_valid(&self, nodes: &[u32]) {
        for &id in nodes {
            let handle = {
                let st = self.shards[self.node_shard(id)].state.lock().unwrap();
                st.map.get(&id).map(|e| (e.slot, e.generation))
            };
            if let Some((slot, generation)) = handle {
                self.wait_slot(slot, generation);
            }
        }
    }

    /// `wait_valid` over a plan's pre-resolved tickets: no shard locks at
    /// all on the wait path.
    pub fn wait_plan(&self, plan: &BatchPlan) {
        for h in &plan.wait_handles {
            self.wait_slot(h.slot, h.generation);
        }
    }

    /// Releaser compatibility path: drop one reference per *node*, going
    /// through the node→slot map (one shard lock per touched shard).
    /// Prefer [`FeatureBuffer::release_aliases`] — the engine threads each
    /// batch's alias list to the releaser so this lookup never runs on the
    /// pipeline's critical path. Zero-reference slots become clock-evictable
    /// in place (§4.2 "Release": mapping entries stay valid until claimed).
    pub fn release(&self, node_ids: &[u32]) {
        let mut freed = false;
        if self.shards.len() == 1 {
            let st = self.shards[0].state.lock().unwrap();
            for &id in node_ids {
                freed |= self.release_one(&st, id);
            }
        } else {
            let (order, ends) = self.group_positions(node_ids);
            let mut start = 0usize;
            for (sx, &end) in ends.iter().enumerate() {
                let end = end as usize;
                if end > start {
                    let st = self.shards[sx].state.lock().unwrap();
                    for &pos in &order[start..end] {
                        freed |= self.release_one(&st, node_ids[pos as usize]);
                    }
                }
                start = end;
            }
        }
        if freed {
            self.free_event.signal();
        }
    }

    fn release_one(&self, st: &ShardState, id: u32) -> bool {
        let e = *st.map.get(&id).expect("release of unmapped node");
        let prev = self.states.sub_ref(e.slot);
        assert!(slot_state::refs(prev) > 0, "refcount underflow for node {id}");
        debug_assert_eq!(
            slot_state::generation(prev),
            e.generation,
            "release through a stale entry for node {id}"
        );
        slot_state::refs(prev) == 1
    }

    /// Batch-level release by alias (ROADMAP's "release by slot index"):
    /// drop one reference per non-negative alias straight on the packed
    /// slot word — no node→slot lookup, no shard lock, nothing but one
    /// `fetch_sub` per row. The aliases must come from a `BatchPlan` whose
    /// references are still held, exactly once per `begin_batch`.
    pub fn release_aliases(&self, aliases: &[i32]) {
        let mut freed = false;
        for &a in aliases {
            if a < 0 {
                continue; // padding rows never took a reference
            }
            let slot = a as u32;
            // Underflow guard on the fetch_sub return itself — a separate
            // pre-load would be TOCTOU-racy against a concurrent release.
            let prev = self.states.sub_ref(slot);
            assert!(slot_state::refs(prev) > 0, "refcount underflow for slot {slot}");
            freed |= slot_state::refs(prev) == 1;
        }
        if freed {
            self.free_event.signal();
        }
    }

    /// Degradation support: evict those of `node_ids` whose rows are
    /// resident (including zero-published placeholders from a failed
    /// extraction) and currently unreferenced, so a batch retry reloads
    /// them from storage instead of aliasing stale placeholder bytes.
    /// Nodes still referenced by peer batches are left alone (those peers
    /// already own the degraded rows); unmapped nodes are skipped. Returns
    /// the number of slots actually evicted.
    pub fn evict_if_idle(&self, node_ids: &[u32]) -> usize {
        let mut evicted = 0usize;
        for &id in node_ids {
            let shard = self.node_shard(id);
            let mut st =
                self.shards[shard].state.lock().unwrap_or_else(|e| e.into_inner());
            let Some(e) = st.map.get(&id).copied() else { continue };
            let word = self.states.load(e.slot);
            if slot_state::generation(word) != e.generation {
                // Stale entry (the slot was claimed since): just drop it.
                st.map.remove(&id);
                continue;
            }
            if slot_state::refs(word) != 0 {
                continue;
            }
            let Some(new_gen) = self.states.try_claim(e.slot, word) else {
                continue; // raced with a clock claim or a fresh reference
            };
            st.map.remove(&id);
            // Same hand-back ordering as a raced clock claim: untenant
            // while the claim's reference parks the word, zero the word,
            // then publish the slot on the free stack.
            self.reverse[e.slot as usize].store(-1, Ordering::SeqCst);
            self.states.reset(e.slot, 0, false, new_gen);
            self.free.push(e.slot);
            drop(st);
            self.valid_event(e.slot).signal();
            self.free_event.signal();
            evicted += 1;
        }
        evicted
    }

    /// Trainer-side gather: copy each alias's row into `out` (row-major).
    /// Negative aliases (padding) produce zero rows. Lock-free: one acquire
    /// load per row orders the copy behind the publisher's valid store.
    pub fn gather(&self, aliases: &[i32], out: &mut [f32]) {
        assert!(out.len() >= aliases.len() * self.dim);
        let dim = self.dim;
        for (i, &a) in aliases.iter().enumerate() {
            let dst = &mut out[i * dim..(i + 1) * dim];
            if a < 0 {
                dst.fill(0.0);
            } else {
                debug_assert!((a as usize) < self.n_slots, "alias {a} out of range");
                let _word = self.states.load_acquire(a as u32);
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        self.arena.row(a as usize, dim) as *const f32,
                        dst.as_mut_ptr(),
                        dim,
                    );
                }
            }
        }
    }

    /// Whether `node` currently has a published row in this buffer: a live
    /// mapping entry (generation still matching) with the valid bit set.
    /// No reference is taken, so the answer can go stale the moment it
    /// returns — callers own the coordination (the tiered store consults
    /// this from its quiesced drain paths).
    pub fn is_resident(&self, node: u32) -> bool {
        let handle = {
            let st = self.shards[self.node_shard(node)].state.lock().unwrap();
            st.map.get(&node).map(|e| (e.slot, e.generation))
        };
        match handle {
            Some((slot, generation)) => {
                let w = self.states.load(slot);
                slot_state::generation(w) == generation && slot_state::is_valid(w)
            }
            None => false,
        }
    }

    /// (hits, shared, steals, loads) counters for the reuse diagnostics.
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.shared.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.loads.load(Ordering::Relaxed),
        )
    }

    /// Number of reusable (zero-reference) slots: free-stack members plus
    /// clock-evictable residents. The standby list is implicit now, so this
    /// counts states rather than list nodes (tests/diagnostics).
    pub fn standby_len(&self) -> usize {
        (0..self.n_slots as u32)
            .filter(|&s| slot_state::refs(self.states.load(s)) == 0)
            .count()
    }

    /// Validate cross-structure invariants (tests/property checks):
    /// mapping↔reverse bijection, no stale mapping entries left behind by
    /// the deferred eviction sweep, free-stack membership exactly the
    /// untenanted slots, packed slot words consistent with the mapping.
    /// Takes every shard lock; call at quiesce points.
    pub fn check_invariants(&self) -> Result<(), String> {
        let guards: Vec<_> = self.shards.iter().map(|s| s.state.lock().unwrap()).collect();
        let mut slot_owner: HashMap<u32, u32> = HashMap::new();
        for (sx, st) in guards.iter().enumerate() {
            for (&node, e) in &st.map {
                if self.node_shard(node) != sx {
                    return Err(format!("node {node} mapped in wrong shard {sx}"));
                }
                if e.slot as usize >= self.n_slots {
                    return Err(format!("node {node} has bad slot {}", e.slot));
                }
                let word = self.states.load(e.slot);
                if slot_state::generation(word) != e.generation {
                    return Err(format!(
                        "stale entry at quiesce: node {node} slot {} gen {} vs word gen {}",
                        e.slot,
                        e.generation,
                        slot_state::generation(word)
                    ));
                }
                if let Some(prev) = slot_owner.insert(e.slot, node) {
                    return Err(format!("slot {} owned by {prev} and {node}", e.slot));
                }
                let rev = self.reverse[e.slot as usize].load(Ordering::SeqCst);
                if rev != node as i64 {
                    return Err(format!(
                        "reverse[{}]={} but node {node} maps there",
                        e.slot, rev
                    ));
                }
            }
        }
        let parked: HashSet<u32> = self.free.snapshot().into_iter().collect();
        for slot in 0..self.n_slots as u32 {
            let rev = self.reverse[slot as usize].load(Ordering::SeqCst);
            let word = self.states.load(slot);
            if rev >= 0 {
                if slot_owner.get(&slot) != Some(&(rev as u32)) {
                    return Err(format!("reverse[{slot}]={rev} dangling"));
                }
                if parked.contains(&slot) {
                    return Err(format!("tenanted slot {slot} parked on the free stack"));
                }
            } else {
                if !parked.contains(&slot) {
                    return Err(format!("untenanted slot {slot} missing from free stack"));
                }
                if slot_state::refs(word) != 0 {
                    return Err(format!("free slot {slot} holds references"));
                }
                if slot_state::is_valid(word) {
                    return Err(format!("free slot {slot} marked valid"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DeviceMemory;
    use std::sync::Arc;

    fn buf(slots: usize, dim: usize) -> FeatureBuffer {
        let dev = DeviceMemory::new(64 << 20);
        FeatureBuffer::in_device(&dev, slots, dim).unwrap()
    }

    fn load_all(fb: &FeatureBuffer, plan: &BatchPlan) {
        for &(node, slot) in &plan.to_load {
            let row: Vec<f32> = (0..fb.dim).map(|j| (node * 100 + j as u32) as f32).collect();
            fb.publish(node, slot, &row);
        }
    }

    #[test]
    fn fresh_batch_allocates_and_gathers() {
        let fb = buf(8, 4);
        let plan = fb.begin_batch(&[10, 11, 12]);
        assert_eq!(plan.to_load.len(), 3);
        assert!(plan.wait_list.is_empty());
        assert!(plan.aliases.iter().all(|&a| a >= 0));
        load_all(&fb, &plan);
        let mut out = vec![0f32; 3 * 4];
        fb.gather(&plan.aliases, &mut out);
        assert_eq!(out[0], 1000.0); // node 10, j 0
        assert_eq!(out[5], 1101.0); // node 11, j 1
        fb.check_invariants().unwrap();
        fb.release(&[10, 11, 12]);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), 8);
    }

    #[test]
    fn released_nodes_are_reused_without_reload() {
        let fb = buf(8, 2);
        let p1 = fb.begin_batch(&[1, 2, 3]);
        load_all(&fb, &p1);
        fb.release(&[1, 2, 3]);
        let p2 = fb.begin_batch(&[2, 3, 4]);
        // 2 and 3 are hits; only 4 loads.
        assert_eq!(p2.to_load.len(), 1);
        assert_eq!(p2.to_load[0].0, 4);
        let (hits, _, _, loads) = fb.stats();
        assert_eq!(hits, 2);
        assert_eq!(loads, 4);
        // Aliases of 2,3 match their original slots.
        assert_eq!(p2.aliases[0], p1.aliases[1]);
        assert_eq!(p2.aliases[1], p1.aliases[2]);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn clock_claim_invalidates_previous_tenant() {
        let fb = buf(4, 2);
        // Free-stack pops are ascending, so node k lands in slot k-1.
        let p1 = fb.begin_batch(&[1, 2, 3, 4]);
        load_all(&fb, &p1);
        fb.release(&[1, 2, 3, 4]);
        // All four slots zero-ref with fresh clock bits. Two new nodes must
        // claim via the clock (the free stack is empty): the hand strips
        // every clock bit on its first pass, then claims slots 0 and 1 —
        // evicting nodes 1 and 2.
        let p2 = fb.begin_batch(&[5, 6]);
        assert_eq!(p2.to_load.len(), 2);
        load_all(&fb, &p2);
        let (_, _, steals, _) = fb.stats();
        assert_eq!(steals, 2, "each claim evicts one tenant");
        fb.check_invariants().unwrap();
        // The surviving tenants (3 and 4) are still resident and hit.
        let p3 = fb.begin_batch(&[3, 4]);
        assert!(p3.to_load.is_empty(), "survivors must hit without reloading");
        // The evicted tenants re-resolve as fresh loads.
        fb.release(&[5, 6]);
        fb.release(&[3, 4]);
        let p4 = fb.begin_batch(&[1, 2]);
        assert_eq!(p4.to_load.len(), 2, "evicted tenants must reload");
        load_all(&fb, &p4);
        fb.release(&[1, 2]);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn clock_gives_recently_used_slots_a_second_chance() {
        let fb = buf(4, 2);
        // Nodes 1..4 in slots 0..3.
        let p1 = fb.begin_batch(&[1, 2, 3, 4]);
        load_all(&fb, &p1);
        fb.release(&[1, 2, 3, 4]);
        // Node 5's claim sweeps one full pass (clearing every clock bit)
        // and takes slot 0; slots 1..3 are left swept-but-resident.
        let p2 = fb.begin_batch(&[5]);
        assert_eq!(p2.to_load.len(), 1);
        load_all(&fb, &p2);
        // Re-reference node 2: its slot (1) gets a fresh clock bit.
        let p3 = fb.begin_batch(&[2]);
        assert!(p3.to_load.is_empty(), "node 2 still resident");
        fb.release(&[2]);
        // Node 6's claim starts at slot 1, sees the fresh clock bit, grants
        // the second chance, and evicts slot 2 (node 3) instead.
        let p4 = fb.begin_batch(&[6]);
        assert_eq!(p4.to_load.len(), 1);
        load_all(&fb, &p4);
        let p5 = fb.begin_batch(&[2]);
        assert!(
            p5.to_load.is_empty(),
            "recently-used node 2 must survive the sweep"
        );
        let p6 = fb.begin_batch(&[3]);
        assert_eq!(p6.to_load.len(), 1, "swept node 3 was the eviction victim");
        load_all(&fb, &p6);
        fb.release(&[5, 6, 2, 3]);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn release_aliases_matches_release_by_node() {
        // Determinism: identical schedules through the alias path and the
        // node path end in identical stats and alias assignments.
        let schedule: Vec<Vec<u32>> = vec![
            vec![1, 2, 3, 4],
            vec![3, 4, 5, 6],
            vec![1, 2, 7, 8],
            vec![5, 6, 7, 8],
        ];
        let by_node = buf(6, 2);
        let by_alias = buf(6, 2);
        for batch in &schedule {
            let pn = by_node.begin_batch(batch);
            let pa = by_alias.begin_batch(batch);
            assert_eq!(pn.aliases, pa.aliases, "allocation must not depend on release path");
            load_all(&by_node, &pn);
            load_all(&by_alias, &pa);
            by_node.release(batch);
            by_alias.release_aliases(&pa.aliases);
            by_node.check_invariants().unwrap();
            by_alias.check_invariants().unwrap();
        }
        assert_eq!(by_node.stats(), by_alias.stats());
        assert_eq!(by_node.standby_len(), by_alias.standby_len());
    }

    #[test]
    fn release_aliases_skips_padding() {
        let fb = buf(8, 2);
        let plan = fb.begin_batch(&[1, 2]);
        load_all(&fb, &plan);
        let mut padded = plan.aliases.clone();
        padded.push(-1);
        padded.push(-1);
        fb.release_aliases(&padded);
        assert_eq!(fb.standby_len(), 8);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_extraction_shares_inflight_node() {
        let fb = buf(8, 2);
        let p1 = fb.begin_batch(&[7]);
        assert_eq!(p1.to_load.len(), 1);
        // A second "extractor" wants node 7 before it is valid.
        let p2 = fb.begin_batch(&[7, 8]);
        assert_eq!(p2.to_load.len(), 1, "only node 8 loads");
        assert_eq!(p2.wait_list, vec![7]);
        assert_eq!(p2.aliases[0], p1.aliases[0], "shared slot alias");
        assert_eq!(p2.wait_handles.len(), 1);
        assert_eq!(p2.wait_handles[0].node, 7);
        // Publish from extractor 1; waiter unblocks.
        let fb = Arc::new(fb);
        let waiter = {
            let fb = fb.clone();
            std::thread::spawn(move || fb.wait_valid(&[7]))
        };
        std::thread::sleep(std::time::Duration::from_millis(5));
        fb.publish(7, p1.to_load[0].1, &[1.0, 2.0]);
        waiter.join().unwrap();
        fb.wait_plan(&p2); // ticket path: returns immediately, row is valid
        let (_, shared, _, _) = fb.stats();
        assert_eq!(shared, 1);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn begin_batch_blocks_until_release_frees_slots() {
        let fb = Arc::new(buf(4, 2));
        let p1 = fb.begin_batch(&[1, 2, 3, 4]);
        load_all(&fb, &p1);
        // All slots referenced; a new batch must wait for release.
        let fb2 = fb.clone();
        let h = std::thread::spawn(move || {
            let p = fb2.begin_batch(&[9]);
            assert_eq!(p.to_load.len(), 1);
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(!h.is_finished(), "allocation should be blocked");
        fb.release(&[1, 2, 3, 4]);
        h.join().unwrap();
        fb.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "refcount underflow")]
    fn double_release_panics() {
        let fb = buf(4, 2);
        let p = fb.begin_batch(&[1]);
        load_all(&fb, &p);
        fb.release(&[1]);
        fb.release(&[1]);
    }

    #[test]
    #[should_panic(expected = "refcount underflow")]
    fn double_release_aliases_panics() {
        let fb = buf(4, 2);
        let p = fb.begin_batch(&[1]);
        load_all(&fb, &p);
        fb.release_aliases(&p.aliases);
        fb.release_aliases(&p.aliases);
    }

    #[test]
    fn device_memory_charged() {
        let dev = DeviceMemory::new(1 << 20);
        let _fb = FeatureBuffer::in_device(&dev, 100, 16).unwrap();
        assert_eq!(dev.reserved(), 100 * 16 * 4);
        assert!(FeatureBuffer::in_device(&dev, 1 << 20, 16).is_err());
    }

    // ---- sharded-path coverage (the tests above run with one shard) ----

    #[test]
    fn big_buffers_shard_and_roundtrip() {
        let fb = buf(512, 4);
        assert!(fb.shard_count() > 1, "512 slots should shard");
        let nodes: Vec<u32> = (0..300).map(|i| i * 7 + 1).collect();
        let plan = fb.begin_batch(&nodes);
        assert_eq!(plan.to_load.len(), nodes.len());
        assert!(plan.wait_list.is_empty());
        load_all(&fb, &plan);
        let mut out = vec![0f32; nodes.len() * 4];
        fb.gather(&plan.aliases, &mut out);
        for (i, &node) in nodes.iter().enumerate() {
            assert_eq!(out[i * 4], (node * 100) as f32, "node {node} row");
            assert_eq!(out[i * 4 + 3], (node * 100 + 3) as f32, "node {node} row tail");
        }
        fb.check_invariants().unwrap();
        fb.release(&nodes);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), 512);
        // Second pass: everything hits, nothing reloads.
        let p2 = fb.begin_batch(&nodes);
        assert!(p2.to_load.is_empty());
        assert_eq!(p2.aliases, plan.aliases);
        fb.release_aliases(&p2.aliases);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn full_buffer_allocates_each_slot_once_then_blocks() {
        // Fill the whole buffer: every slot allocated exactly once straight
        // off the free stack, no clock claims, no blocking.
        let fb = buf(256, 2);
        assert!(fb.shard_count() > 1);
        let nodes: Vec<u32> = (0..256).collect();
        let plan = fb.begin_batch(&nodes);
        assert_eq!(plan.to_load.len(), 256, "every slot allocated exactly once");
        let (_, _, steals, loads) = fb.stats();
        assert_eq!(loads, 256);
        assert_eq!(steals, 0, "cold start allocates from the free stack");
        load_all(&fb, &plan);
        fb.check_invariants().unwrap();
        // All referenced: one more node must block until a release.
        let fb = Arc::new(fb);
        let fb2 = fb.clone();
        let h = std::thread::spawn(move || {
            let p = fb2.begin_batch(&[9999]);
            assert_eq!(p.to_load.len(), 1);
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(!h.is_finished(), "allocation should be blocked");
        fb.release(&nodes);
        h.join().unwrap();
        fb.check_invariants().unwrap();
    }

    #[test]
    fn stale_wait_handle_returns_after_claim() {
        let fb = buf(4, 2);
        let p1 = fb.begin_batch(&[1]);
        load_all(&fb, &p1);
        let slot = p1.to_load[0].1;
        let gen1 = {
            // Ticket as a sharer would have captured it pre-publish.
            WaitHandle { node: 1, slot, generation: slot_state::generation(fb.states.load(slot)) }
        };
        fb.release(&[1]);
        // Claim node 1's slot: generation moves, the stale ticket must not
        // hang even though valid is cleared again.
        let p2 = fb.begin_batch(&[2, 3, 4, 5]);
        assert_eq!(p2.to_load.len(), 4);
        fb.wait_slot(gen1.slot, gen1.generation); // returns: generation moved
        load_all(&fb, &p2);
        fb.release(&[2, 3, 4, 5]);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn stale_entry_is_dropped_and_reinstalled_on_next_lookup() {
        let fb = buf(4, 2);
        let p1 = fb.begin_batch(&[1]);
        load_all(&fb, &p1);
        fb.release(&[1]);
        // Exhaust the stack and claim node 1's slot.
        let p2 = fb.begin_batch(&[2, 3, 4, 5]);
        assert_eq!(p2.to_load.len(), 4);
        load_all(&fb, &p2);
        fb.check_invariants().unwrap(); // eviction sweep removed node 1's entry
        fb.release(&[2, 3, 4, 5]);
        // Node 1 re-resolves as a fresh load (its old slot is tenanted).
        let p3 = fb.begin_batch(&[1]);
        assert_eq!(p3.to_load.len(), 1);
        load_all(&fb, &p3);
        fb.release_aliases(&p3.aliases);
        fb.check_invariants().unwrap();
    }
}
