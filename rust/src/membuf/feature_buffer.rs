//! GNNDrive's feature-buffer manager (paper §4.2, Fig 6, Algorithm 1).
//!
//! The feature buffer lives in device memory (host memory for CPU-based
//! training) and holds one slot per extracted node row. Four structures
//! manage it, exactly as in the paper:
//!
//! * **mapping table** — node → (slot index, reference count, valid bit);
//! * **reverse mapping** — slot → node (or −1), to identify a slot's tenant;
//! * **standby list** — LRU of slots with zero references: free slots plus
//!   retired-but-reusable ones (inter-batch locality);
//! * **node alias list** — per-batch slot indexes handed to the trainer.
//!
//! State machine per entry: `(slot=-1, valid=0)` absent → `(slot=s,
//! valid=0, ref>0)` being extracted → `(slot=s, valid=1)` ready; a ready
//! node with `ref=0` sits in the standby list and can be either *reused*
//! (hit) or *stolen* (its slot reassigned, entry invalidated). Extractors
//! that find a node mid-extraction by a peer alias its slot, join a wait
//! list, and re-check validity at the end (`wait_valid`) — sharing I/O
//! instead of duplicating it.

use crate::storage::{DeviceMemory, HostMemory, Reservation};
use crate::util::lru::Lru;
use crate::util::fxhash::FxHashMap;
use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

/// Where the buffer's memory is charged.
pub enum BufferHome {
    Device(Reservation),
    Host(Reservation),
}

#[derive(Clone, Copy, Debug, Default)]
struct MapEntry {
    slot: i32,
    ref_count: u32,
    valid: bool,
}

struct BufState {
    map: FxHashMap<u32, MapEntry>,
    /// slot → node id or -1.
    reverse: Vec<i64>,
    /// Zero-reference slots, LRU order (free slots enter via `release`).
    standby: Lru<u32>,
    /// Diagnostics.
    hits: u64,
    shared: u64,
    steals: u64,
    loads: u64,
}

/// The extraction plan for one mini-batch (outcome of Algorithm 1 lines
/// 1–30, before I/O).
#[derive(Debug)]
pub struct BatchPlan {
    /// Slot alias per batch node (parallel to the node list).
    pub aliases: Vec<i32>,
    /// (node, slot) pairs whose rows must be loaded from SSD.
    pub to_load: Vec<(u32, u32)>,
    /// Nodes being extracted by peer extractors; wait for their valid bits.
    pub wait_list: Vec<u32>,
}

pub struct FeatureBuffer {
    pub n_slots: usize,
    pub dim: usize,
    state: Mutex<BufState>,
    /// Signalled when slots enter the standby list.
    slot_freed: Condvar,
    /// Signalled when any node's valid bit is set.
    valid_set: Condvar,
    /// Slot payload. One mutex per slot: writers are PCIe-completion
    /// callbacks, readers are the trainer; contention is per-row and brief.
    data: Vec<Mutex<Box<[f32]>>>,
    _home: BufferHome,
}

impl FeatureBuffer {
    /// Reserve `n_slots × dim` f32 slots in device memory.
    pub fn in_device(
        dev: &DeviceMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = dev.reserve("feature buffer", bytes)?;
        Ok(Self::build(n_slots, dim, BufferHome::Device(res)))
    }

    /// CPU-training variant: the buffer is charged to host memory (§4.4).
    pub fn in_host(
        host: &HostMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = host.reserve("feature buffer (cpu)", bytes)?;
        Ok(Self::build(n_slots, dim, BufferHome::Host(res)))
    }

    fn build(n_slots: usize, dim: usize, home: BufferHome) -> Self {
        let mut standby = Lru::new();
        for s in 0..n_slots as u32 {
            standby.insert(s);
        }
        // Free slots should be consumed oldest-first; insertion above leaves
        // slot 0 at the LRU end… insert order: 0 first → 0 is least recent. ✓
        let data = (0..n_slots)
            .map(|_| Mutex::new(vec![0f32; dim].into_boxed_slice()))
            .collect();
        FeatureBuffer {
            n_slots,
            dim,
            state: Mutex::new(BufState {
                map: FxHashMap::default(),
                reverse: vec![-1; n_slots],
                standby,
                hits: 0,
                shared: 0,
                steals: 0,
                loads: 0,
            }),
            slot_freed: Condvar::new(),
            valid_set: Condvar::new(),
            data,
            _home: home,
        }
    }

    /// Algorithm 1, planning phase: resolve every batch node to a slot,
    /// reusing valid data, sharing in-flight extractions, and allocating LRU
    /// standby slots for the rest (blocking if none are free — the engine
    /// sizes the buffer ≥ (queue depth + extractors) × batch cap so waiting
    /// always terminates). Reference counts of all batch nodes are
    /// incremented here and dropped by `release`.
    pub fn begin_batch(&self, node_ids: &[u32]) -> BatchPlan {
        let mut st = self.state.lock().unwrap();
        let mut aliases = vec![-1i32; node_ids.len()];
        let mut to_load = Vec::new();
        let mut wait_list = Vec::new();

        for (i, &id) in node_ids.iter().enumerate() {
            if let Some(e) = st.map.get(&id).copied() {
                if e.valid {
                    // Ready in the buffer: reuse. A zero-ref entry sits in
                    // the standby list — pull it out so it cannot be stolen.
                    if e.ref_count == 0 {
                        st.standby.remove(&(e.slot as u32));
                    }
                    st.hits += 1;
                    aliases[i] = e.slot;
                } else {
                    // Being extracted by a peer (ref>0, invalid): share it.
                    debug_assert!(e.ref_count > 0, "invalid zero-ref entry leaked");
                    st.shared += 1;
                    aliases[i] = e.slot;
                    wait_list.push(id);
                }
                st.map.get_mut(&id).unwrap().ref_count += 1;
            } else {
                // Absent: allocate the LRU standby slot (Algorithm 1 L24-29).
                let slot = loop {
                    if let Some(s) = st.standby.pop_lru() {
                        break s;
                    }
                    // No standby slot: wait for the releaser.
                    st = self.slot_freed.wait(st).unwrap();
                };
                // Steal: invalidate the previous tenant's mapping.
                let prev = st.reverse[slot as usize];
                if prev >= 0 {
                    st.map.remove(&(prev as u32));
                    st.steals += 1;
                }
                st.reverse[slot as usize] = id as i64;
                st.map.insert(id, MapEntry { slot: slot as i32, ref_count: 1, valid: false });
                st.loads += 1;
                aliases[i] = slot as i32;
                to_load.push((id, slot));
            }
        }
        BatchPlan { aliases, to_load, wait_list }
    }

    /// Write a loaded row into its slot and publish the valid bit
    /// (Algorithm 1 L36; called from the transfer-completion path).
    pub fn publish(&self, node: u32, slot: u32, row: &[f32]) {
        {
            let mut dst = self.data[slot as usize].lock().unwrap();
            let n = dst.len().min(row.len());
            dst[..n].copy_from_slice(&row[..n]);
        }
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.map.get_mut(&node) {
            // The entry may have been stolen+reassigned only if ref hit 0,
            // which cannot happen mid-extraction (we hold a reference).
            debug_assert_eq!(e.slot, slot as i32);
            e.valid = true;
        }
        drop(st);
        self.valid_set.notify_all();
    }

    /// Block until every node in `nodes` has a set valid bit (end of
    /// Algorithm 1: the wait-list check).
    pub fn wait_valid(&self, nodes: &[u32]) {
        let mut st = self.state.lock().unwrap();
        for &id in nodes {
            loop {
                match st.map.get(&id) {
                    Some(e) if e.valid => break,
                    Some(_) => {
                        st = self.valid_set.wait(st).unwrap();
                    }
                    None => break, // released+stolen after we trained on it — impossible while we hold a ref; tolerate in release builds
                }
            }
        }
    }

    /// Releaser: drop one reference per node; zero-ref slots re-enter the
    /// standby list MRU-first (retired but reusable — inter-batch locality).
    /// Mapping entries stay valid until stolen (§4.2 "Release").
    pub fn release(&self, node_ids: &[u32]) {
        let mut st = self.state.lock().unwrap();
        let mut freed = false;
        for &id in node_ids {
            let e = st.map.get_mut(&id).expect("release of unmapped node");
            assert!(e.ref_count > 0, "refcount underflow for node {id}");
            e.ref_count -= 1;
            if e.ref_count == 0 {
                let slot = e.slot as u32;
                st.standby.insert(slot);
                freed = true;
            }
        }
        drop(st);
        if freed {
            self.slot_freed.notify_all();
        }
    }

    /// Trainer-side gather: copy each alias's row into `out` (row-major).
    /// Negative aliases (padding) produce zero rows.
    pub fn gather(&self, aliases: &[i32], out: &mut [f32]) {
        assert!(out.len() >= aliases.len() * self.dim);
        for (i, &a) in aliases.iter().enumerate() {
            let dst = &mut out[i * self.dim..(i + 1) * self.dim];
            if a < 0 {
                dst.fill(0.0);
            } else {
                let row = self.data[a as usize].lock().unwrap();
                dst.copy_from_slice(&row);
            }
        }
    }

    /// (hits, shared, steals, loads) counters for the reuse diagnostics.
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        let st = self.state.lock().unwrap();
        (st.hits, st.shared, st.steals, st.loads)
    }

    /// Number of slots currently in the standby list (tests/diagnostics).
    pub fn standby_len(&self) -> usize {
        self.state.lock().unwrap().standby.len()
    }

    /// Validate cross-structure invariants (tests/property checks):
    /// mapping↔reverse bijection, standby = exactly the zero-ref mapped
    /// slots plus never-used free slots, no two nodes sharing a slot.
    pub fn check_invariants(&self) -> Result<(), String> {
        let st = self.state.lock().unwrap();
        let mut slot_owner: HashMap<i32, u32> = HashMap::new();
        for (&node, e) in &st.map {
            if e.slot < 0 || e.slot as usize >= self.n_slots {
                return Err(format!("node {node} has bad slot {}", e.slot));
            }
            if let Some(prev) = slot_owner.insert(e.slot, node) {
                return Err(format!("slot {} owned by {prev} and {node}", e.slot));
            }
            if st.reverse[e.slot as usize] != node as i64 {
                return Err(format!(
                    "reverse[{}]={} but node {node} maps there",
                    e.slot, st.reverse[e.slot as usize]
                ));
            }
            if e.ref_count == 0 && !st.standby.contains(&(e.slot as u32)) {
                return Err(format!("zero-ref node {node} slot {} not standby", e.slot));
            }
            if e.ref_count > 0 && st.standby.contains(&(e.slot as u32)) {
                return Err(format!("referenced slot {} in standby", e.slot));
            }
        }
        for (slot, &node) in st.reverse.iter().enumerate() {
            if node >= 0 {
                match st.map.get(&(node as u32)) {
                    Some(e) if e.slot == slot as i32 => {}
                    _ => return Err(format!("reverse[{slot}]={node} dangling")),
                }
            } else if !st.standby.contains(&(slot as u32)) {
                return Err(format!("empty slot {slot} missing from standby"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DeviceMemory;
    use std::sync::Arc;

    fn buf(slots: usize, dim: usize) -> FeatureBuffer {
        let dev = DeviceMemory::new(64 << 20);
        FeatureBuffer::in_device(&dev, slots, dim).unwrap()
    }

    fn load_all(fb: &FeatureBuffer, plan: &BatchPlan) {
        for &(node, slot) in &plan.to_load {
            let row: Vec<f32> = (0..fb.dim).map(|j| (node * 100 + j as u32) as f32).collect();
            fb.publish(node, slot, &row);
        }
    }

    #[test]
    fn fresh_batch_allocates_and_gathers() {
        let fb = buf(8, 4);
        let plan = fb.begin_batch(&[10, 11, 12]);
        assert_eq!(plan.to_load.len(), 3);
        assert!(plan.wait_list.is_empty());
        assert!(plan.aliases.iter().all(|&a| a >= 0));
        load_all(&fb, &plan);
        let mut out = vec![0f32; 3 * 4];
        fb.gather(&plan.aliases, &mut out);
        assert_eq!(out[0], 1000.0); // node 10, j 0
        assert_eq!(out[5], 1101.0); // node 11, j 1
        fb.check_invariants().unwrap();
        fb.release(&[10, 11, 12]);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), 8);
    }

    #[test]
    fn released_nodes_are_reused_without_reload() {
        let fb = buf(8, 2);
        let p1 = fb.begin_batch(&[1, 2, 3]);
        load_all(&fb, &p1);
        fb.release(&[1, 2, 3]);
        let p2 = fb.begin_batch(&[2, 3, 4]);
        // 2 and 3 are hits; only 4 loads.
        assert_eq!(p2.to_load.len(), 1);
        assert_eq!(p2.to_load[0].0, 4);
        let (hits, _, _, loads) = fb.stats();
        assert_eq!(hits, 2);
        assert_eq!(loads, 4);
        // Aliases of 2,3 match their original slots.
        assert_eq!(p2.aliases[0], p1.aliases[1]);
        assert_eq!(p2.aliases[1], p1.aliases[2]);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn lru_steal_invalidates_previous_tenant() {
        let fb = buf(4, 2);
        let p1 = fb.begin_batch(&[1, 2, 3, 4]);
        load_all(&fb, &p1);
        fb.release(&[1, 2, 3, 4]);
        // All four slots standby, LRU order 1,2,3,4. Two new nodes steal
        // the two LRU slots (1's and 2's).
        let p2 = fb.begin_batch(&[5, 6]);
        assert_eq!(p2.to_load.len(), 2);
        let (_, _, steals, _) = fb.stats();
        assert_eq!(steals, 2);
        // Nodes 1,2 are gone from the mapping; 3,4 still reusable.
        let p3 = fb.begin_batch(&[3, 4]);
        assert!(p3.to_load.is_empty());
        fb.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_extraction_shares_inflight_node() {
        let fb = buf(8, 2);
        let p1 = fb.begin_batch(&[7]);
        assert_eq!(p1.to_load.len(), 1);
        // A second "extractor" wants node 7 before it is valid.
        let p2 = fb.begin_batch(&[7, 8]);
        assert_eq!(p2.to_load.len(), 1, "only node 8 loads");
        assert_eq!(p2.wait_list, vec![7]);
        assert_eq!(p2.aliases[0], p1.aliases[0], "shared slot alias");
        // Publish from extractor 1; waiter unblocks.
        let fb = Arc::new(fb);
        let waiter = {
            let fb = fb.clone();
            std::thread::spawn(move || fb.wait_valid(&[7]))
        };
        std::thread::sleep(std::time::Duration::from_millis(5));
        fb.publish(7, p1.to_load[0].1, &[1.0, 2.0]);
        waiter.join().unwrap();
        let (_, shared, _, _) = fb.stats();
        assert_eq!(shared, 1);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn begin_batch_blocks_until_release_frees_slots() {
        let fb = Arc::new(buf(4, 2));
        let p1 = fb.begin_batch(&[1, 2, 3, 4]);
        load_all(&fb, &p1);
        // All slots referenced; a new batch must wait for release.
        let fb2 = fb.clone();
        let h = std::thread::spawn(move || {
            let p = fb2.begin_batch(&[9]);
            assert_eq!(p.to_load.len(), 1);
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(!h.is_finished(), "allocation should be blocked");
        fb.release(&[1, 2, 3, 4]);
        h.join().unwrap();
        fb.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "refcount underflow")]
    fn double_release_panics() {
        let fb = buf(4, 2);
        let p = fb.begin_batch(&[1]);
        load_all(&fb, &p);
        fb.release(&[1]);
        fb.release(&[1]);
    }

    #[test]
    fn device_memory_charged() {
        let dev = DeviceMemory::new(1 << 20);
        let _fb = FeatureBuffer::in_device(&dev, 100, 16).unwrap();
        assert_eq!(dev.reserved(), 100 * 16 * 4);
        assert!(FeatureBuffer::in_device(&dev, 1 << 20, 16).is_err());
    }
}
