//! GNNDrive's feature-buffer manager (paper §4.2, Fig 6, Algorithm 1),
//! re-architected as a sharded, lock-minimized coordinator.
//!
//! The feature buffer lives in device memory (host memory for CPU-based
//! training) and holds one slot per extracted node row. The paper's four
//! structures are all here, but arranged for concurrency:
//!
//! * **mapping table** — node → (slot, generation); *sharded by node-id
//!   hash* so concurrent extractors planning different batches take
//!   different locks (`begin_batch` groups its node list per shard and takes
//!   each shard mutex at most once on the fast path);
//! * **reverse mapping** — slot → node (or −1), per-slot atomics;
//! * **standby list** — LRU of zero-reference slots, one list per shard
//!   (a freed slot parks in its tenant node's shard; a dry shard steals the
//!   LRU slot of a peer shard — approximate global LRU, exact within a
//!   shard, and exactly the old global order when there is one shard);
//! * **node alias list** — per-batch slot indexes handed to the trainer.
//!
//! Row payloads live in one contiguous flat arena instead of
//! `Vec<Mutex<Box<[f32]>>>`; a packed per-slot `AtomicU64`
//! (`refcount | valid | generation`, see [`super::slot_state`]) carries the
//! slot's lifecycle. `publish` is write-row + release-store of the valid bit
//! + targeted wakeup; `gather` is an acquire load + `copy_nonoverlapping`
//! per row — no per-row locks anywhere. The old condvar broadcasts
//! (`notify_all` on every release and publish) are replaced by
//! [`EventCount`]s whose signal side is one atomic load when nobody waits.
//!
//! State machine per entry is unchanged from the paper: `(slot=-1,
//! valid=0)` absent → `(slot=s, valid=0, ref>0)` being extracted →
//! `(slot=s, valid=1)` ready; a ready node with `ref=0` sits in a standby
//! list and can be either *reused* (hit) or *stolen* (slot reassigned,
//! generation bumped, entry invalidated). Extractors that find a node
//! mid-extraction by a peer alias its slot, join the wait list, and re-check
//! validity at the end (`wait_valid`/`wait_plan`) — sharing I/O instead of
//! duplicating it.
//!
//! The pre-shard single-mutex coordinator is preserved verbatim as
//! [`super::single_mutex::SingleMutexFeatureBuffer`] so
//! `benches/micro_hotpath.rs` can measure the contention win against it.

use super::shard::{EventCount, MapEntry, Shard, ShardState};
use super::slot_state::{self, SlotStates};
use crate::storage::{DeviceMemory, HostMemory, Reservation};
use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Where the buffer's memory is charged.
pub enum BufferHome {
    Device(Reservation),
    Host(Reservation),
}

/// Wait-group fan-out for publish wakeups (power of two; a waiter parks on
/// `slot % WAIT_GROUPS`, so a publish wakes only the waiters hashed to its
/// group instead of every waiter in the system).
const WAIT_GROUPS: usize = 64;

/// Stale-handle ticket for one awaited slot: resolved once at plan time so
/// `wait_plan` never re-locks a shard.
#[derive(Clone, Copy, Debug)]
pub struct WaitHandle {
    pub node: u32,
    pub slot: u32,
    pub generation: u32,
}

/// The extraction plan for one mini-batch (outcome of Algorithm 1 lines
/// 1–30, before I/O).
#[derive(Debug)]
pub struct BatchPlan {
    /// Slot alias per batch node (parallel to the node list).
    pub aliases: Vec<i32>,
    /// (node, slot) pairs whose rows must be loaded from SSD.
    pub to_load: Vec<(u32, u32)>,
    /// Nodes being extracted by peer extractors; wait for their valid bits.
    pub wait_list: Vec<u32>,
    /// Pre-resolved (slot, generation) tickets for `wait_list` — lets
    /// `wait_plan` spin on the packed slot words without shard locks.
    pub wait_handles: Vec<WaitHandle>,
}

/// Flat row arena. Rows are disjoint and single-writer by protocol (only
/// the extractor that planned a slot's load publishes into it, and readers
/// are ordered behind the valid bit), so access goes through raw pointers —
/// no per-row mutex, no `&mut` aliasing over the whole buffer.
struct Arena {
    base: *mut f32,
    len: usize,
}

unsafe impl Send for Arena {}
unsafe impl Sync for Arena {}

impl Arena {
    fn new(len: usize) -> Self {
        let boxed = vec![0f32; len].into_boxed_slice();
        Arena { base: Box::into_raw(boxed) as *mut f32, len }
    }

    #[inline]
    fn row(&self, slot: usize, dim: usize) -> *mut f32 {
        debug_assert!((slot + 1) * dim <= self.len);
        // Provenance: `base` came from Box::into_raw over the whole arena.
        unsafe { self.base.add(slot * dim) }
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        unsafe {
            drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(self.base, self.len)));
        }
    }
}

/// Outcome of resolving one node inside its shard.
enum Resolved {
    /// Ready in the buffer (hit): alias this slot.
    Alias(u32),
    /// Being extracted by a peer: alias + wait for its valid bit.
    Wait(u32, u32),
    /// Newly allocated: caller must load the row, then publish.
    Load(u32),
    /// Shard has no standby slot; take the slow allocation path.
    Dry,
}

pub struct FeatureBuffer {
    pub n_slots: usize,
    pub dim: usize,
    shards: Vec<Shard>,
    /// `shards.len() - 1`; shard count is a power of two.
    shard_mask: usize,
    states: SlotStates,
    /// slot → tenant node id or -1.
    reverse: Vec<AtomicI64>,
    arena: Arena,
    /// Signalled when slots enter a standby list and allocators are waiting.
    free_event: EventCount,
    /// Publish wakeups, fanned out by `slot % WAIT_GROUPS`.
    valid_events: Vec<EventCount>,
    /// Diagnostics.
    hits: AtomicU64,
    shared: AtomicU64,
    steals: AtomicU64,
    loads: AtomicU64,
    _home: BufferHome,
}

/// Largest power of two ≤ `x` (x ≥ 1).
fn floor_pow2(x: usize) -> usize {
    1 << (usize::BITS - 1 - x.leading_zeros())
}

/// Shard count policy: tiny buffers (unit tests, degenerate configs) get one
/// shard — making the coordinator *exactly* the paper's global-LRU machine —
/// while production-sized buffers get up to 16 shards with ≥64 slots each.
fn shard_count_for(n_slots: usize) -> usize {
    if n_slots < 256 {
        1
    } else {
        floor_pow2((n_slots / 64).min(16))
    }
}

impl FeatureBuffer {
    /// Reserve `n_slots × dim` f32 slots in device memory.
    pub fn in_device(
        dev: &DeviceMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = dev.reserve("feature buffer", bytes)?;
        Ok(Self::build(n_slots, dim, BufferHome::Device(res)))
    }

    /// CPU-training variant: the buffer is charged to host memory (§4.4).
    pub fn in_host(
        host: &HostMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = host.reserve("feature buffer (cpu)", bytes)?;
        Ok(Self::build(n_slots, dim, BufferHome::Host(res)))
    }

    fn build(n_slots: usize, dim: usize, home: BufferHome) -> Self {
        let n_shards = shard_count_for(n_slots);
        let shards: Vec<Shard> =
            (0..n_shards).map(|_| Shard::new(n_slots / n_shards + 1)).collect();
        // Distribute the free slots round-robin; within a shard the insert
        // order is ascending, so slot `s` is consumed before slot `s + n`.
        for (sx, shard) in shards.iter().enumerate() {
            let mut st = shard.state.lock().unwrap();
            for s in (sx..n_slots).step_by(n_shards) {
                st.standby.insert(s as u32);
            }
        }
        FeatureBuffer {
            n_slots,
            dim,
            shard_mask: n_shards - 1,
            shards,
            states: SlotStates::new(n_slots),
            reverse: (0..n_slots).map(|_| AtomicI64::new(-1)).collect(),
            arena: Arena::new(n_slots * dim),
            free_event: EventCount::new(),
            valid_events: (0..WAIT_GROUPS.min(n_slots.max(1))).map(|_| EventCount::new()).collect(),
            hits: AtomicU64::new(0),
            shared: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            loads: AtomicU64::new(0),
            _home: home,
        }
    }

    /// Number of mapping-table shards (diagnostics / benches).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn node_shard(&self, node: u32) -> usize {
        // Fibonacci mix; the low bits of raw node ids correlate with batch
        // layout, which would unbalance the shards.
        let h = (node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) & self.shard_mask
    }

    #[inline]
    fn valid_event(&self, slot: u32) -> &EventCount {
        &self.valid_events[slot as usize % self.valid_events.len()]
    }

    /// Resolve one node against its own shard (`st` is `shard_idx`'s state,
    /// and `node_shard(id) == shard_idx`). Increments the reference count on
    /// every outcome except `Dry`.
    fn resolve_in_shard(&self, st: &mut ShardState, id: u32) -> Resolved {
        if let Some(e) = st.map.get(&id).copied() {
            let word = self.states.load(e.slot);
            debug_assert_eq!(slot_state::generation(word), e.generation, "map/word gen skew");
            if slot_state::is_valid(word) {
                // Ready in the buffer: reuse. A zero-ref entry sits in this
                // shard's standby list — pull it out so it cannot be stolen.
                if slot_state::refs(word) == 0 {
                    let removed = st.standby.remove(&e.slot);
                    debug_assert!(removed, "zero-ref valid slot {} not in standby", e.slot);
                }
                self.states.add_ref(e.slot);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Resolved::Alias(e.slot)
            } else {
                // Being extracted by a peer (ref>0, invalid): share it.
                debug_assert!(slot_state::refs(word) > 0, "invalid zero-ref entry leaked");
                self.states.add_ref(e.slot);
                self.shared.fetch_add(1, Ordering::Relaxed);
                Resolved::Wait(e.slot, e.generation)
            }
        } else if let Some(slot) = st.standby.pop_lru() {
            // Absent: allocate this shard's LRU standby slot (Algorithm 1
            // L24-29). Steal = invalidate the previous tenant's mapping; by
            // the parking invariant the tenant hashes to this same shard.
            let generation = self.claim_slot(st, slot);
            self.install(st, id, slot, generation);
            Resolved::Load(slot)
        } else {
            Resolved::Dry
        }
    }

    /// Evict `slot`'s previous tenant (if any) from `st`'s map and bump the
    /// slot generation. Returns the new generation; the slot is left
    /// unmapped, invalid, zero-ref — exclusively owned by the caller.
    fn claim_slot(&self, st: &mut ShardState, slot: u32) -> u32 {
        let prev = self.reverse[slot as usize].swap(-1, Ordering::SeqCst);
        if prev >= 0 {
            let removed = st.map.remove(&(prev as u32));
            debug_assert!(removed.is_some(), "stolen slot {slot} had no mapping");
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
        let generation = slot_state::generation(self.states.load(slot)).wrapping_add(1);
        self.states.reset(slot, 0, false, generation);
        // A waiter parked on the old generation must re-check and bail.
        self.valid_event(slot).signal();
        generation
    }

    /// Map `id` to an exclusively-owned free slot inside `id`'s shard.
    fn install(&self, st: &mut ShardState, id: u32, slot: u32, generation: u32) {
        self.reverse[slot as usize].store(id as i64, Ordering::SeqCst);
        self.states.reset(slot, 1, false, generation);
        st.map.insert(id, MapEntry { slot, generation });
        self.loads.fetch_add(1, Ordering::Relaxed);
    }

    /// Stable counting sort of batch positions by shard: `order` holds the
    /// positions `0..len` grouped per shard (original order within a
    /// shard), `ends[s]` the exclusive end of shard `s`'s run. Two
    /// allocations per batch instead of one `Vec` per shard.
    fn group_positions(&self, node_ids: &[u32]) -> (Vec<u32>, Vec<u32>) {
        let n_shards = self.shards.len();
        let mut cursor = vec![0u32; n_shards];
        for &id in node_ids {
            cursor[self.node_shard(id)] += 1;
        }
        let mut start = 0u32;
        for c in cursor.iter_mut() {
            let count = *c;
            *c = start;
            start += count;
        }
        let mut order = vec![0u32; node_ids.len()];
        for (i, &id) in node_ids.iter().enumerate() {
            let s = self.node_shard(id);
            order[cursor[s] as usize] = i as u32;
            cursor[s] += 1;
        }
        // After the fill, cursor[s] is exactly shard s's exclusive end.
        (order, cursor)
    }

    /// Algorithm 1, planning phase: resolve every batch node to a slot,
    /// reusing valid data, sharing in-flight extractions, and allocating LRU
    /// standby slots for the rest (blocking if none are free anywhere — the
    /// engine sizes the buffer ≥ (queue depth + extractors) × batch cap so
    /// waiting always terminates). Reference counts of all batch nodes are
    /// incremented here and dropped by `release`.
    pub fn begin_batch(&self, node_ids: &[u32]) -> BatchPlan {
        let mut aliases = vec![-1i32; node_ids.len()];
        let mut to_load = Vec::new();
        let mut wait_list = Vec::new();
        let mut wait_handles = Vec::new();
        let mut deferred: Vec<usize> = Vec::new();

        let apply = |i: usize,
                         r: Resolved,
                         aliases: &mut Vec<i32>,
                         to_load: &mut Vec<(u32, u32)>,
                         wait_list: &mut Vec<u32>,
                         wait_handles: &mut Vec<WaitHandle>|
         -> bool {
            let id = node_ids[i];
            match r {
                Resolved::Alias(slot) => aliases[i] = slot as i32,
                Resolved::Wait(slot, generation) => {
                    aliases[i] = slot as i32;
                    wait_list.push(id);
                    wait_handles.push(WaitHandle { node: id, slot, generation });
                }
                Resolved::Load(slot) => {
                    aliases[i] = slot as i32;
                    to_load.push((id, slot));
                }
                Resolved::Dry => return false,
            }
            true
        };

        if self.shards.len() == 1 {
            // Single shard: one lock for the whole batch, original order.
            let mut st = self.shards[0].state.lock().unwrap();
            for (i, &id) in node_ids.iter().enumerate() {
                let r = self.resolve_in_shard(&mut st, id);
                if !apply(i, r, &mut aliases, &mut to_load, &mut wait_list, &mut wait_handles) {
                    deferred.push(i);
                }
            }
        } else {
            // Group the batch per shard so each shard lock is taken at most
            // once on this fast path (within a shard, batch order holds).
            let (order, ends) = self.group_positions(node_ids);
            let mut start = 0usize;
            for (sx, &end) in ends.iter().enumerate() {
                let end = end as usize;
                if end > start {
                    let mut st = self.shards[sx].state.lock().unwrap();
                    for &pos in &order[start..end] {
                        let i = pos as usize;
                        let r = self.resolve_in_shard(&mut st, node_ids[i]);
                        if !apply(
                            i,
                            r,
                            &mut aliases,
                            &mut to_load,
                            &mut wait_list,
                            &mut wait_handles,
                        ) {
                            deferred.push(i);
                        }
                    }
                }
                start = end;
            }
            deferred.sort_unstable(); // re-establish batch order across shards
        }

        // Slow path: the node's home shard was dry — steal from a peer shard
        // or wait for a release.
        for i in deferred {
            let r = self.alloc_slow(node_ids[i]);
            let ok =
                apply(i, r, &mut aliases, &mut to_load, &mut wait_list, &mut wait_handles);
            debug_assert!(ok, "alloc_slow cannot return Dry");
        }
        BatchPlan { aliases, to_load, wait_list, wait_handles }
    }

    /// Allocate a slot for `id` when its home shard has no standby slot:
    /// retry the home shard, then steal another shard's LRU slot, then block
    /// on the free event until a release parks something.
    fn alloc_slow(&self, id: u32) -> Resolved {
        let home = self.node_shard(id);
        loop {
            if let Some(r) = self.try_alloc(home, id) {
                return r;
            }
            let seen = self.free_event.begin_wait();
            if let Some(r) = self.try_alloc(home, id) {
                self.free_event.cancel_wait();
                return r;
            }
            self.free_event.wait(seen);
        }
    }

    fn try_alloc(&self, home: usize, id: u32) -> Option<Resolved> {
        // A peer may have mapped the node (or released a slot) meanwhile.
        {
            let mut st = self.shards[home].state.lock().unwrap();
            match self.resolve_in_shard(&mut st, id) {
                Resolved::Dry => {}
                r => return Some(r),
            }
        }
        // Steal a peer shard's LRU slot. The stolen slot's previous tenant
        // hashes to that same shard, so eviction needs only that one lock;
        // the slot then migrates into `home`.
        for d in 1..self.shards.len() {
            let sx = (home + d) & self.shard_mask;
            let stolen = {
                let mut st = self.shards[sx].state.lock().unwrap();
                st.standby.pop_lru().map(|slot| (slot, self.claim_slot(&mut st, slot)))
            };
            let Some((slot, generation)) = stolen else { continue };
            let mut st = self.shards[home].state.lock().unwrap();
            match self.resolve_in_shard(&mut st, id) {
                Resolved::Dry => {
                    self.install(&mut st, id, slot, generation);
                    return Some(Resolved::Load(slot));
                }
                r => {
                    // Raced: the node got mapped (or home refilled) while we
                    // were stealing. Park the stolen slot here as free.
                    st.standby.insert(slot);
                    drop(st);
                    self.free_event.signal();
                    return Some(r);
                }
            }
        }
        None
    }

    /// Write a loaded row into its slot and publish the valid bit
    /// (Algorithm 1 L36; called from the transfer-completion path). The
    /// caller is the slot's unique loader (it holds a reference and planned
    /// the load), so the row write is race-free by protocol.
    pub fn publish(&self, node: u32, slot: u32, row: &[f32]) {
        let n = self.dim.min(row.len());
        unsafe {
            std::ptr::copy_nonoverlapping(row.as_ptr(), self.arena.row(slot as usize, self.dim), n);
        }
        self.finish_publish(node, slot);
    }

    /// `publish` from little-endian raw bytes (the staging buffer's wire
    /// format) — decodes straight into the arena with no intermediate
    /// `Vec<f32>` per row.
    pub fn publish_le_bytes(&self, node: u32, slot: u32, bytes: &[u8]) {
        let n = self.dim.min(bytes.len() / 4);
        let dst = self.arena.row(slot as usize, self.dim);
        for (i, chunk) in bytes.chunks_exact(4).take(n).enumerate() {
            let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            unsafe {
                *dst.add(i) = v;
            }
        }
        self.finish_publish(node, slot);
    }

    fn finish_publish(&self, node: u32, slot: u32) {
        debug_assert_eq!(
            self.reverse[slot as usize].load(Ordering::SeqCst),
            node as i64,
            "publish into a slot node {node} does not own"
        );
        let word = self.states.set_valid(slot);
        debug_assert!(slot_state::refs(word) > 0, "publish into zero-ref slot {slot}");
        self.valid_event(slot).signal();
    }

    /// Wait until `slot`'s valid bit is set — or until the slot is stolen
    /// out from under a stale handle (generation moved), which mirrors the
    /// old "entry vanished from the map" tolerance.
    fn wait_slot(&self, slot: u32, generation: u32) {
        let done = |word: u64| {
            slot_state::is_valid(word) || slot_state::generation(word) != generation
        };
        loop {
            if done(self.states.load(slot)) {
                return;
            }
            let ev = self.valid_event(slot);
            let seen = ev.begin_wait();
            if done(self.states.load(slot)) {
                ev.cancel_wait();
                return;
            }
            ev.wait(seen);
        }
    }

    /// Block until every node in `nodes` has a set valid bit (end of
    /// Algorithm 1: the wait-list check). Nodes no longer mapped are
    /// skipped, as before.
    pub fn wait_valid(&self, nodes: &[u32]) {
        for &id in nodes {
            let handle = {
                let st = self.shards[self.node_shard(id)].state.lock().unwrap();
                st.map.get(&id).map(|e| (e.slot, e.generation))
            };
            if let Some((slot, generation)) = handle {
                self.wait_slot(slot, generation);
            }
        }
    }

    /// `wait_valid` over a plan's pre-resolved tickets: no shard locks at
    /// all on the wait path.
    pub fn wait_plan(&self, plan: &BatchPlan) {
        for h in &plan.wait_handles {
            self.wait_slot(h.slot, h.generation);
        }
    }

    /// Releaser: drop one reference per node; zero-ref slots re-enter their
    /// shard's standby list MRU-first (retired but reusable — inter-batch
    /// locality). Mapping entries stay valid until stolen (§4.2 "Release").
    pub fn release(&self, node_ids: &[u32]) {
        let mut freed = false;
        if self.shards.len() == 1 {
            let mut st = self.shards[0].state.lock().unwrap();
            for &id in node_ids {
                freed |= self.release_one(&mut st, id);
            }
        } else {
            let (order, ends) = self.group_positions(node_ids);
            let mut start = 0usize;
            for (sx, &end) in ends.iter().enumerate() {
                let end = end as usize;
                if end > start {
                    let mut st = self.shards[sx].state.lock().unwrap();
                    for &pos in &order[start..end] {
                        freed |= self.release_one(&mut st, node_ids[pos as usize]);
                    }
                }
                start = end;
            }
        }
        if freed {
            self.free_event.signal();
        }
    }

    fn release_one(&self, st: &mut ShardState, id: u32) -> bool {
        let e = *st.map.get(&id).expect("release of unmapped node");
        let word = self.states.load(e.slot);
        assert!(slot_state::refs(word) > 0, "refcount underflow for node {id}");
        let prev = self.states.sub_ref(e.slot);
        if slot_state::refs(prev) == 1 {
            st.standby.insert(e.slot);
            true
        } else {
            false
        }
    }

    /// Trainer-side gather: copy each alias's row into `out` (row-major).
    /// Negative aliases (padding) produce zero rows. Lock-free: one acquire
    /// load per row orders the copy behind the publisher's valid store.
    pub fn gather(&self, aliases: &[i32], out: &mut [f32]) {
        assert!(out.len() >= aliases.len() * self.dim);
        let dim = self.dim;
        for (i, &a) in aliases.iter().enumerate() {
            let dst = &mut out[i * dim..(i + 1) * dim];
            if a < 0 {
                dst.fill(0.0);
            } else {
                debug_assert!((a as usize) < self.n_slots, "alias {a} out of range");
                let _word = self.states.load_acquire(a as u32);
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        self.arena.row(a as usize, dim) as *const f32,
                        dst.as_mut_ptr(),
                        dim,
                    );
                }
            }
        }
    }

    /// (hits, shared, steals, loads) counters for the reuse diagnostics.
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.shared.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.loads.load(Ordering::Relaxed),
        )
    }

    /// Number of slots currently in standby lists (tests/diagnostics).
    pub fn standby_len(&self) -> usize {
        self.shards.iter().map(|s| s.state.lock().unwrap().standby.len()).sum()
    }

    /// Validate cross-structure invariants (tests/property checks):
    /// mapping↔reverse bijection, per-shard standby = exactly that shard's
    /// zero-ref mapped slots plus parked free slots, packed slot words
    /// consistent with the mapping, no two nodes sharing a slot. Takes every
    /// shard lock; call at quiesce points.
    pub fn check_invariants(&self) -> Result<(), String> {
        let guards: Vec<_> = self.shards.iter().map(|s| s.state.lock().unwrap()).collect();
        // Standby membership: each slot in at most one shard's list.
        let mut standby_shard: HashMap<u32, usize> = HashMap::new();
        for (sx, st) in guards.iter().enumerate() {
            for &slot in st.standby.iter_mru() {
                if slot as usize >= self.n_slots {
                    return Err(format!("standby slot {slot} out of range"));
                }
                if let Some(other) = standby_shard.insert(slot, sx) {
                    return Err(format!("slot {slot} in standby of shards {other} and {sx}"));
                }
            }
        }
        let mut slot_owner: HashMap<u32, u32> = HashMap::new();
        for (sx, st) in guards.iter().enumerate() {
            for (&node, e) in &st.map {
                if self.node_shard(node) != sx {
                    return Err(format!("node {node} mapped in wrong shard {sx}"));
                }
                if e.slot as usize >= self.n_slots {
                    return Err(format!("node {node} has bad slot {}", e.slot));
                }
                if let Some(prev) = slot_owner.insert(e.slot, node) {
                    return Err(format!("slot {} owned by {prev} and {node}", e.slot));
                }
                let rev = self.reverse[e.slot as usize].load(Ordering::SeqCst);
                if rev != node as i64 {
                    return Err(format!(
                        "reverse[{}]={} but node {node} maps there",
                        e.slot, rev
                    ));
                }
                let word = self.states.load(e.slot);
                if slot_state::generation(word) != e.generation {
                    return Err(format!(
                        "node {node} slot {} generation skew: word {} vs map {}",
                        e.slot,
                        slot_state::generation(word),
                        e.generation
                    ));
                }
                let refs = slot_state::refs(word);
                match standby_shard.get(&e.slot) {
                    Some(&home) if refs == 0 => {
                        if home != sx {
                            return Err(format!(
                                "zero-ref slot {} parked in shard {home}, tenant shard {sx}",
                                e.slot
                            ));
                        }
                    }
                    Some(_) => {
                        return Err(format!("referenced slot {} in standby", e.slot));
                    }
                    None if refs == 0 => {
                        return Err(format!(
                            "zero-ref node {node} slot {} not standby",
                            e.slot
                        ));
                    }
                    None => {}
                }
            }
        }
        for slot in 0..self.n_slots as u32 {
            let rev = self.reverse[slot as usize].load(Ordering::SeqCst);
            if rev >= 0 {
                if slot_owner.get(&slot) != Some(&(rev as u32)) {
                    return Err(format!("reverse[{slot}]={rev} dangling"));
                }
            } else {
                if !standby_shard.contains_key(&slot) {
                    return Err(format!("empty slot {slot} missing from standby"));
                }
                let word = self.states.load(slot);
                if slot_state::refs(word) != 0 {
                    return Err(format!("free slot {slot} holds references"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DeviceMemory;
    use std::sync::Arc;

    fn buf(slots: usize, dim: usize) -> FeatureBuffer {
        let dev = DeviceMemory::new(64 << 20);
        FeatureBuffer::in_device(&dev, slots, dim).unwrap()
    }

    fn load_all(fb: &FeatureBuffer, plan: &BatchPlan) {
        for &(node, slot) in &plan.to_load {
            let row: Vec<f32> = (0..fb.dim).map(|j| (node * 100 + j as u32) as f32).collect();
            fb.publish(node, slot, &row);
        }
    }

    #[test]
    fn fresh_batch_allocates_and_gathers() {
        let fb = buf(8, 4);
        let plan = fb.begin_batch(&[10, 11, 12]);
        assert_eq!(plan.to_load.len(), 3);
        assert!(plan.wait_list.is_empty());
        assert!(plan.aliases.iter().all(|&a| a >= 0));
        load_all(&fb, &plan);
        let mut out = vec![0f32; 3 * 4];
        fb.gather(&plan.aliases, &mut out);
        assert_eq!(out[0], 1000.0); // node 10, j 0
        assert_eq!(out[5], 1101.0); // node 11, j 1
        fb.check_invariants().unwrap();
        fb.release(&[10, 11, 12]);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), 8);
    }

    #[test]
    fn released_nodes_are_reused_without_reload() {
        let fb = buf(8, 2);
        let p1 = fb.begin_batch(&[1, 2, 3]);
        load_all(&fb, &p1);
        fb.release(&[1, 2, 3]);
        let p2 = fb.begin_batch(&[2, 3, 4]);
        // 2 and 3 are hits; only 4 loads.
        assert_eq!(p2.to_load.len(), 1);
        assert_eq!(p2.to_load[0].0, 4);
        let (hits, _, _, loads) = fb.stats();
        assert_eq!(hits, 2);
        assert_eq!(loads, 4);
        // Aliases of 2,3 match their original slots.
        assert_eq!(p2.aliases[0], p1.aliases[1]);
        assert_eq!(p2.aliases[1], p1.aliases[2]);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn lru_steal_invalidates_previous_tenant() {
        let fb = buf(4, 2);
        let p1 = fb.begin_batch(&[1, 2, 3, 4]);
        load_all(&fb, &p1);
        fb.release(&[1, 2, 3, 4]);
        // All four slots standby, LRU order 1,2,3,4. Two new nodes steal
        // the two LRU slots (1's and 2's).
        let p2 = fb.begin_batch(&[5, 6]);
        assert_eq!(p2.to_load.len(), 2);
        let (_, _, steals, _) = fb.stats();
        assert_eq!(steals, 2);
        // Nodes 1,2 are gone from the mapping; 3,4 still reusable.
        let p3 = fb.begin_batch(&[3, 4]);
        assert!(p3.to_load.is_empty());
        fb.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_extraction_shares_inflight_node() {
        let fb = buf(8, 2);
        let p1 = fb.begin_batch(&[7]);
        assert_eq!(p1.to_load.len(), 1);
        // A second "extractor" wants node 7 before it is valid.
        let p2 = fb.begin_batch(&[7, 8]);
        assert_eq!(p2.to_load.len(), 1, "only node 8 loads");
        assert_eq!(p2.wait_list, vec![7]);
        assert_eq!(p2.aliases[0], p1.aliases[0], "shared slot alias");
        assert_eq!(p2.wait_handles.len(), 1);
        assert_eq!(p2.wait_handles[0].node, 7);
        // Publish from extractor 1; waiter unblocks.
        let fb = Arc::new(fb);
        let waiter = {
            let fb = fb.clone();
            std::thread::spawn(move || fb.wait_valid(&[7]))
        };
        std::thread::sleep(std::time::Duration::from_millis(5));
        fb.publish(7, p1.to_load[0].1, &[1.0, 2.0]);
        waiter.join().unwrap();
        fb.wait_plan(&p2); // ticket path: returns immediately, row is valid
        let (_, shared, _, _) = fb.stats();
        assert_eq!(shared, 1);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn begin_batch_blocks_until_release_frees_slots() {
        let fb = Arc::new(buf(4, 2));
        let p1 = fb.begin_batch(&[1, 2, 3, 4]);
        load_all(&fb, &p1);
        // All slots referenced; a new batch must wait for release.
        let fb2 = fb.clone();
        let h = std::thread::spawn(move || {
            let p = fb2.begin_batch(&[9]);
            assert_eq!(p.to_load.len(), 1);
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(!h.is_finished(), "allocation should be blocked");
        fb.release(&[1, 2, 3, 4]);
        h.join().unwrap();
        fb.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "refcount underflow")]
    fn double_release_panics() {
        let fb = buf(4, 2);
        let p = fb.begin_batch(&[1]);
        load_all(&fb, &p);
        fb.release(&[1]);
        fb.release(&[1]);
    }

    #[test]
    fn device_memory_charged() {
        let dev = DeviceMemory::new(1 << 20);
        let _fb = FeatureBuffer::in_device(&dev, 100, 16).unwrap();
        assert_eq!(dev.reserved(), 100 * 16 * 4);
        assert!(FeatureBuffer::in_device(&dev, 1 << 20, 16).is_err());
    }

    // ---- sharded-path coverage (the tests above run with one shard) ----

    #[test]
    fn big_buffers_shard_and_roundtrip() {
        let fb = buf(512, 4);
        assert!(fb.shard_count() > 1, "512 slots should shard");
        let nodes: Vec<u32> = (0..300).map(|i| i * 7 + 1).collect();
        let plan = fb.begin_batch(&nodes);
        assert_eq!(plan.to_load.len(), nodes.len());
        assert!(plan.wait_list.is_empty());
        load_all(&fb, &plan);
        let mut out = vec![0f32; nodes.len() * 4];
        fb.gather(&plan.aliases, &mut out);
        for (i, &node) in nodes.iter().enumerate() {
            assert_eq!(out[i * 4], (node * 100) as f32, "node {node} row");
            assert_eq!(out[i * 4 + 3], (node * 100 + 3) as f32, "node {node} row tail");
        }
        fb.check_invariants().unwrap();
        fb.release(&nodes);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), 512);
        // Second pass: everything hits, nothing reloads.
        let p2 = fb.begin_batch(&nodes);
        assert!(p2.to_load.is_empty());
        assert_eq!(p2.aliases, plan.aliases);
        fb.release(&nodes);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn dry_shard_steals_cross_shard() {
        // Fill the whole buffer: node hashing is uneven, so at least one
        // shard runs dry and must migrate slots from its peers. Everything
        // still allocates exactly once without blocking.
        let fb = buf(256, 2);
        assert!(fb.shard_count() > 1);
        let nodes: Vec<u32> = (0..256).collect();
        let plan = fb.begin_batch(&nodes);
        assert_eq!(plan.to_load.len(), 256, "every slot allocated exactly once");
        let (_, _, _, loads) = fb.stats();
        assert_eq!(loads, 256);
        load_all(&fb, &plan);
        fb.check_invariants().unwrap();
        // All referenced: one more node must block until a release.
        let fb = Arc::new(fb);
        let fb2 = fb.clone();
        let h = std::thread::spawn(move || {
            let p = fb2.begin_batch(&[9999]);
            assert_eq!(p.to_load.len(), 1);
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(!h.is_finished(), "allocation should be blocked");
        fb.release(&nodes);
        h.join().unwrap();
        fb.check_invariants().unwrap();
    }

    #[test]
    fn stale_wait_handle_returns_after_steal() {
        let fb = buf(4, 2);
        let p1 = fb.begin_batch(&[1]);
        load_all(&fb, &p1);
        let slot = p1.to_load[0].1;
        let gen1 = {
            // Ticket as a sharer would have captured it pre-publish.
            WaitHandle { node: 1, slot, generation: slot_state::generation(fb.states.load(slot)) }
        };
        fb.release(&[1]);
        // Steal node 1's slot: generation moves, the stale ticket must not
        // hang even though valid is cleared again.
        let p2 = fb.begin_batch(&[2, 3, 4, 5]);
        assert_eq!(p2.to_load.len(), 4);
        fb.wait_slot(gen1.slot, gen1.generation); // returns: generation moved
        fb.release(&[2, 3, 4, 5]);
        fb.check_invariants().unwrap();
    }
}
