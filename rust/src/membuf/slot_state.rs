//! Packed per-slot atomic state for the sharded feature buffer.
//!
//! One `AtomicU64` per slot encodes the quadruple the coordinator used to
//! keep behind the global mutex:
//!
//! ```text
//!   bits  0..=31   generation (wraps; bumped every time the slot is stolen)
//!   bit   32       valid (the row's data is published)
//!   bits 33..=52   reference count (how many in-flight batches alias it)
//!   bit   53       clock (second-chance "recently used" bit for eviction)
//! ```
//!
//! `publish` becomes a single release `fetch_or` of the valid bit, and
//! `wait_valid`/`gather` read one word instead of taking a lock. The
//! generation lets a waiter detect that "its" slot was stolen and reassigned
//! (stale handle) without consulting the mapping table.
//!
//! Since the lock-free allocation path landed, the packed word is also the
//! *authority* for slot ownership: a reference is taken with a
//! generation-checked CAS ([`SlotStates::try_ref`]) and an eviction claims a
//! zero-reference slot with a CAS that bumps the generation
//! ([`SlotStates::try_claim`]), so the clock sweep, the hit path, and the
//! release path all race safely without any mutex. The clock bit is set on
//! every reference grab and cleared by a passing clock hand — a slot
//! survives one sweep after its last use (second chance ≈ LRU).

use std::sync::atomic::{AtomicU64, Ordering};

/// Valid bit: the slot's row has been published.
pub const VALID: u64 = 1 << 32;
/// One reference in the packed refcount field.
pub const REF_ONE: u64 = 1 << 33;
/// Second-chance bit: the slot was referenced since the clock hand last
/// passed it.
pub const CLOCK: u64 = 1 << 53;

const GEN_MASK: u64 = u32::MAX as u64;
const REF_SHIFT: u32 = 33;
const REF_FIELD_BITS: u32 = 20;
const REF_MASK: u64 = ((1u64 << REF_FIELD_BITS) - 1) << REF_SHIFT;

/// Maximum representable reference count (engine batch sizing keeps real
/// counts orders of magnitude below this).
pub const MAX_REFS: u32 = (1 << REF_FIELD_BITS) - 1;

#[inline]
pub fn pack(refs: u32, valid: bool, generation: u32) -> u64 {
    debug_assert!(refs <= MAX_REFS);
    (generation as u64) | if valid { VALID } else { 0 } | ((refs as u64) << REF_SHIFT)
}

#[inline]
pub fn generation(word: u64) -> u32 {
    (word & GEN_MASK) as u32
}

#[inline]
pub fn is_valid(word: u64) -> bool {
    word & VALID != 0
}

#[inline]
pub fn refs(word: u64) -> u32 {
    ((word & REF_MASK) >> REF_SHIFT) as u32
}

#[inline]
pub fn has_clock(word: u64) -> bool {
    word & CLOCK != 0
}

/// The flat array of packed slot words.
pub struct SlotStates {
    words: Vec<AtomicU64>,
}

impl SlotStates {
    pub fn new(n_slots: usize) -> Self {
        SlotStates { words: (0..n_slots).map(|_| AtomicU64::new(pack(0, false, 0))).collect() }
    }

    #[inline]
    pub fn load(&self, slot: u32) -> u64 {
        self.words[slot as usize].load(Ordering::SeqCst)
    }

    /// Acquire-load for the gather hot path: establishes the happens-before
    /// edge with the publisher's release of the valid bit before the row
    /// bytes are read out of the arena.
    #[inline]
    pub fn load_acquire(&self, slot: u32) -> u64 {
        self.words[slot as usize].load(Ordering::Acquire)
    }

    /// Publish: set the valid bit; returns the previous word.
    #[inline]
    pub fn set_valid(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_or(VALID, Ordering::SeqCst)
    }

    /// Add one reference unconditionally. Used by the mutex-LRU baseline
    /// (which serializes refcount changes under its shard lock); the
    /// lock-free coordinator takes references through [`SlotStates::try_ref`]
    /// instead, because an unconditional add can race a claim.
    #[inline]
    pub fn add_ref(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_add(REF_ONE, Ordering::SeqCst)
    }

    /// Drop one reference; returns the previous word. Called with *no lock
    /// held* on the lock-free release path: coherence rests on the caller
    /// actually holding a reference (the plan's aliases are released exactly
    /// once), which also pins the generation — a slot with live references
    /// can never be claimed. Callers must verify `refs(prev) > 0` to catch
    /// protocol violations.
    #[inline]
    pub fn sub_ref(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_sub(REF_ONE, Ordering::SeqCst)
    }

    /// Reassign the slot outright (steal / adopt paths; the caller owns the
    /// slot exclusively, so a plain store is race-free).
    #[inline]
    pub fn reset(&self, slot: u32, refs: u32, valid: bool, generation: u32) {
        self.words[slot as usize].store(pack(refs, valid, generation), Ordering::SeqCst);
    }

    /// Take one reference iff the slot still carries `expected_gen` — the
    /// lock-free hit/share path. The CAS also sets the clock bit (the slot
    /// was just used). Returns the pre-CAS word on success; on generation
    /// mismatch (the slot was stolen out from under the mapping entry)
    /// returns the current word so the caller can treat the entry as stale.
    #[inline]
    pub fn try_ref(&self, slot: u32, expected_gen: u32) -> Result<u64, u64> {
        let w = &self.words[slot as usize];
        let mut cur = w.load(Ordering::SeqCst);
        loop {
            if generation(cur) != expected_gen {
                return Err(cur);
            }
            debug_assert!(refs(cur) < MAX_REFS, "refcount saturated on slot {slot}");
            match w.compare_exchange_weak(
                cur,
                (cur + REF_ONE) | CLOCK,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(prev) => return Ok(prev),
                Err(now) => cur = now,
            }
        }
    }

    /// Claim a zero-reference slot for a new tenant (clock eviction): CAS
    /// the exact `expected` word to a claimed word — one reference, invalid,
    /// generation bumped, clock set. A successful claim transfers exclusive
    /// ownership (any surviving mapping entry for the old tenant now has a
    /// stale generation and every `try_ref` through it fails). Returns the
    /// new generation.
    #[inline]
    pub fn try_claim(&self, slot: u32, expected: u64) -> Option<u32> {
        debug_assert_eq!(refs(expected), 0, "claim of referenced slot {slot}");
        let next_gen = generation(expected).wrapping_add(1);
        self.words[slot as usize]
            .compare_exchange(
                expected,
                pack(1, false, next_gen) | CLOCK,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .ok()
            .map(|_| next_gen)
    }

    /// Activate a free-list slot for its first tenant: the caller owns the
    /// slot exclusively (it popped it off the free stack), so a plain store
    /// of one reference / invalid / clock-set suffices. The generation is
    /// kept — no mapping entry can reference it. Returns that generation.
    #[inline]
    pub fn activate(&self, slot: u32) -> u32 {
        let g = generation(self.load(slot));
        self.words[slot as usize].store(pack(1, false, g) | CLOCK, Ordering::SeqCst);
        g
    }

    /// Clock-hand pass: strip the second-chance bit, leaving everything else
    /// (a `fetch_and` composes safely with concurrent ref/claim CASes).
    #[inline]
    pub fn clear_clock(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_and(!CLOCK, Ordering::SeqCst)
    }

    /// Mark the slot recently used without taking a reference (fresh
    /// placements that should survive the next clock pass; the GPU tier's
    /// promotion path). Composes safely with concurrent ref/claim CASes.
    #[inline]
    pub fn set_clock(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_or(CLOCK, Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrip() {
        for &(r, v, g) in &[(0u32, false, 0u32), (1, true, 7), (MAX_REFS, true, u32::MAX)] {
            let w = pack(r, v, g);
            assert_eq!(refs(w), r);
            assert_eq!(is_valid(w), v);
            assert_eq!(generation(w), g);
        }
    }

    #[test]
    fn fields_are_independent() {
        let s = SlotStates::new(4);
        s.reset(2, 0, false, 41);
        s.add_ref(2);
        s.add_ref(2);
        let w = s.set_valid(2);
        assert_eq!(refs(w), 2);
        assert!(!is_valid(w));
        let w = s.load(2);
        assert!(is_valid(w));
        assert_eq!(refs(w), 2);
        assert_eq!(generation(w), 41);
        let w = s.sub_ref(2);
        assert_eq!(refs(w), 2, "fetch_sub returns the prior word");
        assert_eq!(refs(s.load(2)), 1);
        // Untouched neighbors stay at the initial word.
        assert_eq!(s.load(1), pack(0, false, 0));
    }

    #[test]
    fn try_ref_checks_generation_and_sets_clock() {
        let s = SlotStates::new(2);
        s.reset(0, 0, true, 7);
        let prev = s.try_ref(0, 7).expect("generation matches");
        assert_eq!(refs(prev), 0);
        assert!(is_valid(prev));
        let w = s.load(0);
        assert_eq!(refs(w), 1);
        assert!(has_clock(w), "a reference grab marks the slot recently used");
        // Stale handle: wrong generation is rejected without mutating.
        let cur = s.try_ref(0, 6).expect_err("stale generation");
        assert_eq!(generation(cur), 7);
        assert_eq!(refs(s.load(0)), 1);
    }

    #[test]
    fn try_claim_bumps_generation_and_takes_ownership() {
        let s = SlotStates::new(1);
        s.reset(0, 0, true, 3);
        let word = s.load(0);
        let new_gen = s.try_claim(0, word).expect("zero-ref slot claimable");
        assert_eq!(new_gen, 4);
        let w = s.load(0);
        assert_eq!(refs(w), 1);
        assert!(!is_valid(w));
        assert!(has_clock(w));
        // The old tenant's handle is now stale.
        assert!(s.try_ref(0, 3).is_err());
        // A second claim against the old word fails (CAS exactness).
        assert!(s.try_claim(0, word).is_none());
    }

    #[test]
    fn activate_and_clear_clock() {
        let s = SlotStates::new(1);
        let g = s.activate(0);
        assert_eq!(g, 0);
        let w = s.load(0);
        assert_eq!(refs(w), 1);
        assert!(!is_valid(w));
        assert!(has_clock(w));
        s.set_valid(0);
        s.sub_ref(0);
        let before = s.clear_clock(0);
        assert!(has_clock(before), "clear_clock returns the pre-clear word");
        let w = s.load(0);
        assert!(!has_clock(w));
        assert!(is_valid(w));
        assert_eq!(refs(w), 0);
        assert_eq!(generation(w), 0);
    }

    #[test]
    fn generation_wraps_without_touching_flags() {
        let s = SlotStates::new(1);
        s.reset(0, 3, true, u32::MAX);
        let w = s.load(0);
        assert_eq!(generation(w), u32::MAX);
        s.reset(0, 3, true, generation(w).wrapping_add(1));
        let w = s.load(0);
        assert_eq!(generation(w), 0);
        assert_eq!(refs(w), 3);
        assert!(is_valid(w));
    }
}
