//! Packed per-slot atomic state for the sharded feature buffer.
//!
//! One `AtomicU64` per slot encodes the triple the coordinator used to keep
//! behind the global mutex:
//!
//! ```text
//!   bits  0..=31   generation (wraps; bumped every time the slot is stolen)
//!   bit   32       valid (the row's data is published)
//!   bits 33..=52   reference count (how many in-flight batches alias it)
//! ```
//!
//! `publish` becomes a single release `fetch_or` of the valid bit, and
//! `wait_valid`/`gather` read one word instead of taking a lock. Reference
//! counts are only mutated under the owning node's shard lock (they must stay
//! coherent with the shard's mapping table), but living in the packed word
//! lets the lock-free readers and `check_invariants` observe a consistent
//! snapshot. The generation lets a waiter detect that "its" slot was stolen
//! and reassigned (stale handle) without consulting the mapping table.

use std::sync::atomic::{AtomicU64, Ordering};

/// Valid bit: the slot's row has been published.
pub const VALID: u64 = 1 << 32;
/// One reference in the packed refcount field.
pub const REF_ONE: u64 = 1 << 33;

const GEN_MASK: u64 = u32::MAX as u64;
const REF_SHIFT: u32 = 33;
const REF_FIELD_BITS: u32 = 20;
const REF_MASK: u64 = ((1u64 << REF_FIELD_BITS) - 1) << REF_SHIFT;

/// Maximum representable reference count (engine batch sizing keeps real
/// counts orders of magnitude below this).
pub const MAX_REFS: u32 = (1 << REF_FIELD_BITS) - 1;

#[inline]
pub fn pack(refs: u32, valid: bool, generation: u32) -> u64 {
    debug_assert!(refs <= MAX_REFS);
    (generation as u64) | if valid { VALID } else { 0 } | ((refs as u64) << REF_SHIFT)
}

#[inline]
pub fn generation(word: u64) -> u32 {
    (word & GEN_MASK) as u32
}

#[inline]
pub fn is_valid(word: u64) -> bool {
    word & VALID != 0
}

#[inline]
pub fn refs(word: u64) -> u32 {
    ((word & REF_MASK) >> REF_SHIFT) as u32
}

/// The flat array of packed slot words.
pub struct SlotStates {
    words: Vec<AtomicU64>,
}

impl SlotStates {
    pub fn new(n_slots: usize) -> Self {
        SlotStates { words: (0..n_slots).map(|_| AtomicU64::new(pack(0, false, 0))).collect() }
    }

    #[inline]
    pub fn load(&self, slot: u32) -> u64 {
        self.words[slot as usize].load(Ordering::SeqCst)
    }

    /// Acquire-load for the gather hot path: establishes the happens-before
    /// edge with the publisher's release of the valid bit before the row
    /// bytes are read out of the arena.
    #[inline]
    pub fn load_acquire(&self, slot: u32) -> u64 {
        self.words[slot as usize].load(Ordering::Acquire)
    }

    /// Publish: set the valid bit; returns the previous word.
    #[inline]
    pub fn set_valid(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_or(VALID, Ordering::SeqCst)
    }

    /// Add one reference (caller holds the tenant node's shard lock).
    #[inline]
    pub fn add_ref(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_add(REF_ONE, Ordering::SeqCst)
    }

    /// Drop one reference (caller holds the tenant node's shard lock and has
    /// checked `refs > 0`); returns the previous word.
    #[inline]
    pub fn sub_ref(&self, slot: u32) -> u64 {
        self.words[slot as usize].fetch_sub(REF_ONE, Ordering::SeqCst)
    }

    /// Reassign the slot outright (steal / adopt paths; the caller owns the
    /// slot exclusively, so a plain store is race-free).
    #[inline]
    pub fn reset(&self, slot: u32, refs: u32, valid: bool, generation: u32) {
        self.words[slot as usize].store(pack(refs, valid, generation), Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrip() {
        for &(r, v, g) in &[(0u32, false, 0u32), (1, true, 7), (MAX_REFS, true, u32::MAX)] {
            let w = pack(r, v, g);
            assert_eq!(refs(w), r);
            assert_eq!(is_valid(w), v);
            assert_eq!(generation(w), g);
        }
    }

    #[test]
    fn fields_are_independent() {
        let s = SlotStates::new(4);
        s.reset(2, 0, false, 41);
        s.add_ref(2);
        s.add_ref(2);
        let w = s.set_valid(2);
        assert_eq!(refs(w), 2);
        assert!(!is_valid(w));
        let w = s.load(2);
        assert!(is_valid(w));
        assert_eq!(refs(w), 2);
        assert_eq!(generation(w), 41);
        let w = s.sub_ref(2);
        assert_eq!(refs(w), 2, "fetch_sub returns the prior word");
        assert_eq!(refs(s.load(2)), 1);
        // Untouched neighbors stay at the initial word.
        assert_eq!(s.load(1), pack(0, false, 0));
    }

    #[test]
    fn generation_wraps_without_touching_flags() {
        let s = SlotStates::new(1);
        s.reset(0, 3, true, u32::MAX);
        let w = s.load(0);
        assert_eq!(generation(w), u32::MAX);
        s.reset(0, 3, true, generation(w).wrapping_add(1));
        let w = s.load(0);
        assert_eq!(generation(w), 0);
        assert_eq!(refs(w), 3);
        assert!(is_valid(w));
    }
}
