//! Staging buffer: the *only* host memory GNNDrive's extract stage uses.
//!
//! Per the paper (§4.2), its size is bounded by #extractors × the maximum
//! nodes per mini-batch × row bytes — it exists solely to land direct-I/O
//! reads from SSD before the asynchronous PCIe transfer into the device
//! feature buffer, so host memory stays available for the sampling working
//! set. Each extractor owns one [`StagingBuffer`]; slots are reused across
//! mini-batches.
//!
//! Slots are handed around as [`SlotRef`]s — plain `(arena, index)` handles
//! into one contiguous byte arena. I/O completions write through them with a
//! raw `memcpy` and readers decode straight out of the arena: there is no
//! mutex per row anywhere on the submit/complete path. Safety rests on the
//! extraction protocol (one in-flight request owns a slot range exclusively;
//! the engine's completion queue provides the happens-before edge between
//! the completion write and the harvesting reader).

use crate::storage::{HostMemory, Reservation};
use std::cell::UnsafeCell;
use std::sync::Arc;

/// A contiguous `slots × row_bytes` byte arena accessed through raw slot
/// handles. The arena itself never synchronizes: callers uphold the
/// single-owner-per-slot-range protocol described on [`SlotRef`].
pub struct StagingArena {
    data: Box<[UnsafeCell<u8>]>,
    row_bytes: usize,
}

// SAFETY: the arena is a bag of bytes behind `UnsafeCell`. All mutation goes
// through `SlotRef`, whose contract guarantees that concurrently accessed
// byte ranges are disjoint and that cross-thread hand-off happens through a
// synchronizing channel (the engine's completion queue / the wave latch).
unsafe impl Sync for StagingArena {}
unsafe impl Send for StagingArena {}

impl StagingArena {
    pub fn new(slots: usize, row_bytes: usize) -> Arc<Self> {
        assert!(row_bytes > 0, "staging rows must be non-empty");
        let data: Vec<UnsafeCell<u8>> =
            (0..slots * row_bytes).map(|_| UnsafeCell::new(0)).collect();
        Arc::new(StagingArena { data: data.into_boxed_slice(), row_bytes })
    }

    pub fn slots(&self) -> usize {
        self.data.len() / self.row_bytes
    }

    pub fn row_bytes(&self) -> usize {
        self.row_bytes
    }

    fn slot_ptr(&self, slot: usize) -> *mut u8 {
        debug_assert!(slot < self.slots(), "slot {slot} out of range");
        // `UnsafeCell<u8>` is `repr(transparent)`, so the boxed slice is a
        // contiguous byte buffer and in-bounds pointer arithmetic is valid.
        self.data[slot * self.row_bytes].get()
    }
}

/// Handle to one staging slot: the destination of an async read and the
/// source of the subsequent decode into the feature buffer.
///
/// Protocol (what makes the unsynchronized byte accesses sound):
/// * while a request is in flight, its `[dst_off, dst_off+len)` range of the
///   slot is owned exclusively by the serving I/O worker;
/// * concurrent requests targeting the same slot use disjoint ranges;
/// * the reader (extractor / PCIe completion) touches the bytes only after
///   harvesting the request's CQE, which happens-after the worker's write
///   via the completion queue's internal lock.
#[derive(Clone)]
pub struct SlotRef {
    arena: Arc<StagingArena>,
    slot: usize,
}

impl SlotRef {
    pub fn new(arena: Arc<StagingArena>, slot: usize) -> Self {
        debug_assert!(slot < arena.slots());
        SlotRef { arena, slot }
    }

    pub fn len(&self) -> usize {
        self.arena.row_bytes
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy `src` into the slot at `dst_off` (completion-side write; no
    /// lock). Caller must own `[dst_off, dst_off+src.len())` per the slot
    /// protocol.
    pub fn write(&self, dst_off: usize, src: &[u8]) {
        assert!(dst_off + src.len() <= self.len(), "slot write out of range");
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.as_ptr(),
                self.arena.slot_ptr(self.slot).add(dst_off),
                src.len(),
            );
        }
    }

    /// Mutable view of `[off, off+len)` for an I/O engine to read into.
    ///
    /// # Safety
    /// The caller must own that byte range per the slot protocol: no other
    /// thread may read or write it until the owning request's completion has
    /// been published through a synchronizing channel.
    #[allow(clippy::mut_from_ref)] // interior mutability via UnsafeCell
    pub unsafe fn slice_mut(&self, off: usize, len: usize) -> &mut [u8] {
        assert!(off + len <= self.len(), "slot range out of bounds");
        std::slice::from_raw_parts_mut(self.arena.slot_ptr(self.slot).add(off), len)
    }

    /// The slot's bytes (reader side). Sound only after the writes of every
    /// in-flight request on this slot have been synchronized to this thread
    /// (CQE harvested / wave latch passed) — the same protocol
    /// `FeatureBuffer::publish` already relies on.
    pub fn bytes(&self) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(self.arena.slot_ptr(self.slot), self.len())
        }
    }
}

pub struct StagingBuffer {
    arena: Arc<StagingArena>,
    pub row_bytes: usize,
    _res: Reservation,
}

impl StagingBuffer {
    /// Reserve `slots × row_bytes` of host memory for one extractor.
    pub fn new(
        host: &HostMemory,
        slots: usize,
        row_bytes: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let res = host.reserve("staging buffer", (slots * row_bytes) as u64)?;
        Ok(StagingBuffer { arena: StagingArena::new(slots, row_bytes), row_bytes, _res: res })
    }

    pub fn slots(&self) -> usize {
        self.arena.slots()
    }

    /// Handle to slot `i` (cheap: an `Arc` clone + index; the ring and the
    /// PCIe callback share the arena).
    pub fn slot(&self, i: usize) -> SlotRef {
        SlotRef::new(self.arena.clone(), i)
    }

    pub fn bytes(&self) -> u64 {
        (self.slots() * self.row_bytes) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserves_and_exposes_slots() {
        let host = HostMemory::new(1 << 20);
        let sb = StagingBuffer::new(&host, 16, 512).unwrap();
        assert_eq!(sb.slots(), 16);
        assert_eq!(sb.bytes(), 16 * 512);
        assert_eq!(host.reserved(), 16 * 512);
        {
            let b = sb.slot(3);
            b.write(0, &[42]);
        }
        assert_eq!(sb.slot(3).bytes()[0], 42);
        drop(sb);
        assert_eq!(host.reserved(), 0);
    }

    #[test]
    fn oom_when_host_too_small() {
        let host = HostMemory::new(1024);
        assert!(StagingBuffer::new(&host, 16, 512).is_err());
    }

    #[test]
    fn slot_writes_are_disjoint_and_readable() {
        let arena = StagingArena::new(4, 8);
        let a = SlotRef::new(arena.clone(), 0);
        let b = SlotRef::new(arena.clone(), 1);
        a.write(0, &[1, 2, 3, 4]);
        a.write(4, &[5, 6, 7, 8]);
        b.write(0, &[9; 8]);
        assert_eq!(a.bytes(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(b.bytes(), &[9; 8]);
        // Clones address the same slot.
        let a2 = a.clone();
        a2.write(0, &[0xAA]);
        assert_eq!(a.bytes()[0], 0xAA);
    }

    #[test]
    fn cross_thread_handoff_delivers_bytes() {
        let arena = StagingArena::new(2, 64);
        let slot = SlotRef::new(arena, 0);
        let writer = slot.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            writer.write(0, &[7u8; 64]);
            tx.send(()).unwrap(); // the synchronizing channel of the protocol
        });
        rx.recv().unwrap();
        assert!(slot.bytes().iter().all(|&x| x == 7));
        h.join().unwrap();
    }
}
