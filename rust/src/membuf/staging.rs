//! Staging buffer: the *only* host memory GNNDrive's extract stage uses.
//!
//! Per the paper (§4.2), its size is bounded by #extractors × the maximum
//! nodes per mini-batch × row bytes — it exists solely to land direct-I/O
//! reads from SSD before the asynchronous PCIe transfer into the device
//! feature buffer, so host memory stays available for the sampling working
//! set. Each extractor owns one [`StagingBuffer`]; slots are reused across
//! mini-batches.

use crate::storage::uring::IoBuf;
use crate::storage::{HostMemory, Reservation};
use std::sync::{Arc, Mutex};

pub struct StagingBuffer {
    bufs: Vec<IoBuf>,
    pub row_bytes: usize,
    _res: Reservation,
}

impl StagingBuffer {
    /// Reserve `slots × row_bytes` of host memory for one extractor.
    pub fn new(
        host: &HostMemory,
        slots: usize,
        row_bytes: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let res = host.reserve("staging buffer", (slots * row_bytes) as u64)?;
        let bufs = (0..slots)
            .map(|_| Arc::new(Mutex::new(vec![0u8; row_bytes])) as IoBuf)
            .collect();
        Ok(StagingBuffer { bufs, row_bytes, _res: res })
    }

    pub fn slots(&self) -> usize {
        self.bufs.len()
    }

    /// Slot `i`'s buffer (cloned handle; the ring and the PCIe callback
    /// share it).
    pub fn slot(&self, i: usize) -> IoBuf {
        self.bufs[i].clone()
    }

    pub fn bytes(&self) -> u64 {
        (self.bufs.len() * self.row_bytes) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserves_and_exposes_slots() {
        let host = HostMemory::new(1 << 20);
        let sb = StagingBuffer::new(&host, 16, 512).unwrap();
        assert_eq!(sb.slots(), 16);
        assert_eq!(sb.bytes(), 16 * 512);
        assert_eq!(host.reserved(), 16 * 512);
        {
            let b = sb.slot(3);
            b.lock().unwrap()[0] = 42;
        }
        assert_eq!(sb.slot(3).lock().unwrap()[0], 42);
        drop(sb);
        assert_eq!(host.reserved(), 0);
    }

    #[test]
    fn oom_when_host_too_small() {
        let host = HostMemory::new(1024);
        assert!(StagingBuffer::new(&host, 16, 512).is_err());
    }
}
