//! Staging buffer: the *only* host memory GNNDrive's extract stage uses.
//!
//! Per the paper (§4.2), its size is bounded by #extractors × the maximum
//! nodes per mini-batch × row bytes — it exists solely to land direct-I/O
//! reads from SSD before the asynchronous PCIe transfer into the device
//! feature buffer, so host memory stays available for the sampling working
//! set. Each extractor owns one [`StagingBuffer`]; the arena is reused
//! across mini-batches.
//!
//! The arena is **range-granular**: a [`SlotRef`] names an arbitrary
//! contiguous byte range, not a fixed one-row slot. The extractor's
//! coalescing layer allocates one range per multi-row *segment* (a merged
//! run of feature rows read by a single device request) through a per-wave
//! bump allocator ([`WaveAlloc`]); the legacy one-row constructor
//! ([`SlotRef::new`]) remains for engines/tests that address the arena as
//! `slots × row_bytes`. I/O completions write through ranges with a raw
//! `memcpy` and readers decode straight out of the arena: there is no mutex
//! per row anywhere on the submit/complete path. Safety rests on the
//! extraction protocol (one in-flight request owns its byte range
//! exclusively; the engine's completion queue provides the happens-before
//! edge between the completion write and the harvesting reader; the
//! wave-end latch quiesces the arena before ranges are reissued).

use crate::storage::{HostMemory, Reservation};
use std::cell::UnsafeCell;
use std::sync::Arc;

/// A contiguous byte arena accessed through raw range handles. The arena
/// itself never synchronizes: callers uphold the single-owner-per-range
/// protocol described on [`SlotRef`].
pub struct StagingArena {
    data: Box<[UnsafeCell<u8>]>,
    row_bytes: usize,
}

// SAFETY: the arena is a bag of bytes behind `UnsafeCell`. All mutation goes
// through `SlotRef`, whose contract guarantees that concurrently accessed
// byte ranges are disjoint and that cross-thread hand-off happens through a
// synchronizing channel (the engine's completion queue / the wave latch).
unsafe impl Sync for StagingArena {}
unsafe impl Send for StagingArena {}

impl StagingArena {
    pub fn new(slots: usize, row_bytes: usize) -> Arc<Self> {
        assert!(row_bytes > 0, "staging rows must be non-empty");
        let data: Vec<UnsafeCell<u8>> =
            (0..slots * row_bytes).map(|_| UnsafeCell::new(0)).collect();
        Arc::new(StagingArena { data: data.into_boxed_slice(), row_bytes })
    }

    pub fn slots(&self) -> usize {
        self.data.len() / self.row_bytes
    }

    pub fn row_bytes(&self) -> usize {
        self.row_bytes
    }

    /// Total arena capacity in bytes (the wave allocator's budget).
    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    /// Base address of the arena's contiguous byte store, for engines that
    /// register the arena as a fixed I/O buffer
    /// (`AsyncIoEngine::register_buffer_range`). `UnsafeCell<u8>` is
    /// `repr(transparent)`, so this is the first byte of `capacity()`
    /// contiguous bytes, valid for the arena's lifetime.
    pub fn base_addr(&self) -> usize {
        self.data.as_ptr() as usize
    }

    fn byte_ptr(&self, off: usize) -> *mut u8 {
        debug_assert!(off < self.data.len(), "offset {off} out of range");
        // `UnsafeCell<u8>` is `repr(transparent)`, so the boxed slice is a
        // contiguous byte buffer and in-bounds pointer arithmetic is valid.
        self.data[off].get()
    }
}

/// Handle to one staging byte range: the destination of an async read and
/// the source of the subsequent decode into the feature buffer. A range may
/// hold a single feature row or a whole coalesced segment of them.
///
/// Protocol (what makes the unsynchronized byte accesses sound):
/// * while a request is in flight, its `[dst_off, dst_off+len)` sub-range is
///   owned exclusively by the serving I/O worker;
/// * concurrent requests use disjoint ranges (the wave allocator hands out
///   non-overlapping ranges; they are not reissued until the wave latch);
/// * the reader (extractor / PCIe completion) touches the bytes only after
///   harvesting the request's CQE, which happens-after the worker's write
///   via the completion queue's internal lock.
#[derive(Clone)]
pub struct SlotRef {
    arena: Arc<StagingArena>,
    start: usize,
    len: usize,
}

impl SlotRef {
    /// Legacy one-row handle: slot `i` of a `slots × row_bytes` arena.
    pub fn new(arena: Arc<StagingArena>, slot: usize) -> Self {
        debug_assert!(slot < arena.slots());
        let row = arena.row_bytes;
        SlotRef { arena, start: slot * row, len: row }
    }

    /// Arbitrary byte range `[start, start+len)` of the arena (segment
    /// destinations; the wave allocator mints these).
    pub fn range(arena: Arc<StagingArena>, start: usize, len: usize) -> Self {
        assert!(start + len <= arena.capacity(), "staging range out of bounds");
        SlotRef { arena, start, len }
    }

    /// Sub-range view `[off, off+len)` of this range (one row of a segment).
    pub fn sub(&self, off: usize, len: usize) -> Self {
        assert!(off + len <= self.len, "sub-range out of bounds");
        SlotRef { arena: self.arena.clone(), start: self.start + off, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy `src` into the range at `dst_off` (completion-side write; no
    /// lock). Caller must own `[dst_off, dst_off+src.len())` per the range
    /// protocol.
    pub fn write(&self, dst_off: usize, src: &[u8]) {
        assert!(dst_off + src.len() <= self.len, "slot write out of range");
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.as_ptr(),
                self.arena.byte_ptr(self.start + dst_off),
                src.len(),
            );
        }
    }

    /// Mutable view of `[off, off+len)` for an I/O engine to read into.
    ///
    /// # Safety
    /// The caller must own that byte range per the range protocol: no other
    /// thread may read or write it until the owning request's completion has
    /// been published through a synchronizing channel.
    #[allow(clippy::mut_from_ref)] // interior mutability via UnsafeCell
    pub unsafe fn slice_mut(&self, off: usize, len: usize) -> &mut [u8] {
        assert!(off + len <= self.len, "slot range out of bounds");
        std::slice::from_raw_parts_mut(self.arena.byte_ptr(self.start + off), len)
    }

    /// The range's bytes (reader side). Sound only after the writes of every
    /// in-flight request on this range have been synchronized to this thread
    /// (CQE harvested / wave latch passed) — the same protocol
    /// `FeatureBuffer::publish` already relies on.
    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.arena.byte_ptr(self.start), self.len) }
    }
}

pub struct StagingBuffer {
    arena: Arc<StagingArena>,
    pub row_bytes: usize,
    _res: Reservation,
}

impl StagingBuffer {
    /// Reserve `slots × row_bytes` of host memory for one extractor.
    pub fn new(
        host: &HostMemory,
        slots: usize,
        row_bytes: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let res = host.reserve("staging buffer", (slots * row_bytes) as u64)?;
        Ok(StagingBuffer { arena: StagingArena::new(slots, row_bytes), row_bytes, _res: res })
    }

    pub fn slots(&self) -> usize {
        self.arena.slots()
    }

    /// Handle to one-row slot `i` (cheap: an `Arc` clone + offsets; the ring
    /// and the PCIe callback share the arena).
    pub fn slot(&self, i: usize) -> SlotRef {
        SlotRef::new(self.arena.clone(), i)
    }

    /// Total arena bytes available to one wave of segments.
    pub fn capacity_bytes(&self) -> usize {
        self.arena.capacity()
    }

    /// `(base address, capacity)` of the backing arena — what an extractor
    /// advertises to its engine for registered-buffer reads.
    pub fn arena_range(&self) -> (usize, usize) {
        (self.arena.base_addr(), self.arena.capacity())
    }

    /// Fresh bump allocator for one extraction wave. The caller must
    /// quiesce every range of the previous wave (harvest its CQEs, pass the
    /// wave latch) before allocating a new wave from the same buffer — that
    /// hand-off is what makes reissuing arena bytes sound.
    pub fn wave_alloc(&self) -> WaveAlloc<'_> {
        WaveAlloc { buf: self, cursor: 0 }
    }

    pub fn bytes(&self) -> u64 {
        self.arena.capacity() as u64
    }
}

/// Per-wave bump allocator over a [`StagingBuffer`]'s arena: hands out
/// disjoint contiguous ranges (one per coalesced segment) until the arena is
/// exhausted, at which point the extractor flushes the wave and starts a new
/// allocator. Replaces the fixed one-row slot scheme: a wave now packs
/// variable-size segments instead of exactly `slots()` rows.
pub struct WaveAlloc<'a> {
    buf: &'a StagingBuffer,
    cursor: usize,
}

impl WaveAlloc<'_> {
    /// Allocate a contiguous `len`-byte range, or `None` if the remaining
    /// arena cannot hold it (wave is full).
    pub fn alloc(&mut self, len: usize) -> Option<SlotRef> {
        if self.cursor + len > self.buf.capacity_bytes() {
            return None;
        }
        let r = SlotRef::range(self.buf.arena.clone(), self.cursor, len);
        self.cursor += len;
        Some(r)
    }

    /// Bytes handed out so far in this wave.
    pub fn used(&self) -> usize {
        self.cursor
    }

    pub fn is_empty(&self) -> bool {
        self.cursor == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserves_and_exposes_slots() {
        let host = HostMemory::new(1 << 20);
        let sb = StagingBuffer::new(&host, 16, 512).unwrap();
        assert_eq!(sb.slots(), 16);
        assert_eq!(sb.bytes(), 16 * 512);
        assert_eq!(host.reserved(), 16 * 512);
        {
            let b = sb.slot(3);
            b.write(0, &[42]);
        }
        assert_eq!(sb.slot(3).bytes()[0], 42);
        drop(sb);
        assert_eq!(host.reserved(), 0);
    }

    #[test]
    fn oom_when_host_too_small() {
        let host = HostMemory::new(1024);
        assert!(StagingBuffer::new(&host, 16, 512).is_err());
    }

    #[test]
    fn slot_writes_are_disjoint_and_readable() {
        let arena = StagingArena::new(4, 8);
        let a = SlotRef::new(arena.clone(), 0);
        let b = SlotRef::new(arena.clone(), 1);
        a.write(0, &[1, 2, 3, 4]);
        a.write(4, &[5, 6, 7, 8]);
        b.write(0, &[9; 8]);
        assert_eq!(a.bytes(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(b.bytes(), &[9; 8]);
        // Clones address the same slot.
        let a2 = a.clone();
        a2.write(0, &[0xAA]);
        assert_eq!(a.bytes()[0], 0xAA);
    }

    #[test]
    fn range_handles_span_rows_and_subdivide() {
        let arena = StagingArena::new(4, 8); // 32-byte arena
        let seg = SlotRef::range(arena.clone(), 4, 20); // crosses row bounds
        assert_eq!(seg.len(), 20);
        let payload: Vec<u8> = (0..20).collect();
        seg.write(0, &payload);
        assert_eq!(seg.bytes(), &payload[..]);
        // Row view inside the segment.
        let row = seg.sub(8, 8);
        assert_eq!(row.bytes(), &payload[8..16]);
        // The underlying arena bytes line up (range 4+8..4+16).
        let raw = SlotRef::range(arena, 12, 8);
        assert_eq!(raw.bytes(), &payload[8..16]);
    }

    #[test]
    fn wave_alloc_hands_out_disjoint_ranges_until_full() {
        let host = HostMemory::new(1 << 20);
        let sb = StagingBuffer::new(&host, 4, 8).unwrap(); // 32 bytes
        let mut wave = sb.wave_alloc();
        let a = wave.alloc(20).unwrap();
        let b = wave.alloc(12).unwrap();
        assert!(wave.alloc(1).is_none(), "arena exhausted");
        assert_eq!(wave.used(), 32);
        a.write(0, &[1u8; 20]);
        b.write(0, &[2u8; 12]);
        assert!(a.bytes().iter().all(|&x| x == 1));
        assert!(b.bytes().iter().all(|&x| x == 2));
        // A fresh wave reuses the arena from the start.
        let mut wave2 = sb.wave_alloc();
        let c = wave2.alloc(32).unwrap();
        assert_eq!(c.bytes()[..20], [1u8; 20]);
    }

    #[test]
    fn cross_thread_handoff_delivers_bytes() {
        let arena = StagingArena::new(2, 64);
        let slot = SlotRef::new(arena, 0);
        let writer = slot.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            writer.write(0, &[7u8; 64]);
            tx.send(()).unwrap(); // the synchronizing channel of the protocol
        });
        rx.recv().unwrap();
        assert!(slot.bytes().iter().all(|&x| x == 7));
        h.join().unwrap();
    }
}
