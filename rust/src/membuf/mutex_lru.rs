//! The PR-1 sharded coordinator with per-shard mutex-protected standby
//! LRUs, preserved as a benchmark baseline.
//!
//! This is the intermediate generation between the single-global-mutex
//! coordinator ([`super::single_mutex::SingleMutexFeatureBuffer`]) and the
//! current lock-free allocation path in [`super::FeatureBuffer`]: the
//! mapping table and standby list are sharded by node-id hash, slots
//! migrate between shards when one runs dry, and every allocation or
//! release takes the owning shard's mutex. `benches/micro_hotpath.rs` runs
//! the same multi-threaded begin+publish+release workloads against all
//! three generations to quantify each step's contention win; the pipeline
//! does not use this type.

use super::arena::Arena;
use super::shard::{self, EventCount};
use super::slot_state::{self, SlotStates};
use crate::storage::{DeviceMemory, Reservation};
use crate::util::fxhash::FxHashMap;
use crate::util::lru::Lru;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// One shard's state: mapping table (node → slot; this baseline has no
/// wait tickets, so no generation rides along) plus the mutex-protected
/// standby LRU that the lock-free rewrite replaced.
struct ShardState {
    map: FxHashMap<u32, u32>,
    /// Zero-reference slots currently parked in this shard, LRU order.
    standby: Lru<u32>,
}

struct Shard {
    state: Mutex<ShardState>,
}

/// The baseline's extraction plan (aliases + loads + peer wait list).
#[derive(Debug)]
pub struct MlBatchPlan {
    pub aliases: Vec<i32>,
    pub to_load: Vec<(u32, u32)>,
    pub wait_list: Vec<u32>,
}

enum Resolved {
    Alias(u32),
    Wait(u32),
    Load(u32),
    Dry,
}

pub struct MutexLruFeatureBuffer {
    pub n_slots: usize,
    pub dim: usize,
    shards: Vec<Shard>,
    shard_mask: usize,
    states: SlotStates,
    reverse: Vec<AtomicI64>,
    arena: Arena,
    free_event: EventCount,
    hits: AtomicU64,
    shared: AtomicU64,
    steals: AtomicU64,
    loads: AtomicU64,
    _home: Reservation,
}

impl MutexLruFeatureBuffer {
    pub fn in_device(
        dev: &DeviceMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = dev.reserve("feature buffer (mutex-lru baseline)", bytes)?;
        let n_shards = shard::shard_count_for(n_slots);
        let shards: Vec<Shard> = (0..n_shards)
            .map(|_| Shard {
                state: Mutex::new(ShardState {
                    map: FxHashMap::default(),
                    standby: Lru::with_capacity(n_slots / n_shards + 1),
                }),
            })
            .collect();
        for (sx, shard) in shards.iter().enumerate() {
            let mut st = shard.state.lock().unwrap();
            for s in (sx..n_slots).step_by(n_shards) {
                st.standby.insert(s as u32);
            }
        }
        Ok(MutexLruFeatureBuffer {
            n_slots,
            dim,
            shard_mask: n_shards - 1,
            shards,
            states: SlotStates::new(n_slots),
            reverse: (0..n_slots).map(|_| AtomicI64::new(-1)).collect(),
            arena: Arena::new(n_slots * dim),
            free_event: EventCount::new(),
            hits: AtomicU64::new(0),
            shared: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            loads: AtomicU64::new(0),
            _home: res,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn node_shard(&self, node: u32) -> usize {
        let h = (node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) & self.shard_mask
    }

    fn resolve_in_shard(&self, st: &mut ShardState, id: u32) -> Resolved {
        if let Some(&slot) = st.map.get(&id) {
            let word = self.states.load(slot);
            if slot_state::is_valid(word) {
                if slot_state::refs(word) == 0 {
                    st.standby.remove(&slot);
                }
                self.states.add_ref(slot);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Resolved::Alias(slot)
            } else {
                self.states.add_ref(slot);
                self.shared.fetch_add(1, Ordering::Relaxed);
                Resolved::Wait(slot)
            }
        } else if let Some(slot) = st.standby.pop_lru() {
            let generation = self.claim_slot(st, slot);
            self.install(st, id, slot, generation);
            Resolved::Load(slot)
        } else {
            Resolved::Dry
        }
    }

    fn claim_slot(&self, st: &mut ShardState, slot: u32) -> u32 {
        let prev = self.reverse[slot as usize].swap(-1, Ordering::SeqCst);
        if prev >= 0 {
            st.map.remove(&(prev as u32));
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
        let generation = slot_state::generation(self.states.load(slot)).wrapping_add(1);
        self.states.reset(slot, 0, false, generation);
        generation
    }

    fn install(&self, st: &mut ShardState, id: u32, slot: u32, generation: u32) {
        self.reverse[slot as usize].store(id as i64, Ordering::SeqCst);
        self.states.reset(slot, 1, false, generation);
        st.map.insert(id, slot);
        self.loads.fetch_add(1, Ordering::Relaxed);
    }

    fn group_positions(&self, node_ids: &[u32]) -> (Vec<u32>, Vec<u32>) {
        shard::group_positions(self.shards.len(), node_ids, |id| self.node_shard(id))
    }

    pub fn begin_batch(&self, node_ids: &[u32]) -> MlBatchPlan {
        let mut aliases = vec![-1i32; node_ids.len()];
        let mut to_load = Vec::new();
        let mut wait_list = Vec::new();
        let mut deferred: Vec<usize> = Vec::new();

        let apply = |i: usize,
                     r: Resolved,
                     aliases: &mut Vec<i32>,
                     to_load: &mut Vec<(u32, u32)>,
                     wait_list: &mut Vec<u32>|
         -> bool {
            let id = node_ids[i];
            match r {
                Resolved::Alias(slot) => aliases[i] = slot as i32,
                Resolved::Wait(slot) => {
                    aliases[i] = slot as i32;
                    wait_list.push(id);
                }
                Resolved::Load(slot) => {
                    aliases[i] = slot as i32;
                    to_load.push((id, slot));
                }
                Resolved::Dry => return false,
            }
            true
        };

        if self.shards.len() == 1 {
            let mut st = self.shards[0].state.lock().unwrap();
            for (i, &id) in node_ids.iter().enumerate() {
                let r = self.resolve_in_shard(&mut st, id);
                if !apply(i, r, &mut aliases, &mut to_load, &mut wait_list) {
                    deferred.push(i);
                }
            }
        } else {
            let (order, ends) = self.group_positions(node_ids);
            let mut start = 0usize;
            for (sx, &end) in ends.iter().enumerate() {
                let end = end as usize;
                if end > start {
                    let mut st = self.shards[sx].state.lock().unwrap();
                    for &pos in &order[start..end] {
                        let i = pos as usize;
                        let r = self.resolve_in_shard(&mut st, node_ids[i]);
                        if !apply(i, r, &mut aliases, &mut to_load, &mut wait_list) {
                            deferred.push(i);
                        }
                    }
                }
                start = end;
            }
            deferred.sort_unstable();
        }

        for i in deferred {
            let r = self.alloc_slow(node_ids[i]);
            let ok = apply(i, r, &mut aliases, &mut to_load, &mut wait_list);
            debug_assert!(ok, "alloc_slow cannot return Dry");
        }
        MlBatchPlan { aliases, to_load, wait_list }
    }

    fn alloc_slow(&self, id: u32) -> Resolved {
        let home = self.node_shard(id);
        loop {
            if let Some(r) = self.try_alloc(home, id) {
                return r;
            }
            let seen = self.free_event.begin_wait();
            if let Some(r) = self.try_alloc(home, id) {
                self.free_event.cancel_wait();
                return r;
            }
            self.free_event.wait(seen);
        }
    }

    fn try_alloc(&self, home: usize, id: u32) -> Option<Resolved> {
        {
            let mut st = self.shards[home].state.lock().unwrap();
            match self.resolve_in_shard(&mut st, id) {
                Resolved::Dry => {}
                r => return Some(r),
            }
        }
        for d in 1..self.shards.len() {
            let sx = (home + d) & self.shard_mask;
            let stolen = {
                let mut st = self.shards[sx].state.lock().unwrap();
                st.standby.pop_lru().map(|slot| (slot, self.claim_slot(&mut st, slot)))
            };
            let Some((slot, generation)) = stolen else { continue };
            let mut st = self.shards[home].state.lock().unwrap();
            match self.resolve_in_shard(&mut st, id) {
                Resolved::Dry => {
                    self.install(&mut st, id, slot, generation);
                    return Some(Resolved::Load(slot));
                }
                r => {
                    st.standby.insert(slot);
                    drop(st);
                    self.free_event.signal();
                    return Some(r);
                }
            }
        }
        None
    }

    pub fn publish(&self, node: u32, slot: u32, row: &[f32]) {
        let n = self.dim.min(row.len());
        unsafe {
            std::ptr::copy_nonoverlapping(row.as_ptr(), self.arena.row(slot as usize, self.dim), n);
        }
        debug_assert_eq!(self.reverse[slot as usize].load(Ordering::SeqCst), node as i64);
        self.states.set_valid(slot);
    }

    pub fn release(&self, node_ids: &[u32]) {
        let mut freed = false;
        if self.shards.len() == 1 {
            let mut st = self.shards[0].state.lock().unwrap();
            for &id in node_ids {
                freed |= self.release_one(&mut st, id);
            }
        } else {
            let (order, ends) = self.group_positions(node_ids);
            let mut start = 0usize;
            for (sx, &end) in ends.iter().enumerate() {
                let end = end as usize;
                if end > start {
                    let mut st = self.shards[sx].state.lock().unwrap();
                    for &pos in &order[start..end] {
                        freed |= self.release_one(&mut st, node_ids[pos as usize]);
                    }
                }
                start = end;
            }
        }
        if freed {
            self.free_event.signal();
        }
    }

    fn release_one(&self, st: &mut ShardState, id: u32) -> bool {
        let slot = *st.map.get(&id).expect("release of unmapped node");
        let word = self.states.load(slot);
        assert!(slot_state::refs(word) > 0, "refcount underflow for node {id}");
        let prev = self.states.sub_ref(slot);
        if slot_state::refs(prev) == 1 {
            st.standby.insert(slot);
            true
        } else {
            false
        }
    }

    /// (hits, shared, steals, loads) counters.
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.shared.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.loads.load(Ordering::Relaxed),
        )
    }

    /// Number of slots currently in standby lists.
    pub fn standby_len(&self) -> usize {
        self.shards.iter().map(|s| s.state.lock().unwrap().standby.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DeviceMemory;
    use std::sync::{Arc, Barrier};

    #[test]
    fn baseline_smoke_begin_publish_release() {
        let dev = DeviceMemory::new(1 << 20);
        let fb = MutexLruFeatureBuffer::in_device(&dev, 8, 4).unwrap();
        let plan = fb.begin_batch(&[10, 11, 12]);
        assert_eq!(plan.to_load.len(), 3);
        for &(node, slot) in &plan.to_load {
            fb.publish(node, slot, &[node as f32; 4]);
        }
        fb.release(&[10, 11, 12]);
        assert_eq!(fb.standby_len(), 8);
        let p2 = fb.begin_batch(&[11, 13]);
        assert_eq!(p2.to_load.len(), 1);
        let (hits, _, _, loads) = fb.stats();
        assert_eq!((hits, loads), (1, 4));
        fb.release(&[11, 13]);
    }

    #[test]
    fn baseline_steals_under_pressure_across_threads() {
        let dev = DeviceMemory::new(64 << 20);
        let fb = Arc::new(MutexLruFeatureBuffer::in_device(&dev, 512, 4).unwrap());
        assert!(fb.shard_count() > 1);
        let barrier = Barrier::new(4);
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let fb = fb.clone();
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    for round in 0..20u32 {
                        let ids: Vec<u32> =
                            (0..64).map(|k| t * 100_000 + round * 64 + k).collect();
                        let plan = fb.begin_batch(&ids);
                        for &(node, slot) in &plan.to_load {
                            fb.publish(node, slot, &[node as f32; 4]);
                        }
                        fb.release(&ids);
                    }
                });
            }
        });
        assert_eq!(fb.standby_len(), 512, "all slots zero-ref after join");
        let (_, _, steals, loads) = fb.stats();
        assert!(loads >= 512);
        assert!(steals > 0, "a 512-slot buffer over 4×1280 ids must steal");
    }
}
