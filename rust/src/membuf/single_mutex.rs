//! The pre-shard feature-buffer coordinator, preserved as a benchmark
//! baseline.
//!
//! This is the original §4.2 implementation: one global `Mutex<BufState>`
//! serializing begin/publish/release/gather bookkeeping, one `Mutex` per
//! row payload, and `Condvar::notify_all` broadcasts for slot-freed /
//! valid-set events. `benches/micro_hotpath.rs` runs the same multi-threaded
//! begin+publish+release workload against this and against the sharded
//! [`super::FeatureBuffer`] to quantify the contention win; it is not used
//! by the pipeline.

use crate::storage::{DeviceMemory, HostMemory, Reservation};
use crate::util::fxhash::FxHashMap;
use crate::util::lru::Lru;
use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

enum Home {
    #[allow(dead_code)]
    Device(Reservation),
    #[allow(dead_code)]
    Host(Reservation),
}

#[derive(Clone, Copy, Debug, Default)]
struct MapEntry {
    slot: i32,
    ref_count: u32,
    valid: bool,
}

struct BufState {
    map: FxHashMap<u32, MapEntry>,
    /// slot → node id or -1.
    reverse: Vec<i64>,
    /// Zero-reference slots, LRU order (free slots enter via `release`).
    standby: Lru<u32>,
    hits: u64,
    shared: u64,
    steals: u64,
    loads: u64,
}

/// The baseline's extraction plan (same shape as the paper's Algorithm 1
/// output; no wait tickets — the baseline re-locks to wait).
#[derive(Debug)]
pub struct SmBatchPlan {
    pub aliases: Vec<i32>,
    pub to_load: Vec<(u32, u32)>,
    pub wait_list: Vec<u32>,
}

pub struct SingleMutexFeatureBuffer {
    pub n_slots: usize,
    pub dim: usize,
    state: Mutex<BufState>,
    /// Signalled when slots enter the standby list.
    slot_freed: Condvar,
    /// Signalled when any node's valid bit is set.
    valid_set: Condvar,
    /// Slot payload, one mutex per row.
    data: Vec<Mutex<Box<[f32]>>>,
    _home: Home,
}

impl SingleMutexFeatureBuffer {
    pub fn in_device(
        dev: &DeviceMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = dev.reserve("feature buffer (baseline)", bytes)?;
        Ok(Self::build(n_slots, dim, Home::Device(res)))
    }

    pub fn in_host(
        host: &HostMemory,
        n_slots: usize,
        dim: usize,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        let bytes = (n_slots * dim * 4) as u64;
        let res = host.reserve("feature buffer (baseline, cpu)", bytes)?;
        Ok(Self::build(n_slots, dim, Home::Host(res)))
    }

    fn build(n_slots: usize, dim: usize, home: Home) -> Self {
        let mut standby = Lru::with_capacity(n_slots);
        for s in 0..n_slots as u32 {
            standby.insert(s);
        }
        let data = (0..n_slots)
            .map(|_| Mutex::new(vec![0f32; dim].into_boxed_slice()))
            .collect();
        SingleMutexFeatureBuffer {
            n_slots,
            dim,
            state: Mutex::new(BufState {
                map: FxHashMap::default(),
                reverse: vec![-1; n_slots],
                standby,
                hits: 0,
                shared: 0,
                steals: 0,
                loads: 0,
            }),
            slot_freed: Condvar::new(),
            valid_set: Condvar::new(),
            data,
            _home: home,
        }
    }

    pub fn begin_batch(&self, node_ids: &[u32]) -> SmBatchPlan {
        let mut st = self.state.lock().unwrap();
        let mut aliases = vec![-1i32; node_ids.len()];
        let mut to_load = Vec::new();
        let mut wait_list = Vec::new();

        for (i, &id) in node_ids.iter().enumerate() {
            if let Some(e) = st.map.get(&id).copied() {
                if e.valid {
                    if e.ref_count == 0 {
                        st.standby.remove(&(e.slot as u32));
                    }
                    st.hits += 1;
                    aliases[i] = e.slot;
                } else {
                    debug_assert!(e.ref_count > 0, "invalid zero-ref entry leaked");
                    st.shared += 1;
                    aliases[i] = e.slot;
                    wait_list.push(id);
                }
                st.map.get_mut(&id).unwrap().ref_count += 1;
            } else {
                let slot = loop {
                    if let Some(s) = st.standby.pop_lru() {
                        break s;
                    }
                    st = self.slot_freed.wait(st).unwrap();
                };
                let prev = st.reverse[slot as usize];
                if prev >= 0 {
                    st.map.remove(&(prev as u32));
                    st.steals += 1;
                }
                st.reverse[slot as usize] = id as i64;
                st.map.insert(id, MapEntry { slot: slot as i32, ref_count: 1, valid: false });
                st.loads += 1;
                aliases[i] = slot as i32;
                to_load.push((id, slot));
            }
        }
        SmBatchPlan { aliases, to_load, wait_list }
    }

    pub fn publish(&self, node: u32, slot: u32, row: &[f32]) {
        {
            let mut dst = self.data[slot as usize].lock().unwrap();
            let n = dst.len().min(row.len());
            dst[..n].copy_from_slice(&row[..n]);
        }
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.map.get_mut(&node) {
            debug_assert_eq!(e.slot, slot as i32);
            e.valid = true;
        }
        drop(st);
        self.valid_set.notify_all();
    }

    pub fn wait_valid(&self, nodes: &[u32]) {
        let mut st = self.state.lock().unwrap();
        for &id in nodes {
            loop {
                match st.map.get(&id) {
                    Some(e) if e.valid => break,
                    Some(_) => {
                        st = self.valid_set.wait(st).unwrap();
                    }
                    None => break,
                }
            }
        }
    }

    pub fn release(&self, node_ids: &[u32]) {
        let mut st = self.state.lock().unwrap();
        let mut freed = false;
        for &id in node_ids {
            let e = st.map.get_mut(&id).expect("release of unmapped node");
            assert!(e.ref_count > 0, "refcount underflow for node {id}");
            e.ref_count -= 1;
            if e.ref_count == 0 {
                let slot = e.slot as u32;
                st.standby.insert(slot);
                freed = true;
            }
        }
        drop(st);
        if freed {
            self.slot_freed.notify_all();
        }
    }

    pub fn gather(&self, aliases: &[i32], out: &mut [f32]) {
        assert!(out.len() >= aliases.len() * self.dim);
        for (i, &a) in aliases.iter().enumerate() {
            let dst = &mut out[i * self.dim..(i + 1) * self.dim];
            if a < 0 {
                dst.fill(0.0);
            } else {
                let row = self.data[a as usize].lock().unwrap();
                dst.copy_from_slice(&row);
            }
        }
    }

    pub fn stats(&self) -> (u64, u64, u64, u64) {
        let st = self.state.lock().unwrap();
        (st.hits, st.shared, st.steals, st.loads)
    }

    pub fn standby_len(&self) -> usize {
        self.state.lock().unwrap().standby.len()
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        let st = self.state.lock().unwrap();
        let mut slot_owner: HashMap<i32, u32> = HashMap::new();
        for (&node, e) in &st.map {
            if e.slot < 0 || e.slot as usize >= self.n_slots {
                return Err(format!("node {node} has bad slot {}", e.slot));
            }
            if let Some(prev) = slot_owner.insert(e.slot, node) {
                return Err(format!("slot {} owned by {prev} and {node}", e.slot));
            }
            if st.reverse[e.slot as usize] != node as i64 {
                return Err(format!(
                    "reverse[{}]={} but node {node} maps there",
                    e.slot, st.reverse[e.slot as usize]
                ));
            }
            if e.ref_count == 0 && !st.standby.contains(&(e.slot as u32)) {
                return Err(format!("zero-ref node {node} slot {} not standby", e.slot));
            }
            if e.ref_count > 0 && st.standby.contains(&(e.slot as u32)) {
                return Err(format!("referenced slot {} in standby", e.slot));
            }
        }
        for (slot, &node) in st.reverse.iter().enumerate() {
            if node >= 0 {
                match st.map.get(&(node as u32)) {
                    Some(e) if e.slot == slot as i32 => {}
                    _ => return Err(format!("reverse[{slot}]={node} dangling")),
                }
            } else if !st.standby.contains(&(slot as u32)) {
                return Err(format!("empty slot {slot} missing from standby"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DeviceMemory;

    #[test]
    fn baseline_smoke_begin_publish_release() {
        let dev = DeviceMemory::new(1 << 20);
        let fb = SingleMutexFeatureBuffer::in_device(&dev, 8, 4).unwrap();
        let plan = fb.begin_batch(&[10, 11, 12]);
        assert_eq!(plan.to_load.len(), 3);
        for &(node, slot) in &plan.to_load {
            fb.publish(node, slot, &[node as f32; 4]);
        }
        let mut out = vec![0f32; 3 * 4];
        fb.gather(&plan.aliases, &mut out);
        assert_eq!(out[0], 10.0);
        fb.release(&[10, 11, 12]);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), 8);
        let p2 = fb.begin_batch(&[11, 13]);
        assert_eq!(p2.to_load.len(), 1);
        let (hits, _, _, loads) = fb.stats();
        assert_eq!((hits, loads), (1, 4));
        fb.release(&[11, 13]);
        fb.check_invariants().unwrap();
    }
}
