//! Flat f32 row arena shared by the feature-buffer coordinator generations.
//!
//! Rows are disjoint and single-writer by protocol (only the extractor that
//! planned a slot's load publishes into it, and readers are ordered behind
//! the slot's valid bit), so access goes through raw pointers — no per-row
//! mutex, no `&mut` aliasing over the whole buffer. Kept in one place so
//! the unsafe surface exists exactly once for every coordinator that uses
//! it ([`super::feature_buffer::FeatureBuffer`] and the preserved
//! mutex-LRU baseline).

pub(crate) struct Arena {
    base: *mut f32,
    len: usize,
}

unsafe impl Send for Arena {}
unsafe impl Sync for Arena {}

impl Arena {
    pub fn new(len: usize) -> Self {
        let boxed = vec![0f32; len].into_boxed_slice();
        Arena { base: Box::into_raw(boxed) as *mut f32, len }
    }

    /// Pointer to row `slot` of width `dim`.
    #[inline]
    pub fn row(&self, slot: usize, dim: usize) -> *mut f32 {
        debug_assert!((slot + 1) * dim <= self.len);
        // Provenance: `base` came from Box::into_raw over the whole arena.
        unsafe { self.base.add(slot * dim) }
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        unsafe {
            drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(self.base, self.len)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_disjoint_and_zeroed() {
        let a = Arena::new(4 * 3);
        for slot in 0..4 {
            let p = a.row(slot, 3);
            unsafe {
                for j in 0..3 {
                    assert_eq!(*p.add(j), 0.0);
                    *p.add(j) = (slot * 10 + j) as f32;
                }
            }
        }
        for slot in 0..4 {
            let p = a.row(slot, 3);
            unsafe {
                assert_eq!(*p, (slot * 10) as f32);
                assert_eq!(*p.add(2), (slot * 10 + 2) as f32);
            }
        }
    }
}
