//! Shards of the feature-buffer coordinator, plus the eventcount used for
//! targeted wakeups.
//!
//! The mapping table and standby list are sharded by node-id hash: one batch
//! groups its node list per shard and takes each shard mutex at most once on
//! the fast path, so `cfg.extractors` threads planning different batches no
//! longer serialize on a single global lock. Slots migrate between shards:
//! a freed slot parks in the standby list of its tenant node's shard, and a
//! dry shard may steal the LRU slot of another shard (the stolen slot's old
//! mapping lives in that same shard, so the steal needs exactly one lock).
//!
//! [`EventCount`] replaces the old `Condvar::notify_all` broadcasts: the
//! signal side is a single relaxed-cost atomic load when nobody is waiting,
//! and waiters re-check their predicate between registration and sleep so
//! wakeups cannot be lost.

use crate::util::fxhash::FxHashMap;
use crate::util::lru::Lru;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Mapping-table entry: node → slot plus the slot generation observed when
/// the entry was created (stale-handle detection for waiters).
#[derive(Clone, Copy, Debug)]
pub(crate) struct MapEntry {
    pub slot: u32,
    pub generation: u32,
}

/// One shard's mutable coordinator state.
pub(crate) struct ShardState {
    /// node → (slot, generation) for nodes hashed to this shard.
    pub map: FxHashMap<u32, MapEntry>,
    /// Zero-reference slots currently parked in this shard, LRU order.
    pub standby: Lru<u32>,
}

pub(crate) struct Shard {
    pub state: Mutex<ShardState>,
}

impl Shard {
    pub fn new(expected_slots: usize) -> Self {
        Shard {
            state: Mutex::new(ShardState {
                map: FxHashMap::default(),
                standby: Lru::with_capacity(expected_slots),
            }),
        }
    }
}

/// Lost-wakeup-free event counter (a sequence lock for sleeping).
///
/// Waiter protocol:
/// ```text
///   loop {
///       if predicate() { break }
///       let seen = ec.begin_wait();            // register, then snapshot
///       if predicate() { ec.cancel_wait(); break }
///       ec.wait(seen);                         // sleeps unless seq moved
///   }
/// ```
/// Signal protocol: make the state change visible (e.g. drop the shard
/// lock), then call [`EventCount::signal`] — it bumps the sequence and
/// notifies only when a waiter is registered, so the hot path costs one
/// atomic load instead of a broadcast storm.
///
/// Why no wakeup is lost: the waiter increments the registration counter
/// (SeqCst) *before* re-checking the predicate, and the signaler changes
/// state *before* loading the counter. If the signaler reads zero waiters,
/// the waiter's increment — and therefore its predicate re-check — comes
/// later in the SeqCst total order and observes the state change.
pub(crate) struct EventCount {
    seq: Mutex<u64>,
    cond: Condvar,
    waiters: AtomicUsize,
}

impl EventCount {
    pub fn new() -> Self {
        EventCount { seq: Mutex::new(0), cond: Condvar::new(), waiters: AtomicUsize::new(0) }
    }

    /// Register as a waiter and snapshot the sequence. Must be paired with
    /// exactly one `cancel_wait` or `wait`.
    pub fn begin_wait(&self) -> u64 {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        *self.seq.lock().unwrap()
    }

    /// Deregister without sleeping (the predicate turned true).
    pub fn cancel_wait(&self) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Sleep until the sequence moves past `seen`, then deregister.
    pub fn wait(&self, seen: u64) {
        let mut seq = self.seq.lock().unwrap();
        while *seq == seen {
            seq = self.cond.wait(seq).unwrap();
        }
        drop(seq);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Wake registered waiters; near-free when there are none.
    pub fn signal(&self) {
        if self.waiters.load(Ordering::SeqCst) > 0 {
            *self.seq.lock().unwrap() += 1;
            self.cond.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn signal_with_no_waiters_is_cheap_and_safe() {
        let ec = EventCount::new();
        ec.signal();
        assert_eq!(*ec.seq.lock().unwrap(), 0, "no waiter → no bump");
        let seen = ec.begin_wait();
        ec.cancel_wait();
        assert_eq!(seen, 0);
    }

    #[test]
    fn waiter_wakes_on_signal() {
        let ec = Arc::new(EventCount::new());
        let flag = Arc::new(AtomicBool::new(false));
        let (ec2, flag2) = (ec.clone(), flag.clone());
        let h = std::thread::spawn(move || loop {
            if flag2.load(Ordering::SeqCst) {
                return;
            }
            let seen = ec2.begin_wait();
            if flag2.load(Ordering::SeqCst) {
                ec2.cancel_wait();
                return;
            }
            ec2.wait(seen);
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        flag.store(true, Ordering::SeqCst);
        ec.signal();
        h.join().unwrap();
        assert_eq!(ec.waiters.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn predicate_flip_between_register_and_sleep_is_not_missed() {
        // The canonical lost-wakeup interleaving: signal lands after the
        // waiter's first check but before it sleeps. The re-check after
        // begin_wait (or the moved sequence) must catch it.
        let ec = EventCount::new();
        let seen = ec.begin_wait();
        ec.signal(); // bumps: a waiter is registered
        ec.wait(seen); // returns immediately — seq already moved
        assert_eq!(ec.waiters.load(Ordering::SeqCst), 0);
    }
}
