//! Shards of the feature-buffer coordinator, the lock-free allocation
//! structures ([`FreeStack`], [`ClockHand`]), and the eventcount used for
//! targeted wakeups.
//!
//! The mapping table is sharded by node-id hash: one batch groups its node
//! list per shard and takes each shard mutex at most once on the fast path,
//! so `cfg.extractors` threads planning different batches no longer
//! serialize on a single global lock. Since the lock-free standby path
//! landed, a shard holds *only* its slice of the mapping table — slot
//! allocation goes through the global Treiber free stack and clock hand
//! instead of per-shard standby LRUs, so there is no slot migration and no
//! mutex anywhere on the allocation path.
//!
//! [`EventCount`] replaces the old `Condvar::notify_all` broadcasts: the
//! signal side is a single relaxed-cost atomic load when nobody is waiting,
//! and waiters re-check their predicate between registration and sleep so
//! wakeups cannot be lost.

use crate::util::fxhash::FxHashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Mapping-table entry: node → slot plus the slot generation observed when
/// the entry was created. Entries are *validated on use*: a reference is
/// only taken through a generation-checked CAS on the packed slot word, so
/// an entry whose slot was clock-claimed since (generation moved) is dead
/// weight that the next lookup removes — the lock-free claim never has to
/// reach into another shard's map.
#[derive(Clone, Copy, Debug)]
pub(crate) struct MapEntry {
    pub slot: u32,
    pub generation: u32,
}

/// One shard's mutable coordinator state: just the mapping table now — the
/// standby LRU this struct used to carry is gone (allocation is lock-free).
pub(crate) struct ShardState {
    /// node → (slot, generation) for nodes hashed to this shard.
    pub map: FxHashMap<u32, MapEntry>,
}

pub(crate) struct Shard {
    pub state: Mutex<ShardState>,
}

impl Shard {
    pub fn new(expected_nodes: usize) -> Self {
        let mut map = FxHashMap::default();
        map.reserve(expected_nodes);
        Shard { state: Mutex::new(ShardState { map }) }
    }
}

/// Largest power of two ≤ `x` (x ≥ 1).
pub(crate) fn floor_pow2(x: usize) -> usize {
    1 << (usize::BITS - 1 - x.leading_zeros())
}

/// Shard count policy shared by the coordinator generations: tiny buffers
/// (unit tests, degenerate configs) get one shard, production-sized buffers
/// up to 16 shards with ≥64 slots each.
pub(crate) fn shard_count_for(n_slots: usize) -> usize {
    if n_slots < 256 {
        1
    } else {
        floor_pow2((n_slots / 64).min(16))
    }
}

/// Stable counting sort of batch positions by shard: `order` holds the
/// positions `0..len` grouped per shard (original order within a shard),
/// `ends[s]` the exclusive end of shard `s`'s run. Two allocations per
/// batch instead of one `Vec` per shard.
pub(crate) fn group_positions(
    n_shards: usize,
    node_ids: &[u32],
    shard_of: impl Fn(u32) -> usize,
) -> (Vec<u32>, Vec<u32>) {
    let mut cursor = vec![0u32; n_shards];
    for &id in node_ids {
        cursor[shard_of(id)] += 1;
    }
    let mut start = 0u32;
    for c in cursor.iter_mut() {
        let count = *c;
        *c = start;
        start += count;
    }
    let mut order = vec![0u32; node_ids.len()];
    for (i, &id) in node_ids.iter().enumerate() {
        let s = shard_of(id);
        order[cursor[s] as usize] = i as u32;
        cursor[s] += 1;
    }
    // After the fill, cursor[s] is exactly shard s's exclusive end.
    (order, cursor)
}

/// Sentinel for "no slot" in [`FreeStack`] links.
const NIL: u32 = u32::MAX;

/// Treiber stack of free slot indexes — the lock-free fast path for slots
/// that have never held a tenant (cold start) or were handed back whole.
///
/// Links live in a flat `next[slot]` array (a slot is in at most one stack
/// position at a time), and the head packs `(tag << 32) | slot` so the tag
/// increments on every successful push/pop — the classic ABA guard: a pop
/// whose `next` read was made stale by an intervening pop+push sees a moved
/// tag and retries instead of installing a dangling head.
pub(crate) struct FreeStack {
    head: AtomicU64,
    next: Vec<AtomicU32>,
}

impl FreeStack {
    pub fn new(n_slots: usize) -> Self {
        FreeStack {
            head: AtomicU64::new(Self::pack(0, NIL)),
            next: (0..n_slots).map(|_| AtomicU32::new(NIL)).collect(),
        }
    }

    #[inline]
    fn pack(tag: u32, slot: u32) -> u64 {
        ((tag as u64) << 32) | slot as u64
    }

    #[inline]
    fn slot_of(head: u64) -> u32 {
        head as u32
    }

    #[inline]
    fn tag_of(head: u64) -> u32 {
        (head >> 32) as u32
    }

    /// Push a slot the caller owns exclusively.
    pub fn push(&self, slot: u32) {
        debug_assert!((slot as usize) < self.next.len());
        let mut head = self.head.load(Ordering::SeqCst);
        loop {
            self.next[slot as usize].store(Self::slot_of(head), Ordering::SeqCst);
            let new = Self::pack(Self::tag_of(head).wrapping_add(1), slot);
            match self.head.compare_exchange_weak(head, new, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => return,
                Err(h) => head = h,
            }
        }
    }

    /// Pop a slot; the winner owns it exclusively. One CAS when uncontended.
    pub fn pop(&self) -> Option<u32> {
        let mut head = self.head.load(Ordering::SeqCst);
        loop {
            let slot = Self::slot_of(head);
            if slot == NIL {
                return None;
            }
            let next = self.next[slot as usize].load(Ordering::SeqCst);
            let new = Self::pack(Self::tag_of(head).wrapping_add(1), next);
            match self.head.compare_exchange_weak(head, new, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => return Some(slot),
                Err(h) => head = h,
            }
        }
    }

    /// Snapshot the parked slots (O(n) walk; quiesced callers only — the
    /// walk is not linearizable under concurrent pushes/pops).
    pub fn snapshot(&self) -> Vec<u32> {
        let mut out = Vec::new();
        let mut s = Self::slot_of(self.head.load(Ordering::SeqCst));
        while s != NIL {
            out.push(s);
            s = self.next[s as usize].load(Ordering::SeqCst);
        }
        out
    }
}

/// The clock hand: a global cursor over the slot arena for the
/// second-chance eviction sweep. Each probe advances the hand by one; the
/// modulo keeps it in range (the `fetch_add` wraps around u64-space once
/// per aeon, which at worst teleports the hand — an approximation the
/// approximate LRU absorbs).
pub(crate) struct ClockHand {
    pos: AtomicUsize,
}

impl ClockHand {
    pub fn new() -> Self {
        ClockHand { pos: AtomicUsize::new(0) }
    }

    #[inline]
    pub fn next(&self, n_slots: usize) -> usize {
        self.pos.fetch_add(1, Ordering::Relaxed) % n_slots
    }
}

/// Lost-wakeup-free event counter (a sequence lock for sleeping).
///
/// Waiter protocol:
/// ```text
///   loop {
///       if predicate() { break }
///       let seen = ec.begin_wait();            // register, then snapshot
///       if predicate() { ec.cancel_wait(); break }
///       ec.wait(seen);                         // sleeps unless seq moved
///   }
/// ```
/// Signal protocol: make the state change visible (e.g. drop the shard
/// lock), then call [`EventCount::signal`] — it bumps the sequence and
/// notifies only when a waiter is registered, so the hot path costs one
/// atomic load instead of a broadcast storm.
///
/// Why no wakeup is lost: the waiter increments the registration counter
/// (SeqCst) *before* re-checking the predicate, and the signaler changes
/// state *before* loading the counter. If the signaler reads zero waiters,
/// the waiter's increment — and therefore its predicate re-check — comes
/// later in the SeqCst total order and observes the state change.
pub(crate) struct EventCount {
    seq: Mutex<u64>,
    cond: Condvar,
    waiters: AtomicUsize,
}

impl EventCount {
    pub fn new() -> Self {
        EventCount { seq: Mutex::new(0), cond: Condvar::new(), waiters: AtomicUsize::new(0) }
    }

    /// Register as a waiter and snapshot the sequence. Must be paired with
    /// exactly one `cancel_wait` or `wait`.
    pub fn begin_wait(&self) -> u64 {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        *self.seq.lock().unwrap()
    }

    /// Deregister without sleeping (the predicate turned true).
    pub fn cancel_wait(&self) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Sleep until the sequence moves past `seen`, then deregister.
    pub fn wait(&self, seen: u64) {
        let mut seq = self.seq.lock().unwrap();
        while *seq == seen {
            seq = self.cond.wait(seq).unwrap();
        }
        drop(seq);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Wake registered waiters; near-free when there are none.
    pub fn signal(&self) {
        if self.waiters.load(Ordering::SeqCst) > 0 {
            *self.seq.lock().unwrap() += 1;
            self.cond.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn free_stack_is_lifo_and_exact() {
        let fs = FreeStack::new(8);
        assert_eq!(fs.pop(), None);
        for s in 0..8u32 {
            fs.push(s);
        }
        assert_eq!(fs.snapshot().len(), 8);
        for want in (0..8u32).rev() {
            assert_eq!(fs.pop(), Some(want));
        }
        assert_eq!(fs.pop(), None);
        assert!(fs.snapshot().is_empty());
    }

    #[test]
    fn free_stack_concurrent_pops_never_duplicate_or_lose() {
        const SLOTS: usize = 1024;
        const THREADS: usize = 8;
        let fs = Arc::new(FreeStack::new(SLOTS));
        for s in 0..SLOTS as u32 {
            fs.push(s);
        }
        let got: Vec<Vec<u32>> = std::thread::scope(|sc| {
            let handles: Vec<_> = (0..THREADS)
                .map(|_| {
                    let fs = fs.clone();
                    sc.spawn(move || {
                        let mut mine = Vec::new();
                        while let Some(s) = fs.pop() {
                            mine.push(s);
                            // Churn: push half of them back to exercise the
                            // ABA-tagged head under pop/push interleaving.
                            if mine.len() % 2 == 0 {
                                fs.push(mine.pop().unwrap());
                            }
                        }
                        mine
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut seen = HashSet::new();
        let mut total = 0usize;
        for batch in &got {
            for &s in batch {
                assert!(seen.insert(s), "slot {s} popped twice");
                total += 1;
            }
        }
        let left = fs.snapshot();
        for &s in &left {
            assert!(seen.insert(s), "slot {s} both popped and parked");
        }
        assert_eq!(total + left.len(), SLOTS, "slots lost or invented");
    }

    #[test]
    fn group_positions_is_a_stable_shard_sort() {
        // 3 shards, shard = id % 3.
        let ids = [3u32, 1, 4, 6, 2, 7, 9];
        let (order, ends) = group_positions(3, &ids, |id| id as usize % 3);
        assert_eq!(ends, vec![3, 6, 7]);
        // Shard 0: positions of 3, 6, 9 in batch order; shard 1: 1, 4, 7;
        // shard 2: 2.
        assert_eq!(order, vec![0, 3, 6, 1, 2, 5, 4]);
    }

    #[test]
    fn clock_hand_wraps() {
        let c = ClockHand::new();
        let seen: Vec<usize> = (0..10).map(|_| c.next(4)).collect();
        assert_eq!(seen, vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn signal_with_no_waiters_is_cheap_and_safe() {
        let ec = EventCount::new();
        ec.signal();
        assert_eq!(*ec.seq.lock().unwrap(), 0, "no waiter → no bump");
        let seen = ec.begin_wait();
        ec.cancel_wait();
        assert_eq!(seen, 0);
    }

    #[test]
    fn waiter_wakes_on_signal() {
        let ec = Arc::new(EventCount::new());
        let flag = Arc::new(AtomicBool::new(false));
        let (ec2, flag2) = (ec.clone(), flag.clone());
        let h = std::thread::spawn(move || loop {
            if flag2.load(Ordering::SeqCst) {
                return;
            }
            let seen = ec2.begin_wait();
            if flag2.load(Ordering::SeqCst) {
                ec2.cancel_wait();
                return;
            }
            ec2.wait(seen);
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        flag.store(true, Ordering::SeqCst);
        ec.signal();
        h.join().unwrap();
        assert_eq!(ec.waiters.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn predicate_flip_between_register_and_sleep_is_not_missed() {
        // The canonical lost-wakeup interleaving: signal lands after the
        // waiter's first check but before it sleeps. The re-check after
        // begin_wait (or the moved sequence) must catch it.
        let ec = EventCount::new();
        let seen = ec.begin_wait();
        ec.signal(); // bumps: a waiter is registered
        ec.wait(seen); // returns immediately — seq already moved
        assert_eq!(ec.waiters.load(Ordering::SeqCst), 0);
    }
}
