//! Inter-stage buffer management (the paper's §4.2): the sharded,
//! lock-minimized feature buffer (mapping-table shards + per-shard standby
//! LRUs over a flat slot arena with packed atomic slot state), the bounded
//! host-side staging buffer, and the preserved single-mutex coordinator used
//! as a contention baseline by `benches/micro_hotpath.rs`.

pub mod feature_buffer;
mod shard;
pub mod single_mutex;
pub mod slot_state;
pub mod staging;

pub use feature_buffer::{BatchPlan, FeatureBuffer, WaitHandle};
pub use single_mutex::{SingleMutexFeatureBuffer, SmBatchPlan};
pub use staging::StagingBuffer;
