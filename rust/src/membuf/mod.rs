//! Inter-stage buffer management (the paper's §4.2): the sharded feature
//! buffer with a lock-free slot allocation/release path (node-hash mapping
//! shards over a flat slot arena; a Treiber free stack plus a second-chance
//! clock sweep over packed atomic slot words replace the old per-shard
//! standby LRUs), the bounded host-side staging buffer, and two preserved
//! coordinator generations used as contention baselines by
//! `benches/micro_hotpath.rs`: the original single-global-mutex design and
//! the PR-1 sharded mutex-LRU design.
//!
//! ## Tier contract (`--tier`, [`crate::tier`])
//!
//! The [`FeatureBuffer`] is the *host* tier of the tiered feature store
//! ([`crate::tier::TieredFeatureStore`]); the contract between the layers:
//!
//! * **Placement is owned above this module.** The buffer never knows a GPU
//!   tier exists: it plans, publishes, and evicts host slots exactly as in
//!   single-tier operation. The tier layer routes nodes *before* calling
//!   [`FeatureBuffer::begin_batch`] (GPU residents never reach the host
//!   planner) and encodes device residency purely in the alias space —
//!   aliases `>= n_slots` name GPU slots and are masked to `-1` before any
//!   host-side gather/release, so a host alias is always a valid host slot.
//! * **One tier per node.** After a promotion the host copy is released
//!   back through the normal idle-eviction path
//!   ([`FeatureBuffer::evict_if_idle`], deferred until the promoting
//!   batch's references drop); `TieredFeatureStore::check_exclusive`
//!   verifies no node is resident in both tiers at quiesce.
//! * **This module charges nothing new.** Host loads charge SSD reads as
//!   always; all host→device traffic (promotions, pinned-layout uploads,
//!   oversubscription fault migrations) is charged by the tier layer
//!   through the PCIe model. Under `--tier host` the store is a pure
//!   delegate and every counter on this buffer — hits, shared, steals,
//!   loads — is byte-identical to the pre-tier stack.

mod arena;
pub mod feature_buffer;
pub mod mutex_lru;
mod shard;
pub mod single_mutex;
pub mod slot_state;
pub mod staging;

pub use feature_buffer::{BatchPlan, FeatureBuffer, WaitHandle};
pub use mutex_lru::{MlBatchPlan, MutexLruFeatureBuffer};
pub use single_mutex::{SingleMutexFeatureBuffer, SmBatchPlan};
pub use staging::{SlotRef, StagingArena, StagingBuffer, WaveAlloc};
