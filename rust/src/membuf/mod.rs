//! Inter-stage buffer management (the paper's §4.2): the sharded feature
//! buffer with a lock-free slot allocation/release path (node-hash mapping
//! shards over a flat slot arena; a Treiber free stack plus a second-chance
//! clock sweep over packed atomic slot words replace the old per-shard
//! standby LRUs), the bounded host-side staging buffer, and two preserved
//! coordinator generations used as contention baselines by
//! `benches/micro_hotpath.rs`: the original single-global-mutex design and
//! the PR-1 sharded mutex-LRU design.

mod arena;
pub mod feature_buffer;
pub mod mutex_lru;
mod shard;
pub mod single_mutex;
pub mod slot_state;
pub mod staging;

pub use feature_buffer::{BatchPlan, FeatureBuffer, WaitHandle};
pub use mutex_lru::{MlBatchPlan, MutexLruFeatureBuffer};
pub use single_mutex::{SingleMutexFeatureBuffer, SmBatchPlan};
pub use staging::{SlotRef, StagingArena, StagingBuffer, WaveAlloc};
