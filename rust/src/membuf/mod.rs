//! Inter-stage buffer management (the paper's §4.2): feature buffer with
//! mapping table / reverse map / standby LRU, plus the bounded host-side
//! staging buffer.

pub mod feature_buffer;
pub mod staging;

pub use feature_buffer::{BatchPlan, FeatureBuffer};
pub use staging::StagingBuffer;
