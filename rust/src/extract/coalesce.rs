//! Segment-coalescing planner for feature extraction (paper §4.4, "Access
//! Granularity"; Ginex/DiskGNN-style feature-access batching).
//!
//! The extractor's wave used to issue **one SQE per feature row**: every
//! row was independently sector-aligned and charged, so two rows sharing a
//! sector paid the sector twice and every row paid a full submit/harvest
//! round-trip. This module turns a wave's `(node, slot)` load list into
//! **segments**: rows sorted by file offset and greedily merged into
//! contiguous spans, each served by a single device request. On completion
//! the extractor scatters each row out of its segment's staging range — the
//! row table never leaves the submitter; engines only ever see contiguous
//! reads.
//!
//! Merging rules (both CLI-tunable, `--coalesce-bytes` / `--coalesce-gap`):
//!
//! * the next row joins the current segment iff the file-byte **gap**
//!   between the end of the previous row and its start is *strictly less
//!   than* `gap_bytes` (rows exactly `gap_bytes` apart do **not** merge);
//!   contiguous rows (gap 0) always merge, whatever `gap_bytes` is;
//! * a segment's total span never exceeds `max_bytes` (clamped to the
//!   staging-arena capacity, since a segment must land in one contiguous
//!   staging range);
//! * `max_bytes == 0` disables coalescing entirely — one single-row segment
//!   per load, byte-for-byte the paper's baseline behavior, for ablation
//!   parity.
//!
//! Bridged gap bytes are read and discarded: they cost bandwidth but save
//! an IOPS charge and a per-request round-trip, which is the right trade on
//! the IOPS-bound random-row workload (PM883: 520 MB/s ÷ 97 kIOPS ≈ 5.4 KiB
//! of "free" bytes per op saved, and random 512 B rows leave ~10× of the
//! bandwidth ceiling idle). Accounting stays honest: a segment records its
//! rows' bytes as *useful* and its sector-aligned span as *aligned*, so
//! [`crate::storage::DirectIoStats`] amplification visibly drops when
//! sector sharing wins and visibly grows when gap bridging pays bytes for
//! ops.
//!
//! ## Striping (`--devices N`)
//!
//! On a striped array ([`StripeSpec`]) the planner adds one rule and one
//! reorder:
//!
//! * a segment never merges past [`StripeSpec::chunk_end`] of its starting
//!   offset, so every multi-row segment maps to exactly **one** device and
//!   the engine can pair its completion with one
//!   `charge_multi_dev(dev, ..)`. The only segment that may span devices is
//!   a *single row* wider than `--stripe-bytes` — unavoidable, served
//!   through the striped backing, and charged to the device owning its
//!   starting offset (a deliberate approximation: a row that wide is a
//!   configuration smell, not a steady state);
//! * the offset-sorted plan is **interleaved round-robin by owning device**
//!   before it is returned, so a wave's submissions fill all per-device
//!   sub-queues concurrently instead of saturating device 0 first. Safe
//!   because the extractor keys completions by wave index
//!   (`user_data = in_wave.len()`), never by list position.
//!
//! At `--devices 1` (`StripeSpec::single()`) the chunk constraint is
//! vacuous and the single "device 0" list is returned in place — the plan
//! is byte-for-byte identical to the unstriped planner.

use crate::graph::FeatureTable;
use crate::storage::StripeSpec;

/// Tuning knobs for the segment planner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoalesceConfig {
    /// Max bytes one segment may span (0 = coalescing disabled).
    pub max_bytes: usize,
    /// Strict upper bound on the bridged gap between consecutive rows.
    pub gap_bytes: usize,
}

impl CoalesceConfig {
    /// Per-row requests, exactly the pre-coalescing extractor (`--coalesce-bytes 0`).
    pub fn disabled() -> Self {
        CoalesceConfig { max_bytes: 0, gap_bytes: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.max_bytes > 0
    }
}

impl Default for CoalesceConfig {
    /// 256 KiB segments, 16 KiB gap: segments stay well under the staging
    /// arena, and on a PM883-class drive bridging up to 16 KiB trades idle
    /// bandwidth for scarce IOPS at a comfortable margin (see module docs).
    fn default() -> Self {
        CoalesceConfig { max_bytes: 256 << 10, gap_bytes: 16 << 10 }
    }
}

/// One feature row inside a segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegRow {
    pub node: u32,
    /// Feature-buffer slot the row publishes into.
    pub slot: u32,
    /// Byte offset of the row within the segment's staging range.
    pub rel_off: usize,
}

/// A contiguous span of the feature file served by one device request.
#[derive(Clone, Debug)]
pub struct Segment {
    /// File offset of the first row (the span is *not* pre-padded to sector
    /// alignment — backends account the aligned span themselves, and the
    /// `O_DIRECT` path bounces through its own aligned buffer).
    pub offset: u64,
    /// Bytes from the first row's start to the last row's end (rows +
    /// bridged gaps); the staging range the segment needs.
    pub span: usize,
    /// Σ row bytes — the genuinely requested volume ([`crate::storage::Sqe::useful`]).
    pub useful: usize,
    pub rows: Vec<SegRow>,
}

/// Plan a load list into segments: sort by file offset, merge greedily.
///
/// `staging_capacity` bounds the effective `max_bytes` (a segment must fit
/// one contiguous staging range); a single row always fits by construction,
/// so the planner never produces an unplaceable segment.
pub fn plan_segments(
    to_load: &[(u32, u32)],
    features: &FeatureTable,
    cfg: &CoalesceConfig,
    staging_capacity: usize,
) -> Vec<Segment> {
    plan_segments_striped(to_load, features, cfg, staging_capacity, StripeSpec::single())
}

/// Stripe-aware planner (see the module docs): identical to
/// [`plan_segments`] except that segments never merge past
/// [`StripeSpec::chunk_end`] and the result is interleaved round-robin by
/// owning device. `StripeSpec::single()` reproduces [`plan_segments`]
/// byte-for-byte.
pub fn plan_segments_striped(
    to_load: &[(u32, u32)],
    features: &FeatureTable,
    cfg: &CoalesceConfig,
    staging_capacity: usize,
    spec: StripeSpec,
) -> Vec<Segment> {
    let row_bytes = features.row_bytes() as usize;
    let rows: Vec<(u64, u32, u32)> = to_load
        .iter()
        .map(|&(node, slot)| (features.row_offset(node as u64), node, slot))
        .collect();
    plan_rows(rows, row_bytes, cfg, staging_capacity, spec)
}

/// Planner core over pre-computed `(file_offset, node, slot)` rows — the
/// shared engine behind [`plan_segments_striped`] (offsets from the online
/// feature table) and the packed-layout path (`layout/`, offsets into a
/// batch's pack run or the hot tier). All merge rules — strict gap, span
/// cap, staging clamp, the one-segment-one-device stripe invariant — and
/// the round-robin device interleave apply identically to both callers.
pub fn plan_rows(
    rows: Vec<(u64, u32, u32)>,
    row_bytes: usize,
    cfg: &CoalesceConfig,
    staging_capacity: usize,
    spec: StripeSpec,
) -> Vec<Segment> {
    plan_rows_adaptive(rows, row_bytes, std::slice::from_ref(cfg), staging_capacity, spec)
}

/// Per-device flavor of [`plan_segments_striped`]: `cfgs[d]` governs the
/// segments whose starting offset maps to stripe device `d` (indices past
/// the slice clamp to its last entry, mirroring engine routing). This is
/// the adaptive-coalescing entry point — the governor
/// ([`crate::extract::CoalesceGovernor`]) hands the extractor one effective
/// config per device, and the one-segment-one-device invariant guarantees
/// each merge decision has exactly one governing device. A one-element
/// slice reproduces [`plan_segments_striped`] byte-for-byte.
pub fn plan_segments_striped_adaptive(
    to_load: &[(u32, u32)],
    features: &FeatureTable,
    cfgs: &[CoalesceConfig],
    staging_capacity: usize,
    spec: StripeSpec,
) -> Vec<Segment> {
    let row_bytes = features.row_bytes() as usize;
    let rows: Vec<(u64, u32, u32)> = to_load
        .iter()
        .map(|&(node, slot)| (features.row_offset(node as u64), node, slot))
        .collect();
    plan_rows_adaptive(rows, row_bytes, cfgs, staging_capacity, spec)
}

/// Planner core generalized over per-device configs (see
/// [`plan_segments_striped_adaptive`]); [`plan_rows`] is the one-config
/// special case.
pub fn plan_rows_adaptive(
    mut rows: Vec<(u64, u32, u32)>,
    row_bytes: usize,
    cfgs: &[CoalesceConfig],
    staging_capacity: usize,
    spec: StripeSpec,
) -> Vec<Segment> {
    debug_assert!(staging_capacity >= row_bytes, "staging cannot hold one row");
    assert!(!cfgs.is_empty(), "planner needs at least one coalesce config");
    rows.sort_unstable_by_key(|&(off, _, _)| off);

    // A segment's governing config is its starting offset's device; the
    // chunk constraint below keeps the whole segment on that device, so the
    // choice is unambiguous.
    let cfg_for = |off: u64| &cfgs[spec.device_of(off).min(cfgs.len() - 1)];

    let mut segments: Vec<Segment> = Vec::new();
    for (off, node, slot) in rows {
        if let Some(seg) = segments.last_mut() {
            let cfg = cfg_for(seg.offset);
            let max_span = if cfg.enabled() {
                cfg.max_bytes.clamp(row_bytes, staging_capacity)
            } else {
                row_bytes
            };
            let end = seg.offset + seg.span as u64;
            // `to_load` holds distinct nodes, so sorted rows never overlap:
            // `off >= end` always. gap == 0 (contiguous) always merges.
            let gap = (off - end) as usize;
            let new_span = (off + row_bytes as u64 - seg.offset) as usize;
            let mergeable = cfg.enabled()
                && (gap == 0 || gap < cfg.gap_bytes)
                && new_span <= max_span
                // Never grow a segment past the stripe chunk that owns its
                // first byte — the one-segment-one-device invariant
                // (vacuous when unstriped: chunk_end == u64::MAX).
                && seg.offset + new_span as u64 <= spec.chunk_end(seg.offset);
            if mergeable {
                seg.rows.push(SegRow { node, slot, rel_off: (off - seg.offset) as usize });
                seg.span = new_span;
                seg.useful += row_bytes;
                continue;
            }
        }
        segments.push(Segment {
            offset: off,
            span: row_bytes,
            useful: row_bytes,
            rows: vec![SegRow { node, slot, rel_off: 0 }],
        });
    }
    interleave_by_device(segments, spec)
}

/// Round-robin the offset-sorted plan across owning devices so submission
/// fills every per-device sub-queue concurrently. Within one device the
/// offset order (and thus the planner's merge decisions) is preserved.
fn interleave_by_device(segments: Vec<Segment>, spec: StripeSpec) -> Vec<Segment> {
    if !spec.is_striped() || segments.len() < 2 {
        return segments;
    }
    let mut by_dev: Vec<Vec<Segment>> = (0..spec.devices).map(|_| Vec::new()).collect();
    for seg in segments {
        by_dev[spec.device_of(seg.offset)].push(seg);
    }
    let total: usize = by_dev.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    let mut lanes: Vec<_> = by_dev.into_iter().map(Vec::into_iter).collect();
    while out.len() < total {
        for lane in &mut lanes {
            if let Some(seg) = lane.next() {
                out.push(seg);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::FeatureGen;
    use crate::storage::{DataKind, FileId};
    use std::sync::Arc;

    const DIM: usize = 16; // 64-byte rows

    fn table() -> FeatureTable {
        let labels = Arc::new(vec![0u16; 4096]);
        let gen = FeatureGen::new(1, DIM, 2, 0.1, labels);
        FeatureTable::procedural(FileId::new(77, DataKind::Features), 4096, gen)
    }

    fn nodes(ids: &[u32]) -> Vec<(u32, u32)> {
        ids.iter().enumerate().map(|(i, &n)| (n, i as u32)).collect()
    }

    #[test]
    fn disabled_config_yields_one_row_per_segment() {
        let t = table();
        let segs = plan_segments(&nodes(&[5, 6, 7, 100]), &t, &CoalesceConfig::disabled(), 1 << 20);
        assert_eq!(segs.len(), 4);
        for s in &segs {
            assert_eq!(s.rows.len(), 1);
            assert_eq!(s.span, 64);
            assert_eq!(s.useful, 64);
        }
        // Sorted by offset regardless of input order.
        let segs = plan_segments(&nodes(&[9, 2, 4]), &t, &CoalesceConfig::disabled(), 1 << 20);
        let offs: Vec<u64> = segs.iter().map(|s| s.offset).collect();
        assert_eq!(offs, vec![2 * 64, 4 * 64, 9 * 64]);
    }

    #[test]
    fn contiguous_rows_merge_even_with_zero_gap_budget() {
        let t = table();
        let cfg = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: 0 };
        let segs = plan_segments(&nodes(&[10, 11, 12, 20]), &t, &cfg, 1 << 20);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].rows.len(), 3);
        assert_eq!(segs[0].offset, 10 * 64);
        assert_eq!(segs[0].span, 3 * 64);
        assert_eq!(segs[0].useful, 3 * 64);
        assert_eq!(
            segs[0].rows.iter().map(|r| r.rel_off).collect::<Vec<_>>(),
            vec![0, 64, 128]
        );
        assert_eq!(segs[1].rows.len(), 1);
    }

    #[test]
    fn gap_boundary_is_strict() {
        let t = table();
        // Nodes 0 and 4: gap between row 0's end (64) and row 4's start
        // (256) is 192 bytes.
        let cfg = |gap| CoalesceConfig { max_bytes: 1 << 20, gap_bytes: gap };
        // gap == gap_bytes → must NOT merge.
        let segs = plan_segments(&nodes(&[0, 4]), &t, &cfg(192), 1 << 20);
        assert_eq!(segs.len(), 2, "rows exactly coalesce-gap apart must not merge");
        // gap < gap_bytes → merges, span covers the bridged bytes but
        // useful counts only the rows.
        let segs = plan_segments(&nodes(&[0, 4]), &t, &cfg(193), 1 << 20);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].span, 5 * 64);
        assert_eq!(segs[0].useful, 2 * 64);
        assert_eq!(segs[0].rows[1].rel_off, 4 * 64);
    }

    #[test]
    fn max_bytes_caps_segment_span() {
        let t = table();
        let cfg = CoalesceConfig { max_bytes: 128, gap_bytes: 4096 };
        let segs = plan_segments(&nodes(&[0, 1, 2, 3, 4]), &t, &cfg, 1 << 20);
        // 64-byte rows, 128-byte cap → two rows per segment.
        assert_eq!(segs.len(), 3);
        assert_eq!(segs[0].rows.len(), 2);
        assert_eq!(segs[1].rows.len(), 2);
        assert_eq!(segs[2].rows.len(), 1);
        assert!(segs.iter().all(|s| s.span <= 128));
    }

    #[test]
    fn staging_capacity_clamps_max_bytes() {
        let t = table();
        let cfg = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: 4096 };
        // Arena of 4 rows: segments can never span more than 256 bytes.
        let segs = plan_segments(&nodes(&[0, 1, 2, 3, 4, 5, 6, 7]), &t, &cfg, 256);
        assert_eq!(segs.len(), 2);
        assert!(segs.iter().all(|s| s.span <= 256 && s.rows.len() == 4));
    }

    #[test]
    fn rows_and_bytes_are_conserved() {
        let t = table();
        let ids: Vec<u32> = vec![3, 900, 17, 901, 40, 41, 42, 500];
        let cfg = CoalesceConfig::default();
        let segs = plan_segments(&nodes(&ids), &t, &cfg, 1 << 20);
        let total_rows: usize = segs.iter().map(|s| s.rows.len()).sum();
        assert_eq!(total_rows, ids.len());
        let useful: usize = segs.iter().map(|s| s.useful).sum();
        assert_eq!(useful, ids.len() * 64, "useful bytes independent of merging");
        // Every (node, slot) pair survives with a consistent rel_off.
        for s in &segs {
            for r in &s.rows {
                assert_eq!(s.offset + r.rel_off as u64, t.row_offset(r.node as u64));
                let i = ids.iter().position(|&n| n == r.node).unwrap();
                assert_eq!(r.slot, i as u32);
            }
            assert!(s.span >= s.useful);
        }
    }

    #[test]
    fn striped_plan_splits_segments_at_chunk_boundaries() {
        let t = table();
        // 64-byte rows, 256-byte chunks, 2 devices: nodes 0..8 are one
        // contiguous 512-byte run that must split at offsets 256 and stay
        // one-device-per-segment.
        let spec = StripeSpec::new(2, 256);
        let cfg = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: 4096 };
        let segs =
            plan_segments_striped(&nodes(&[0, 1, 2, 3, 4, 5, 6, 7]), &t, &cfg, 1 << 20, spec);
        assert_eq!(segs.len(), 2);
        for s in &segs {
            assert_eq!(s.span, 256);
            assert_eq!(s.rows.len(), 4);
            let end = s.offset + s.span as u64;
            assert!(end <= spec.chunk_end(s.offset), "segment crosses its chunk");
        }
        let mut offs: Vec<u64> = segs.iter().map(|s| s.offset).collect();
        offs.sort_unstable();
        assert_eq!(offs, vec![0, 256]);
    }

    #[test]
    fn striped_plan_interleaves_round_robin_by_device() {
        let t = table();
        // Chunks of 256 B on 2 devices. Rows 0..4 → chunk 0 (dev 0), rows
        // 8..12 → chunk 2 (dev 0), rows 12..16 → chunk 3 (dev 1). Sorted
        // order is dev [0, 0, 1]; round-robin must yield [0, 1, 0].
        let spec = StripeSpec::new(2, 256);
        let cfg = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: 4096 };
        let ids: Vec<u32> = (0..4).chain(8..16).collect();
        let segs = plan_segments_striped(&nodes(&ids), &t, &cfg, 1 << 20, spec);
        assert_eq!(segs.len(), 3);
        let devs: Vec<usize> = segs.iter().map(|s| spec.device_of(s.offset)).collect();
        assert_eq!(devs, vec![0, 1, 0]);
        assert_eq!(
            segs.iter().map(|s| s.offset).collect::<Vec<_>>(),
            vec![0, 768, 512],
            "within a device, offset order is preserved"
        );
        let total_rows: usize = segs.iter().map(|s| s.rows.len()).sum();
        assert_eq!(total_rows, ids.len());
    }

    #[test]
    fn row_wider_than_stripe_becomes_its_own_segment() {
        let t = table();
        // 64-byte rows, 32-byte chunks: every row necessarily crosses a
        // chunk boundary, so nothing can merge — each row is one segment
        // served through the striped backing.
        let spec = StripeSpec::new(2, 32);
        let cfg = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: 4096 };
        let segs = plan_segments_striped(&nodes(&[0, 1, 2]), &t, &cfg, 1 << 20, spec);
        assert_eq!(segs.len(), 3);
        for s in &segs {
            assert_eq!(s.rows.len(), 1);
            assert_eq!(s.span, 64);
        }
    }

    #[test]
    fn adaptive_plan_applies_per_device_configs() {
        let t = table();
        // 64-byte rows, 256-byte chunks, 2 devices. Nodes 0..8 cover chunk
        // 0 (dev 0) and chunk 1 (dev 1). Dev 0 gets coalescing disabled,
        // dev 1 keeps wide merging: dev-0 rows must stay one-per-segment
        // while dev-1 rows merge into one 256-byte segment.
        let spec = StripeSpec::new(2, 256);
        let cfgs = [
            CoalesceConfig::disabled(),
            CoalesceConfig { max_bytes: 1 << 20, gap_bytes: 4096 },
        ];
        let segs = plan_segments_striped_adaptive(
            &nodes(&[0, 1, 2, 3, 4, 5, 6, 7]),
            &t,
            &cfgs,
            1 << 20,
            spec,
        );
        let (dev0, dev1): (Vec<_>, Vec<_>) =
            segs.iter().partition(|s| spec.device_of(s.offset) == 0);
        assert_eq!(dev0.len(), 4, "disabled config: one row per segment");
        assert!(dev0.iter().all(|s| s.rows.len() == 1 && s.span == 64));
        assert_eq!(dev1.len(), 1, "wide config: whole chunk merges");
        assert_eq!(dev1[0].rows.len(), 4);
        assert_eq!(dev1[0].span, 256);
        // One-element slice reproduces the single-config planner.
        let cfg = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: 4096 };
        let a = plan_segments_striped(&nodes(&[0, 1, 2, 3, 8, 9]), &t, &cfg, 1 << 20, spec);
        let b = plan_segments_striped_adaptive(
            &nodes(&[0, 1, 2, 3, 8, 9]),
            &t,
            &[cfg],
            1 << 20,
            spec,
        );
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.offset, x.span, x.useful), (y.offset, y.span, y.useful));
            assert_eq!(x.rows, y.rows);
        }
    }

    #[test]
    fn single_device_striped_plan_matches_unstriped() {
        let t = table();
        let ids: Vec<u32> = vec![3, 900, 17, 901, 40, 41, 42, 500];
        let cfg = CoalesceConfig::default();
        let plain = plan_segments(&nodes(&ids), &t, &cfg, 1 << 20);
        let striped =
            plan_segments_striped(&nodes(&ids), &t, &cfg, 1 << 20, StripeSpec::single());
        assert_eq!(plain.len(), striped.len());
        for (a, b) in plain.iter().zip(&striped) {
            assert_eq!(a.offset, b.offset);
            assert_eq!(a.span, b.span);
            assert_eq!(a.useful, b.useful);
            assert_eq!(a.rows, b.rows);
        }
    }
}
