//! Asynchronous two-phase feature extraction (paper §4.2, Fig 5,
//! Algorithm 1) with segment-coalesced I/O (§4.4).
//!
//! One extractor handles one mini-batch end to end, never blocking per
//! request. Phase 1 plans the batch's missing rows into coalesced
//! *segments* ([`crate::extract::coalesce`]) — runs of rows sorted by file
//! offset and merged into contiguous spans — and submits **one SQE per
//! segment** to its backend's async engine (direct I/O, large depth).
//! Phase 2 harvests completions and launches each segment's staging→device
//! PCIe transfer *as soon as its load completes*, overlapping with
//! outstanding loads; the transfer's completion scatters every row of the
//! segment into the feature buffer and publishes its valid bit. Nodes
//! already resident are aliased (no I/O), nodes being extracted by peers
//! are awaited at the end (shared I/O).
//!
//! Segments are packed into *waves* bounded by the staging arena: a wave
//! bump-allocates contiguous staging ranges ([`crate::membuf::WaveAlloc`])
//! until the arena is full, flushes, and continues — the staging buffer is
//! intentionally small (bounded memory footprint), so large batches simply
//! run in more waves. With coalescing disabled (`--coalesce-bytes 0`) every
//! segment is one row and the wave degenerates to the paper's baseline
//! one-SQE-per-row behavior.
//!
//! The extractor is backend-agnostic: it holds an [`IoBackend`] and drives
//! whatever [`AsyncIoEngine`] that backend mints (the sim io_uring, or the
//! OS-file `pread` pool), so the same pipeline runs against the simulator
//! and against real files. Completions land in lock-free staging ranges
//! ([`crate::membuf::SlotRef`]) — no mutex per row anywhere between submit
//! and publish.
//!
//! The returned alias list is the batch's currency downstream: the trainer
//! gathers rows by alias, and the releaser drops the references this
//! extraction took via [`FeatureBuffer::release_aliases`] — by slot index,
//! never re-resolving node ids — so the whole post-extraction lifecycle
//! stays off the coordinator's shard locks.

use super::coalesce::{plan_rows, plan_segments_striped_adaptive, CoalesceConfig, SegRow, Segment};
use crate::graph::FeatureTable;
use crate::layout::PackedLayout;
use crate::membuf::{FeatureBuffer, StagingBuffer};
use crate::sim::Latch;
use crate::tier::TieredFeatureStore;
use crate::storage::api::{AsyncIoEngine, Cqe, IoBackend, IoError, IoMode, Sqe};
use crate::storage::{Pcie, SimFile, StripeSpec};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A batch extraction that completed *degraded*: every row of the batch is
/// present and the wave protocol fully resolved (aliases are valid, staging
/// ranges were recycled, references balance), but `failed_nodes` hold zeroed
/// placeholder rows because their I/O exhausted the retry policy. The caller
/// owns policy: gather-and-train anyway (`drop-rows`), release + evict +
/// re-extract (`retry`), or abort (`fail`). Either way the aliases **must**
/// still be released through the normal lifecycle.
#[derive(Debug)]
pub struct ExtractError {
    /// Alias list of the whole batch — valid for gather/release like a
    /// successful extraction's return value.
    pub aliases: Vec<i32>,
    /// Nodes whose rows hold zeroed placeholders. Pair with
    /// [`FeatureBuffer::evict_if_idle`] before a retry so the reload is
    /// served by storage, not by the stale placeholder.
    pub failed_nodes: Vec<u32>,
    /// Representative (first-seen) error.
    pub error: IoError,
}

impl std::fmt::Display for ExtractError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "feature extraction degraded: {} row(s) failed ({})",
            self.failed_nodes.len(),
            self.error
        )
    }
}

impl std::error::Error for ExtractError {}

/// Where extracted rows land (§4.4 "CPU-based Training" skips the PCIe hop).
pub enum ExtractTarget {
    /// GPU training: staging → device via asynchronous PCIe transfers.
    Device(Arc<Pcie>),
    /// CPU training: rows go straight from staging into the host-resident
    /// feature buffer.
    Host,
}

/// Straggler-hedging knobs (`--hedge` / `--hedge-us`): re-issue the slowest
/// in-flight segments of a wave once their service time exceeds a
/// threshold. Original and hedge read the same span into **two distinct
/// staging ranges** of the same wave, so a late original can never scatter
/// into bytes the hedge already published — the first successful completion
/// wins (`done[]` guard), the loser is harvested and discarded, and both
/// requests are charged honestly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HedgeConfig {
    /// Master switch; `false` leaves the wave loop byte-identical to the
    /// pre-hedging extractor (no polling, no latency tracking).
    pub enabled: bool,
    /// Explicit reissue threshold in microseconds. `None` → p99-driven:
    /// the extractor tracks recent wave-relative segment completion times
    /// and hedges once a wave has been in flight past their p99.
    pub pin_us: Option<u64>,
}

impl HedgeConfig {
    pub fn disabled() -> Self {
        HedgeConfig { enabled: false, pin_us: None }
    }

    /// Hedge at a fixed threshold (tests, `--hedge-us`).
    pub fn pinned(us: u64) -> Self {
        HedgeConfig { enabled: true, pin_us: Some(us) }
    }

    /// Hedge at the observed p99 (`--hedge`).
    pub fn adaptive() -> Self {
        HedgeConfig { enabled: true, pin_us: None }
    }
}

impl Default for HedgeConfig {
    fn default() -> Self {
        HedgeConfig::disabled()
    }
}

/// Ablation switches (paper mechanisms turned off individually).
#[derive(Clone, Copy, Debug)]
pub struct ExtractOptions {
    /// false → synchronous per-row reads on the extractor thread (the
    /// paper's D2 congestion mode; `-async` ablation).
    pub asynchronous: bool,
    /// false → feature reads go through the OS page cache (the paper's D1
    /// contention mode; `-direct` ablation).
    pub direct: bool,
    /// Segment-coalescing knobs (`--coalesce-bytes 0` disables, restoring
    /// one request per row). Applies to the asynchronous direct path; the
    /// buffered and synchronous ablations keep per-row requests so they
    /// stay faithful baselines.
    pub coalesce: CoalesceConfig,
    /// Hedged reissue of straggler segments (default off). Direct
    /// asynchronous path only — the ablation baselines never hedge.
    pub hedge: HedgeConfig,
}

impl Default for ExtractOptions {
    fn default() -> Self {
        ExtractOptions {
            asynchronous: true,
            direct: true,
            coalesce: CoalesceConfig::default(),
            hedge: HedgeConfig::disabled(),
        }
    }
}

/// Completion-latency samples kept for the p99-driven hedge threshold.
const LAT_WINDOW: usize = 512;
/// Samples required before an adaptive (un-pinned) threshold is trusted.
const MIN_HEDGE_SAMPLES: usize = 32;
/// Poll interval of the hedging harvest loop while a hedge could still be
/// issued (the non-hedging path blocks in `wait_cqe` and never polls).
const HEDGE_TICK: Duration = Duration::from_micros(100);

pub struct Extractor {
    engine: Box<dyn AsyncIoEngine>,
    staging: StagingBuffer,
    fb: Arc<FeatureBuffer>,
    features: FeatureTable,
    target: ExtractTarget,
    backend: Arc<dyn IoBackend>,
    opts: ExtractOptions,
    /// Reused read buffer of the synchronous ablation path (one row; kept
    /// across `extract` calls instead of reallocating per invocation). The
    /// mutex is uncontended — it only serializes the rare case of one
    /// `Extractor` value driven from several threads.
    sync_scratch: Mutex<Vec<u8>>,
    /// Packed on-disk layout (`layout/`): when set, batches with a
    /// pre-sampled pack entry are served from their sequential pack run and
    /// the hot tier instead of random online rows
    /// ([`Extractor::try_extract_at`]).
    layout: Option<Arc<PackedLayout>>,
    /// Batches this extractor served from the packed layout (cumulative;
    /// the pipeline engine takes per-epoch deltas).
    packed_batches: AtomicU64,
    /// Hot-tier nodes that were already buffer-resident when a packed batch
    /// began — the pin's payoff (cumulative).
    hot_hits: AtomicU64,
    /// Per-device effective coalescing configs pushed by the adaptive
    /// governor (`pipeline` feeds [`Extractor::set_coalesce_configs`] each
    /// epoch). Empty → plan with `opts.coalesce` exactly as before.
    coalesce_override: Mutex<Vec<CoalesceConfig>>,
    /// Recent wave-relative segment completion times in µs (ring of
    /// [`LAT_WINDOW`]), the sample pool of the p99 hedge threshold. Only
    /// fed while hedging is enabled.
    lat_us: Mutex<Vec<u64>>,
    /// Tiered placement store (`--tier gpu`): when set, batch planning
    /// routes through the store so GPU-resident nodes are aliased into the
    /// device tier before the host buffer plans its misses. `None` (and
    /// `--tier host`) is the pre-tier path, byte- and charge-identical.
    tier: Option<Arc<TieredFeatureStore>>,
}

impl Extractor {
    pub fn new(
        backend: Arc<dyn IoBackend>,
        io_depth: usize,
        staging: StagingBuffer,
        fb: Arc<FeatureBuffer>,
        features: FeatureTable,
        target: ExtractTarget,
    ) -> Self {
        Self::with_options(backend, io_depth, staging, fb, features, target, ExtractOptions::default())
    }

    pub fn with_options(
        backend: Arc<dyn IoBackend>,
        io_depth: usize,
        staging: StagingBuffer,
        fb: Arc<FeatureBuffer>,
        features: FeatureTable,
        target: ExtractTarget,
        opts: ExtractOptions,
    ) -> Self {
        let engine = backend.clone().async_engine(io_depth);
        // Advertise the staging arena once: every SQE destination this
        // extractor ever submits lives inside it, so engines that can
        // pre-register DMA buffers (the io_uring path) serve the whole
        // workload as READ_FIXED. A pure hint — see the trait docs.
        let (arena_addr, arena_len) = staging.arena_range();
        engine.register_buffer_range(arena_addr, arena_len);
        Extractor {
            engine,
            staging,
            fb,
            features,
            target,
            backend,
            opts,
            sync_scratch: Mutex::new(Vec::new()),
            layout: None,
            tier: None,
            packed_batches: AtomicU64::new(0),
            hot_hits: AtomicU64::new(0),
            coalesce_override: Mutex::new(Vec::new()),
            lat_us: Mutex::new(Vec::new()),
        }
    }

    /// Install the governor's per-device effective coalescing configs for
    /// subsequent extractions (`cfgs[d]` governs stripe device `d`; empty
    /// restores `opts.coalesce`). Applies to the asynchronous direct online
    /// plan only — ablation baselines and the packed fast path are never
    /// rewritten by the governor.
    pub fn set_coalesce_configs(&self, cfgs: &[CoalesceConfig]) {
        let mut o = self.coalesce_override.lock().unwrap_or_else(|e| e.into_inner());
        o.clear();
        o.extend_from_slice(cfgs);
    }

    /// Current hedge threshold in µs: the explicit pin, or the observed p99
    /// once enough samples accumulated (`None` = cannot hedge yet).
    fn hedge_threshold_us(&self) -> Option<u64> {
        if let Some(us) = self.opts.hedge.pin_us {
            return Some(us.max(1));
        }
        let v = self.lat_us.lock().unwrap_or_else(|e| e.into_inner());
        if v.len() < MIN_HEDGE_SAMPLES {
            return None;
        }
        let mut s = v.clone();
        drop(v);
        s.sort_unstable();
        Some(s[(s.len() * 99 / 100).min(s.len() - 1)].max(1))
    }

    /// Record one original segment's wave-relative completion time.
    fn record_latency(&self, since_submit: Duration) {
        let mut v = self.lat_us.lock().unwrap_or_else(|e| e.into_inner());
        if v.len() >= LAT_WINDOW {
            v.swap_remove(0);
        }
        v.push(since_submit.as_micros() as u64);
    }

    /// Attach a packed layout: subsequent [`Extractor::try_extract_at`]
    /// calls with a batch context look up the batch's pack entry and serve
    /// it sequentially. Extraction without a context (or for batches the
    /// layout does not cover) is byte-identical to the unpacked path.
    pub fn set_layout(&mut self, layout: Arc<PackedLayout>) {
        self.layout = Some(layout);
    }

    /// Attach the tiered placement store (`--tier gpu`). Must wrap the same
    /// `FeatureBuffer` this extractor publishes into: the store only changes
    /// *planning* (GPU-tier aliasing, promotion bookkeeping); loads and
    /// publishes still go through the host buffer slots of `plan.to_load`.
    pub fn set_tier(&mut self, tier: Arc<TieredFeatureStore>) {
        debug_assert!(Arc::ptr_eq(tier.buffer(), &self.fb));
        self.tier = Some(tier);
    }

    /// Begin a batch through the tier store when attached, else directly on
    /// the host buffer (identical plans when no GPU tier exists).
    fn begin_batch(&self, nodes: &[u32]) -> crate::membuf::BatchPlan {
        match &self.tier {
            Some(t) => t.begin_batch(nodes),
            None => self.fb.begin_batch(nodes),
        }
    }

    /// Cumulative `(packed_batches, hot_hits)` counters.
    pub fn packed_stats(&self) -> (u64, u64) {
        (self.packed_batches.load(Ordering::Relaxed), self.hot_hits.load(Ordering::Relaxed))
    }

    /// Per-device submission-queue high-water marks of this extractor's
    /// engine (empty when the engine predates striping observability).
    pub fn queue_highwater(&self) -> Vec<u64> {
        self.engine.queue_highwater()
    }

    /// Extract the feature rows of `nodes` into the feature buffer; returns
    /// the node alias list (slot per node) for the trainer. Infallible
    /// facade over [`Extractor::try_extract`] for callers with no error
    /// policy: an exhausted-retry I/O failure panics here (the pipeline and
    /// serve engines use `try_extract` and decide policy instead).
    pub fn extract(&self, nodes: &[u32]) -> Vec<i32> {
        match self.try_extract(nodes) {
            Ok(aliases) => aliases,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible extraction with graceful degradation: on I/O failure every
    /// failed row is published as a zeroed placeholder (so the wave/wait
    /// protocol fully resolves and no staging range or reference leaks) and
    /// the batch returns [`ExtractError`] carrying the still-valid alias
    /// list plus the failed node ids.
    pub fn try_extract(&self, nodes: &[u32]) -> Result<Vec<i32>, ExtractError> {
        self.try_extract_at(nodes, None)
    }

    /// [`Extractor::try_extract`] with a batch context: when a packed
    /// layout is attached and covers `(epoch, batch_id)`, the batch's
    /// missing rows are served from its sequential pack run (+ the hot
    /// tier) instead of random online feature-file offsets. Every other
    /// case — no layout, no context, an uncovered batch, any node the pack
    /// row table cannot place, or the buffered/sync ablations — falls back
    /// to the online plan, byte-identical to the unpacked path.
    pub fn try_extract_at(
        &self,
        nodes: &[u32],
        ctx: Option<(u64, u64)>,
    ) -> Result<Vec<i32>, ExtractError> {
        let plan = self.begin_batch(nodes);

        if !self.opts.asynchronous {
            let (failed_nodes, first_err) = self.try_extract_sync(&plan.to_load);
            self.fb.wait_plan(&plan);
            return match first_err {
                None => Ok(plan.aliases),
                Some(error) => {
                    Err(ExtractError { aliases: plan.aliases, failed_nodes, error })
                }
            };
        }

        // Shutdown/abort ordering: a previous extraction that exited early
        // (panicking publish, caller caught an error and reused this
        // extractor) may have left submitted requests unharvested. Their
        // staging ranges are exactly the bytes this call's first wave is
        // about to reissue from cursor 0, so quiesce the engine *before*
        // any wave allocation — a late CQE must never scatter into a
        // recycled range. No-op on the normal path (both counters zero).
        if self.engine.inflight() > 0 || self.engine.pending_harvest() > 0 {
            self.engine.drain();
        }

        let mode = if self.opts.direct { IoMode::Direct } else { IoMode::Buffered };
        // Coalescing only pays on the direct path; the buffered ablation
        // keeps per-row requests so its page-cache accounting stays the
        // paper's D1 baseline.
        let coalesce =
            if self.opts.direct { self.opts.coalesce } else { CoalesceConfig::disabled() };
        let capacity = self.staging.capacity_bytes();
        // Packed fast path: a covered batch reads its pack run (+ hot-tier
        // stragglers) — long sequential segments — instead of the online
        // plan. Direct-mode only: the buffered ablation must stay the
        // paper's D1 baseline.
        let packed = match (&self.layout, ctx) {
            (Some(layout), Some((epoch, batch_id))) if self.opts.direct => {
                layout.plan_batch(epoch, batch_id, &plan.to_load)
            }
            _ => None,
        };
        // Every segment names the file it reads (feature table online; pack
        // or hot file packed), so one wave loop serves both layouts.
        let segments: Vec<(SimFile, Segment)> = match packed {
            Some(pp) => {
                let layout = self.layout.as_ref().unwrap();
                self.packed_batches.fetch_add(1, Ordering::Relaxed);
                // Hot nodes of the batch that did NOT need a load were
                // served by the pinned tier (or a peer's earlier load).
                let hot_in_batch =
                    nodes.iter().filter(|&&n| layout.is_hot(n)).count() as u64;
                self.hot_hits
                    .fetch_add(hot_in_batch - pp.hot_rows.len() as u64, Ordering::Relaxed);
                let row_bytes = self.staging.row_bytes;
                // A pack run is one contiguous span per batch; bridge the
                // holes of already-resident rows so the run degenerates to
                // ~one segment (bounded only by staging capacity and the
                // one-device-per-segment stripe rule).
                let run_cfg = CoalesceConfig { max_bytes: capacity, gap_bytes: capacity };
                let mut segs: Vec<(SimFile, Segment)> =
                    plan_rows(pp.pack_rows, row_bytes, &run_cfg, capacity, self.backend.stripe())
                        .into_iter()
                        .map(|s| (layout.packs.clone(), s))
                        .collect();
                // Hot-tier stragglers (not pinned yet): ordinary coalescing
                // over the unstriped hot file.
                segs.extend(
                    plan_rows(pp.hot_rows, row_bytes, &coalesce, capacity, StripeSpec::single())
                        .into_iter()
                        .map(|s| (layout.hot_file.clone(), s)),
                );
                segs
            }
            // Stripe-aware online plan: segments stay inside one stripe
            // chunk (one device per request) and are interleaved
            // round-robin across devices so every per-device sub-queue
            // fills from SQE one. The governor's per-device effective
            // configs (if pushed, and only while the direct path keeps
            // coalescing on) replace the static config here.
            None => {
                let over = self
                    .coalesce_override
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .clone();
                let cfgs: Vec<CoalesceConfig> = if coalesce.enabled() && !over.is_empty() {
                    over
                } else {
                    vec![coalesce]
                };
                plan_segments_striped_adaptive(
                    &plan.to_load,
                    &self.features,
                    &cfgs,
                    capacity,
                    self.backend.stripe(),
                )
                .into_iter()
                .map(|s| (self.features.file.clone(), s))
                .collect()
            }
        };

        // Waves: pack segments into the staging arena until it is full,
        // flush, repeat. Each staging range is owned by its segment's
        // request until the CQE is harvested (the SlotRef protocol); the
        // wave-end latch keeps the next wave from reusing arena bytes
        // before every transfer of this wave has landed.
        let hedging = self.opts.hedge.enabled && self.opts.direct;
        let mut failed_nodes: Vec<u32> = Vec::new();
        let mut first_err: Option<IoError> = None;
        let mut poisoned = false;
        let mut next = 0;
        while next < segments.len() && !poisoned {
            let mut wave = self.staging.wave_alloc();
            let mut in_wave = Vec::new();
            let mut sqes = Vec::new();
            while next < segments.len() {
                let (file, seg) = &segments[next];
                let Some(dst) = wave.alloc(seg.span) else { break };
                sqes.push(Sqe {
                    file: file.clone(),
                    offset: seg.offset,
                    len: seg.span,
                    useful: seg.useful,
                    dst: dst.clone(),
                    dst_off: 0,
                    user_data: in_wave.len() as u64,
                    mode,
                });
                in_wave.push((file, seg, dst));
                next += 1;
            }
            assert!(!in_wave.is_empty(), "segment exceeds staging capacity");

            // Phase 1: submit every segment load asynchronously.
            let latch = Arc::new(Latch::new(in_wave.len()));
            let submit_at = Instant::now();
            let thr_us = if hedging { self.hedge_threshold_us() } else { None };
            self.engine.submit_batch(sqes);

            // Phase 2: as each segment completes, launch its transfer
            // without waiting for sibling segments. A segment that
            // completes with an error degrades in place: its rows publish
            // as zeroed placeholders (keeping the latch/wait protocol
            // balanced) and are reported to the caller.
            //
            // Hedging (when enabled and a threshold is known): once the
            // wave has been in flight past the threshold, every live
            // not-yet-hedged segment is re-issued into a *fresh* staging
            // range of this same wave. Each request — original or hedge —
            // produces exactly one CQE and all of them are harvested before
            // the wave ends, so no range leaks and no late completion can
            // touch recycled arena bytes. `done[]` makes the first
            // successful completion the only one that scatters.
            let mut done = vec![false; in_wave.len()];
            let mut hedged = vec![false; in_wave.len()];
            let mut outstanding: Vec<u32> = vec![1; in_wave.len()];
            let mut stashed_err: Vec<Option<IoError>> = vec![None; in_wave.len()];
            // Hedge ordinal → (wave index, the duplicate's staging range);
            // hedge k carries user_data in_wave.len() + k.
            let mut hedges: Vec<(usize, crate::membuf::SlotRef)> = Vec::new();
            let mut arena_full = false;
            let mut pending = in_wave.len();
            while pending > 0 {
                // Poll (instead of block) only while a hedge could still
                // fire; once nothing is hedgeable, fall back to the
                // blocking harvest — which also surfaces engine poisoning,
                // something `peek_cqe` never synthesizes.
                let can_hedge = thr_us.is_some()
                    && !arena_full
                    && done.iter().zip(&hedged).any(|(d, h)| !*d && !*h);
                let cqe = if can_hedge {
                    match self.engine.peek_cqe() {
                        Some(c) => c,
                        None => {
                            let thr = thr_us.unwrap();
                            if submit_at.elapsed().as_micros() as u64 > thr {
                                for idx in 0..in_wave.len() {
                                    if done[idx] || hedged[idx] {
                                        continue;
                                    }
                                    let (file, seg, _) = &in_wave[idx];
                                    let Some(dst) = wave.alloc(seg.span) else {
                                        arena_full = true;
                                        break;
                                    };
                                    self.engine.submit(Sqe {
                                        file: (*file).clone(),
                                        offset: seg.offset,
                                        len: seg.span,
                                        useful: seg.useful,
                                        dst: dst.clone(),
                                        dst_off: 0,
                                        user_data: (in_wave.len() + hedges.len()) as u64,
                                        mode,
                                    });
                                    self.backend.direct_stats().count_hedge();
                                    hedged[idx] = true;
                                    outstanding[idx] += 1;
                                    hedges.push((idx, dst));
                                    pending += 1;
                                }
                            }
                            std::thread::sleep(HEDGE_TICK);
                            continue;
                        }
                    }
                } else {
                    self.engine.wait_cqe()
                };
                if cqe.user_data == Cqe::POISON_USER_DATA {
                    // The engine died with this wave outstanding: every
                    // unharvested segment is failed; the core has already
                    // reconciled its counters and a late completion can no
                    // longer scatter (workers are gone).
                    for (harvested, (_, seg, _)) in done.iter().zip(&in_wave) {
                        if !harvested {
                            fail_rows(&self.fb, &seg.rows, self.staging.row_bytes);
                            failed_nodes.extend(seg.rows.iter().map(|r| r.node));
                            latch.count_down();
                        }
                    }
                    first_err.get_or_insert(IoError::EnginePoisoned);
                    poisoned = true;
                    break;
                }
                pending -= 1;
                let (idx, is_hedge, staged) = if (cqe.user_data as usize) < in_wave.len() {
                    (cqe.user_data as usize, false, &in_wave[cqe.user_data as usize].2)
                } else {
                    let (idx, dst) = &hedges[cqe.user_data as usize - in_wave.len()];
                    (*idx, true, dst)
                };
                outstanding[idx] -= 1;
                if done[idx] {
                    // The loser of a hedged pair: its bytes stay in their
                    // own (wave-owned) range and are simply discarded.
                    continue;
                }
                let (_, seg, _) = &in_wave[idx];
                match &cqe.status {
                    Err(e) => {
                        if outstanding[idx] > 0 {
                            // The sibling request may still deliver; fail
                            // the segment only when both halves are in.
                            stashed_err[idx].get_or_insert(e.clone());
                            continue;
                        }
                        // Staging bytes are undefined: never decode them.
                        done[idx] = true;
                        fail_rows(&self.fb, &seg.rows, self.staging.row_bytes);
                        failed_nodes.extend(seg.rows.iter().map(|r| r.node));
                        let err = stashed_err[idx].take().unwrap_or_else(|| e.clone());
                        first_err.get_or_insert(err);
                        latch.count_down();
                    }
                    Ok(_) => {
                        done[idx] = true;
                        if is_hedge {
                            self.backend.direct_stats().count_hedge_win();
                        } else if hedging {
                            self.record_latency(submit_at.elapsed());
                        }
                        match &self.target {
                            ExtractTarget::Device(pcie) => {
                                let fb = self.fb.clone();
                                let latch = latch.clone();
                                let staged = staged.clone();
                                let rows = seg.rows.clone();
                                let row_bytes = self.staging.row_bytes;
                                // Only the rows cross PCIe — bridged gap
                                // bytes die in staging.
                                pcie.transfer_async(seg.useful, move || {
                                    // Decode straight from the staging
                                    // bytes into the arena rows — no
                                    // intermediate Vec<f32>, no per-row
                                    // lock.
                                    publish_rows(&fb, &rows, &staged, row_bytes);
                                    latch.count_down();
                                });
                            }
                            ExtractTarget::Host => {
                                publish_rows(
                                    &self.fb,
                                    &seg.rows,
                                    staged,
                                    self.staging.row_bytes,
                                );
                                latch.count_down();
                            }
                        }
                    }
                }
            }
            // All transfers of this wave must land before its staging
            // ranges are reused by the next wave.
            latch.wait();
        }

        // A poisoned engine cannot serve the remaining waves (submitting
        // would abort): their rows degrade to placeholders too, so the
        // plan's loading slots all resolve and `wait_plan` cannot hang.
        if poisoned {
            for (_, seg) in &segments[next..] {
                fail_rows(&self.fb, &seg.rows, self.staging.row_bytes);
                failed_nodes.extend(seg.rows.iter().map(|r| r.node));
            }
        }

        // Wait for nodes being extracted by peer extractors (pre-resolved
        // tickets: no shard locks on the wait path).
        self.fb.wait_plan(&plan);
        match first_err {
            None => Ok(plan.aliases),
            Some(error) => Err(ExtractError { aliases: plan.aliases, failed_nodes, error }),
        }
    }

    /// Ablation: synchronous extraction — one blocking read + one blocking
    /// transfer per row on this thread (no overlap, no coalescing: the
    /// paper's D2 congestion mode must stay a faithful per-row baseline).
    /// Applies the backend's retry policy per row; rows that exhaust it
    /// publish zeroed placeholders and are returned as failed.
    fn try_extract_sync(&self, to_load: &[(u32, u32)]) -> (Vec<u32>, Option<IoError>) {
        let row_bytes = self.staging.row_bytes;
        let policy = self.backend.retry_policy();
        // Poison-tolerant lock: a panic in an unrelated caller must not
        // wedge every future extraction on this shared scratch buffer (the
        // Vec itself is always left in a valid state — worst case it holds
        // stale bytes that the next read overwrites).
        let mut buf = self.sync_scratch.lock().unwrap_or_else(|e| e.into_inner());
        buf.resize(row_bytes, 0);
        let mut failed_nodes = Vec::new();
        let mut first_err: Option<IoError> = None;
        for &(node, slot) in to_load {
            let off = self.features.row_offset(node as u64);
            let mut attempt = 0u32;
            let outcome = loop {
                let r = if self.opts.direct {
                    self.backend.try_read_direct(&self.features.file, off, &mut buf, attempt)
                } else {
                    self.backend.try_read_buffered(&self.features.file, off, &mut buf, attempt)
                };
                match r {
                    Ok(()) => break Ok(()),
                    Err(e) if e.retryable() && attempt < policy.max_retries => {
                        attempt += 1;
                        self.backend.direct_stats().count_retry();
                        std::thread::sleep(Duration::from_micros(
                            policy.backoff_us(off, attempt),
                        ));
                    }
                    Err(e) => {
                        self.backend.direct_stats().count_failure();
                        break Err(e);
                    }
                }
            };
            match outcome {
                Ok(()) => {
                    // Host target (CPU training) skips the PCIe hop: the
                    // row decodes straight into the host-resident buffer.
                    if let ExtractTarget::Device(pcie) = &self.target {
                        pcie.transfer_sync(row_bytes);
                    }
                    self.fb.publish_le_bytes(node, slot, &buf);
                }
                Err(e) => {
                    buf.fill(0);
                    self.fb.publish_le_bytes(node, slot, &buf);
                    failed_nodes.push(node);
                    first_err.get_or_insert(e);
                }
            }
        }
        (failed_nodes, first_err)
    }
}

/// Publish zeroed placeholder rows for a failed segment: the wave protocol
/// (latch, wait_plan, reference balance) requires *something* in every
/// loading slot, and zeros are the only bytes we may legally write when the
/// staging range contents are undefined.
fn fail_rows(fb: &FeatureBuffer, rows: &[SegRow], row_bytes: usize) {
    let zeros = vec![0u8; row_bytes];
    for r in rows {
        fb.publish_le_bytes(r.node, r.slot, &zeros);
    }
}

/// Scatter a completed segment's rows into the feature buffer.
fn publish_rows(
    fb: &FeatureBuffer,
    rows: &[SegRow],
    staged: &crate::membuf::SlotRef,
    row_bytes: usize,
) {
    let bytes = staged.bytes();
    for r in rows {
        fb.publish_le_bytes(r.node, r.slot, &bytes[r.rel_off..r.rel_off + row_bytes]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Machine, MachineConfig};
    use crate::graph::{Dataset, DatasetSpec};
    use crate::sim::Clock;
    use crate::storage::DeviceMemory;

    fn setup() -> (Machine, Dataset, Arc<FeatureBuffer>) {
        let m = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &m).unwrap();
        let dev = DeviceMemory::new(8 << 20);
        let fb = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        (m, ds, fb)
    }

    fn extractor_with(
        m: &Machine,
        ds: &Dataset,
        fb: Arc<FeatureBuffer>,
        slots: usize,
        opts: ExtractOptions,
    ) -> Extractor {
        let staging =
            StagingBuffer::new(&m.host, slots, ds.features.row_bytes() as usize).unwrap();
        Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb,
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            opts,
        )
    }

    fn extractor(m: &Machine, ds: &Dataset, fb: Arc<FeatureBuffer>, slots: usize) -> Extractor {
        extractor_with(m, ds, fb, slots, ExtractOptions::default())
    }

    #[test]
    fn extracts_correct_rows() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = vec![5, 900, 33, 2999];
        let aliases = ex.extract(&nodes);
        assert!(aliases.iter().all(|&a| a >= 0));
        let mut out = vec![0f32; nodes.len() * ds.spec.dim];
        fb.gather(&aliases, &mut out);
        // Compare against the oracle generator.
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            ds.feature_gen.fill_row(v as u64, &mut want);
            let exp = crate::graph::FeatureGen::decode_row(&want);
            let got = &out[i * ds.spec.dim..(i + 1) * ds.spec.dim];
            assert_eq!(got, &exp[..], "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn waves_handle_batches_larger_than_staging() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 8); // tiny staging
        let nodes: Vec<u32> = (100..160).collect(); // 60 nodes, 8-slot staging
        let aliases = ex.extract(&nodes);
        assert_eq!(aliases.len(), 60);
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn coalescing_merges_requests_without_changing_rows() {
        // Same nodes, coalescing off vs on: identical extracted rows,
        // strictly fewer charged device requests, identical useful bytes.
        let (m, ds, _) = setup();
        let dev = DeviceMemory::new(8 << 20);
        let nodes: Vec<u32> = (200..264).collect(); // dense: 64-byte rows share sectors

        let fb_off = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        let ex_off = extractor_with(
            &m,
            &ds,
            fb_off.clone(),
            64,
            ExtractOptions { coalesce: CoalesceConfig::disabled(), ..Default::default() },
        );
        m.storage.ssd.reset_stats();
        let dio0 = m.backend.direct_stats().snapshot();
        let a_off = ex_off.extract(&nodes);
        let reads_off = m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed);
        let (useful_off, aligned_off) = {
            let (u, a) = m.backend.direct_stats().snapshot();
            (u - dio0.0, a - dio0.1)
        };

        let fb_on = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        let ex_on = extractor(&m, &ds, fb_on.clone(), 64);
        m.storage.ssd.reset_stats();
        let dio1 = m.backend.direct_stats().snapshot();
        let a_on = ex_on.extract(&nodes);
        let reads_on = m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed);
        let (useful_on, aligned_on) = {
            let (u, a) = m.backend.direct_stats().snapshot();
            (u - dio1.0, a - dio1.1)
        };

        assert_eq!(reads_off, 64, "baseline: one request per row");
        assert!(
            reads_on * 2 <= reads_off,
            "coalescing must at least halve charged requests: {reads_on} vs {reads_off}"
        );
        assert_eq!(useful_on, useful_off, "useful bytes independent of coalescing");
        assert!(
            aligned_on <= aligned_off,
            "dense rows must not amplify: {aligned_on} vs {aligned_off}"
        );

        let mut off_rows = vec![0f32; nodes.len() * ds.spec.dim];
        let mut on_rows = vec![0f32; nodes.len() * ds.spec.dim];
        fb_off.gather(&a_off, &mut off_rows);
        fb_on.gather(&a_on, &mut on_rows);
        assert_eq!(off_rows, on_rows, "extracted bytes must be identical");
        fb_on.check_invariants().unwrap();
    }

    #[test]
    fn second_extraction_reuses_buffer() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = (0..32).collect();
        ex.extract(&nodes);
        fb.release(&nodes);
        m.storage.ssd.reset_stats();
        let aliases = ex.extract(&nodes);
        // No SSD reads the second time.
        assert_eq!(
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        assert_eq!(aliases.len(), 32);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn alias_release_roundtrips_with_extraction() {
        // The engine's lifecycle: extract → gather → release_aliases (the
        // releaser never sees node ids). Slots must come back reusable and
        // a re-extraction must still hit.
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = (40..72).collect();
        let aliases = ex.extract(&nodes);
        let mut out = vec![0f32; nodes.len() * ds.spec.dim];
        fb.gather(&aliases, &mut out);
        fb.release_aliases(&aliases);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), fb.n_slots, "all references dropped");
        m.storage.ssd.reset_stats();
        let again = ex.extract(&nodes);
        assert_eq!(again, aliases, "released-by-alias rows stay resident");
        assert_eq!(
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "alias release must not evict resident rows"
        );
        fb.release_aliases(&again);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn direct_io_bypasses_page_cache() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb, 64);
        ex.extract(&(0..64).collect::<Vec<u32>>());
        // Feature extraction must not populate the page cache (D1 fix),
        // coalesced segments included.
        let feat_hits = m
            .storage
            .cache
            .stats()
            .features
            .hits
            .load(std::sync::atomic::Ordering::Relaxed);
        let feat_misses = m
            .storage
            .cache
            .stats()
            .features
            .misses
            .load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(feat_hits + feat_misses, 0, "feature reads went through page cache");
    }

    #[test]
    fn sync_mode_produces_identical_rows() {
        let (m, ds, fb) = setup();
        let staging =
            StagingBuffer::new(&m.host, 64, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb.clone(),
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            ExtractOptions { asynchronous: false, ..Default::default() },
        );
        let nodes: Vec<u32> = (10..42).collect();
        let aliases = ex.extract(&nodes);
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn sync_mode_host_target_publishes_without_pcie() {
        // The sync ablation must respect ExtractTarget::Host: rows publish
        // into the host buffer and the PCIe link stays untouched.
        let (m, ds, _) = setup();
        let host_fb = Arc::new(FeatureBuffer::in_host(&m.host, 256, ds.spec.dim).unwrap());
        let staging =
            StagingBuffer::new(&m.host, 32, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            32,
            staging,
            host_fb.clone(),
            ds.features.clone(),
            ExtractTarget::Host,
            ExtractOptions { asynchronous: false, ..Default::default() },
        );
        let pcie_before = m.pcie.transfer_count();
        let nodes: Vec<u32> = (7..23).collect();
        let aliases = ex.extract(&nodes);
        assert_eq!(m.pcie.transfer_count(), pcie_before, "Host target must skip PCIe");
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            host_fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        host_fb.check_invariants().unwrap();
    }

    #[test]
    fn sync_scratch_is_reused_across_calls() {
        let (m, ds, fb) = setup();
        let ex = extractor_with(
            &m,
            &ds,
            fb.clone(),
            64,
            ExtractOptions { asynchronous: false, ..Default::default() },
        );
        ex.extract(&(0..8).collect::<Vec<u32>>());
        let ptr1 = ex.sync_scratch.lock().unwrap().as_ptr();
        let cap1 = ex.sync_scratch.lock().unwrap().capacity();
        fb.release(&(0..8).collect::<Vec<u32>>());
        ex.extract(&(100..108).collect::<Vec<u32>>());
        let ptr2 = ex.sync_scratch.lock().unwrap().as_ptr();
        assert_eq!(ptr1, ptr2, "scratch buffer must not reallocate per call");
        assert_eq!(ex.sync_scratch.lock().unwrap().capacity(), cap1);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn buffered_mode_populates_page_cache() {
        let (m, ds, fb) = setup();
        let staging =
            StagingBuffer::new(&m.host, 64, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb,
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            ExtractOptions { asynchronous: true, direct: false, ..Default::default() },
        );
        m.storage.cache.stats().reset();
        ex.extract(&(0..32).collect::<Vec<u32>>());
        let touches = m
            .storage
            .cache
            .stats()
            .features
            .misses
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(touches > 0, "-direct ablation must go through the page cache");
    }

    #[test]
    fn governor_override_rewrites_effective_coalescing() {
        // Pushing a disabled per-device config must restore the per-row
        // request baseline even though opts.coalesce stays enabled — and
        // clearing the override must bring merging back.
        let (m, ds, _) = setup();
        let dev = DeviceMemory::new(8 << 20);
        let nodes: Vec<u32> = (400..464).collect(); // dense rows

        let fb = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        let ex = extractor(&m, &ds, fb.clone(), 64);
        ex.set_coalesce_configs(&[CoalesceConfig::disabled()]);
        m.storage.ssd.reset_stats();
        ex.extract(&nodes);
        let reads_overridden =
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(reads_overridden, 64, "disabled override must plan one request per row");

        let fb2 = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        let ex2 = extractor(&m, &ds, fb2.clone(), 64);
        ex2.set_coalesce_configs(&[CoalesceConfig::disabled()]);
        ex2.set_coalesce_configs(&[]); // clear → back to opts.coalesce
        m.storage.ssd.reset_stats();
        ex2.extract(&nodes);
        let reads_cleared =
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed);
        assert!(
            reads_cleared * 2 <= reads_overridden,
            "cleared override must coalesce again: {reads_cleared} vs {reads_overridden}"
        );
        fb2.check_invariants().unwrap();
    }

    #[test]
    fn hedged_reissue_beats_stalled_originals_without_double_scatter() {
        use crate::storage::{BackendKind, FaultInjectBackend, FaultPlan, RetryPolicy};

        let clock = Clock::new(0.05);
        let m = Machine::new(MachineConfig::paper(), clock.clone());
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &m).unwrap();
        let fb = Arc::new(FeatureBuffer::in_host(&m.host, 256, ds.spec.dim).unwrap());

        let nodes: Vec<u32> = (300..316).collect();
        let offsets: Vec<u64> =
            nodes.iter().map(|&n| ds.features.row_offset(n as u64)).collect();
        // Deterministic storm: select a seed where ≥3 offsets stall on
        // their first service draw (the original's) but not their second
        // (the hedge's), and no offset stalls on both draws — so at least
        // one hedge must win and no hedged pair is a double-stall washout.
        let seed = (0..5_000u64)
            .find(|&s| {
                let plan =
                    FaultPlan { seed: s, stall_rate: 0.4, stall_us: 1, ..FaultPlan::default() };
                let mut winnable = 0;
                for &off in &offsets {
                    let d0 = plan.stall_verdict(off, 0);
                    let d1 = plan.stall_verdict(off, 1);
                    if d0 && d1 {
                        return false;
                    }
                    if d0 && !d1 {
                        winnable += 1;
                    }
                }
                winnable >= 3
            })
            .expect("no usable stall seed in 0..5000");
        // 100 ms of simulated stall ≈ 5 ms real at clock scale 0.05 — far
        // past the 500 µs hedge pin, far under test-timeout scale.
        let plan = FaultPlan {
            seed,
            stall_rate: 0.4,
            stall_us: 100_000,
            ..FaultPlan::default()
        };
        let faulty = Arc::new(FaultInjectBackend::new(
            m.backend.clone(),
            BackendKind::Sim,
            plan,
            RetryPolicy::default(),
            clock,
        ));

        let staging =
            StagingBuffer::new(&m.host, 64, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            faulty.clone(),
            64,
            staging,
            fb.clone(),
            ds.features.clone(),
            ExtractTarget::Host,
            ExtractOptions {
                // Per-row segments keep wave offsets == the seed-searched
                // row offsets; a pinned threshold needs no warm-up samples.
                coalesce: CoalesceConfig::disabled(),
                hedge: HedgeConfig::pinned(500),
                ..Default::default()
            },
        );

        let aliases = ex.extract(&nodes);
        // Correct bytes regardless of which copy won.
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        // Counters reconcile: hedges were issued, at least one won, and
        // wins never exceed issues.
        let (hedges, wins) = faulty.direct_stats().hedge_snapshot();
        assert!(hedges >= 3, "stalled originals must have been hedged: {hedges}");
        assert!(wins >= 1, "an unstalled hedge must beat its stalled original");
        assert!(wins <= hedges);
        // Exactly one scatter per node: a hedge/original pair must publish
        // once, never twice.
        let (_, _, _, loads) = fb.stats();
        assert_eq!(loads, nodes.len() as u64, "double scatter detected");
        fb.check_invariants().unwrap();

        // No leaked staging ranges or stray CQEs: the arena reissues
        // cleanly for a second batch on the same extractor.
        fb.release(&nodes);
        let nodes2: Vec<u32> = (600..608).collect();
        let a2 = ex.extract(&nodes2);
        assert_eq!(a2.len(), nodes2.len());
        fb.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_extractors_share_work() {
        let (m, ds, fb) = setup();
        let ex1 = Arc::new(extractor(&m, &ds, fb.clone(), 64));
        let ex2 = Arc::new(extractor(&m, &ds, fb.clone(), 64));
        let nodes: Vec<u32> = (0..48).collect();
        let (n1, n2) = (nodes.clone(), nodes.clone());
        let h1 = std::thread::spawn(move || ex1.extract(&n1));
        let h2 = std::thread::spawn(move || ex2.extract(&n2));
        let a1 = h1.join().unwrap();
        let a2 = h2.join().unwrap();
        assert_eq!(a1, a2, "both extractors must alias the same slots");
        let (_, _, _, loads) = fb.stats();
        assert_eq!(loads, 48, "each node loaded exactly once across extractors");
        fb.check_invariants().unwrap();
    }
}
