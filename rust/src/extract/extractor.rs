//! Asynchronous two-phase feature extraction (paper §4.2, Fig 5,
//! Algorithm 1) with segment-coalesced I/O (§4.4).
//!
//! One extractor handles one mini-batch end to end, never blocking per
//! request. Phase 1 plans the batch's missing rows into coalesced
//! *segments* ([`crate::extract::coalesce`]) — runs of rows sorted by file
//! offset and merged into contiguous spans — and submits **one SQE per
//! segment** to its backend's async engine (direct I/O, large depth).
//! Phase 2 harvests completions and launches each segment's staging→device
//! PCIe transfer *as soon as its load completes*, overlapping with
//! outstanding loads; the transfer's completion scatters every row of the
//! segment into the feature buffer and publishes its valid bit. Nodes
//! already resident are aliased (no I/O), nodes being extracted by peers
//! are awaited at the end (shared I/O).
//!
//! Segments are packed into *waves* bounded by the staging arena: a wave
//! bump-allocates contiguous staging ranges ([`crate::membuf::WaveAlloc`])
//! until the arena is full, flushes, and continues — the staging buffer is
//! intentionally small (bounded memory footprint), so large batches simply
//! run in more waves. With coalescing disabled (`--coalesce-bytes 0`) every
//! segment is one row and the wave degenerates to the paper's baseline
//! one-SQE-per-row behavior.
//!
//! The extractor is backend-agnostic: it holds an [`IoBackend`] and drives
//! whatever [`AsyncIoEngine`] that backend mints (the sim io_uring, or the
//! OS-file `pread` pool), so the same pipeline runs against the simulator
//! and against real files. Completions land in lock-free staging ranges
//! ([`crate::membuf::SlotRef`]) — no mutex per row anywhere between submit
//! and publish.
//!
//! The returned alias list is the batch's currency downstream: the trainer
//! gathers rows by alias, and the releaser drops the references this
//! extraction took via [`FeatureBuffer::release_aliases`] — by slot index,
//! never re-resolving node ids — so the whole post-extraction lifecycle
//! stays off the coordinator's shard locks.

use super::coalesce::{plan_segments, CoalesceConfig, SegRow};
use crate::graph::FeatureTable;
use crate::membuf::{FeatureBuffer, StagingBuffer};
use crate::sim::Latch;
use crate::storage::api::{AsyncIoEngine, IoBackend, IoMode, Sqe};
use crate::storage::Pcie;
use std::sync::{Arc, Mutex};

/// Where extracted rows land (§4.4 "CPU-based Training" skips the PCIe hop).
pub enum ExtractTarget {
    /// GPU training: staging → device via asynchronous PCIe transfers.
    Device(Arc<Pcie>),
    /// CPU training: rows go straight from staging into the host-resident
    /// feature buffer.
    Host,
}

/// Ablation switches (paper mechanisms turned off individually).
#[derive(Clone, Copy, Debug)]
pub struct ExtractOptions {
    /// false → synchronous per-row reads on the extractor thread (the
    /// paper's D2 congestion mode; `-async` ablation).
    pub asynchronous: bool,
    /// false → feature reads go through the OS page cache (the paper's D1
    /// contention mode; `-direct` ablation).
    pub direct: bool,
    /// Segment-coalescing knobs (`--coalesce-bytes 0` disables, restoring
    /// one request per row). Applies to the asynchronous direct path; the
    /// buffered and synchronous ablations keep per-row requests so they
    /// stay faithful baselines.
    pub coalesce: CoalesceConfig,
}

impl Default for ExtractOptions {
    fn default() -> Self {
        ExtractOptions {
            asynchronous: true,
            direct: true,
            coalesce: CoalesceConfig::default(),
        }
    }
}

pub struct Extractor {
    engine: Box<dyn AsyncIoEngine>,
    staging: StagingBuffer,
    fb: Arc<FeatureBuffer>,
    features: FeatureTable,
    target: ExtractTarget,
    backend: Arc<dyn IoBackend>,
    opts: ExtractOptions,
    /// Reused read buffer of the synchronous ablation path (one row; kept
    /// across `extract` calls instead of reallocating per invocation). The
    /// mutex is uncontended — it only serializes the rare case of one
    /// `Extractor` value driven from several threads.
    sync_scratch: Mutex<Vec<u8>>,
}

impl Extractor {
    pub fn new(
        backend: Arc<dyn IoBackend>,
        io_depth: usize,
        staging: StagingBuffer,
        fb: Arc<FeatureBuffer>,
        features: FeatureTable,
        target: ExtractTarget,
    ) -> Self {
        Self::with_options(backend, io_depth, staging, fb, features, target, ExtractOptions::default())
    }

    pub fn with_options(
        backend: Arc<dyn IoBackend>,
        io_depth: usize,
        staging: StagingBuffer,
        fb: Arc<FeatureBuffer>,
        features: FeatureTable,
        target: ExtractTarget,
        opts: ExtractOptions,
    ) -> Self {
        Extractor {
            engine: backend.clone().async_engine(io_depth),
            staging,
            fb,
            features,
            target,
            backend,
            opts,
            sync_scratch: Mutex::new(Vec::new()),
        }
    }

    /// Extract the feature rows of `nodes` into the feature buffer; returns
    /// the node alias list (slot per node) for the trainer.
    pub fn extract(&self, nodes: &[u32]) -> Vec<i32> {
        let plan = self.fb.begin_batch(nodes);

        if !self.opts.asynchronous {
            self.extract_sync(&plan.to_load);
            self.fb.wait_plan(&plan);
            return plan.aliases;
        }

        // Shutdown/abort ordering: a previous extraction that exited early
        // (panicking publish, caller caught an error and reused this
        // extractor) may have left submitted requests unharvested. Their
        // staging ranges are exactly the bytes this call's first wave is
        // about to reissue from cursor 0, so quiesce the engine *before*
        // any wave allocation — a late CQE must never scatter into a
        // recycled range. No-op on the normal path (both counters zero).
        if self.engine.inflight() > 0 || self.engine.pending_harvest() > 0 {
            self.engine.drain();
        }

        let mode = if self.opts.direct { IoMode::Direct } else { IoMode::Buffered };
        // Coalescing only pays on the direct path; the buffered ablation
        // keeps per-row requests so its page-cache accounting stays the
        // paper's D1 baseline.
        let coalesce =
            if self.opts.direct { self.opts.coalesce } else { CoalesceConfig::disabled() };
        let segments = plan_segments(
            &plan.to_load,
            &self.features,
            &coalesce,
            self.staging.capacity_bytes(),
        );

        // Waves: pack segments into the staging arena until it is full,
        // flush, repeat. Each staging range is owned by its segment's
        // request until the CQE is harvested (the SlotRef protocol); the
        // wave-end latch keeps the next wave from reusing arena bytes
        // before every transfer of this wave has landed.
        let mut next = 0;
        while next < segments.len() {
            let mut wave = self.staging.wave_alloc();
            let mut in_wave = Vec::new();
            let mut sqes = Vec::new();
            while next < segments.len() {
                let seg = &segments[next];
                let Some(dst) = wave.alloc(seg.span) else { break };
                sqes.push(Sqe {
                    file: self.features.file.clone(),
                    offset: seg.offset,
                    len: seg.span,
                    useful: seg.useful,
                    dst: dst.clone(),
                    dst_off: 0,
                    user_data: in_wave.len() as u64,
                    mode,
                });
                in_wave.push((seg, dst));
                next += 1;
            }
            assert!(!in_wave.is_empty(), "segment exceeds staging capacity");

            // Phase 1: submit every segment load asynchronously.
            let latch = Arc::new(Latch::new(in_wave.len()));
            self.engine.submit_batch(sqes);

            // Phase 2: as each segment completes, launch its transfer
            // without waiting for sibling segments.
            for _ in 0..in_wave.len() {
                let cqe = self.engine.wait_cqe();
                let (seg, staged) = &in_wave[cqe.user_data as usize];
                match &self.target {
                    ExtractTarget::Device(pcie) => {
                        let fb = self.fb.clone();
                        let latch = latch.clone();
                        let staged = staged.clone();
                        let rows = seg.rows.clone();
                        let row_bytes = self.staging.row_bytes;
                        // Only the rows cross PCIe — bridged gap bytes die
                        // in staging.
                        pcie.transfer_async(seg.useful, move || {
                            // Decode straight from the staging bytes into
                            // the arena rows — no intermediate Vec<f32>,
                            // no per-row lock.
                            publish_rows(&fb, &rows, &staged, row_bytes);
                            latch.count_down();
                        });
                    }
                    ExtractTarget::Host => {
                        publish_rows(&self.fb, &seg.rows, staged, self.staging.row_bytes);
                        latch.count_down();
                    }
                }
            }
            // All transfers of this wave must land before its staging
            // ranges are reused by the next wave.
            latch.wait();
        }

        // Wait for nodes being extracted by peer extractors (pre-resolved
        // tickets: no shard locks on the wait path).
        self.fb.wait_plan(&plan);
        plan.aliases
    }

    /// Ablation: synchronous extraction — one blocking read + one blocking
    /// transfer per row on this thread (no overlap, no coalescing: the
    /// paper's D2 congestion mode must stay a faithful per-row baseline).
    fn extract_sync(&self, to_load: &[(u32, u32)]) {
        let row_bytes = self.staging.row_bytes;
        let mut buf = self.sync_scratch.lock().unwrap();
        buf.resize(row_bytes, 0);
        for &(node, slot) in to_load {
            let off = self.features.row_offset(node as u64);
            if self.opts.direct {
                self.backend.read_direct(&self.features.file, off, &mut buf);
            } else {
                self.backend.read_buffered(&self.features.file, off, &mut buf);
            }
            // Host target (CPU training) skips the PCIe hop: the row
            // decodes straight into the host-resident buffer.
            if let ExtractTarget::Device(pcie) = &self.target {
                pcie.transfer_sync(row_bytes);
            }
            self.fb.publish_le_bytes(node, slot, &buf);
        }
    }
}

/// Scatter a completed segment's rows into the feature buffer.
fn publish_rows(
    fb: &FeatureBuffer,
    rows: &[SegRow],
    staged: &crate::membuf::SlotRef,
    row_bytes: usize,
) {
    let bytes = staged.bytes();
    for r in rows {
        fb.publish_le_bytes(r.node, r.slot, &bytes[r.rel_off..r.rel_off + row_bytes]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Machine, MachineConfig};
    use crate::graph::{Dataset, DatasetSpec};
    use crate::sim::Clock;
    use crate::storage::DeviceMemory;

    fn setup() -> (Machine, Dataset, Arc<FeatureBuffer>) {
        let m = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &m).unwrap();
        let dev = DeviceMemory::new(8 << 20);
        let fb = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        (m, ds, fb)
    }

    fn extractor_with(
        m: &Machine,
        ds: &Dataset,
        fb: Arc<FeatureBuffer>,
        slots: usize,
        opts: ExtractOptions,
    ) -> Extractor {
        let staging =
            StagingBuffer::new(&m.host, slots, ds.features.row_bytes() as usize).unwrap();
        Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb,
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            opts,
        )
    }

    fn extractor(m: &Machine, ds: &Dataset, fb: Arc<FeatureBuffer>, slots: usize) -> Extractor {
        extractor_with(m, ds, fb, slots, ExtractOptions::default())
    }

    #[test]
    fn extracts_correct_rows() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = vec![5, 900, 33, 2999];
        let aliases = ex.extract(&nodes);
        assert!(aliases.iter().all(|&a| a >= 0));
        let mut out = vec![0f32; nodes.len() * ds.spec.dim];
        fb.gather(&aliases, &mut out);
        // Compare against the oracle generator.
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            ds.feature_gen.fill_row(v as u64, &mut want);
            let exp = crate::graph::FeatureGen::decode_row(&want);
            let got = &out[i * ds.spec.dim..(i + 1) * ds.spec.dim];
            assert_eq!(got, &exp[..], "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn waves_handle_batches_larger_than_staging() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 8); // tiny staging
        let nodes: Vec<u32> = (100..160).collect(); // 60 nodes, 8-slot staging
        let aliases = ex.extract(&nodes);
        assert_eq!(aliases.len(), 60);
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn coalescing_merges_requests_without_changing_rows() {
        // Same nodes, coalescing off vs on: identical extracted rows,
        // strictly fewer charged device requests, identical useful bytes.
        let (m, ds, _) = setup();
        let dev = DeviceMemory::new(8 << 20);
        let nodes: Vec<u32> = (200..264).collect(); // dense: 64-byte rows share sectors

        let fb_off = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        let ex_off = extractor_with(
            &m,
            &ds,
            fb_off.clone(),
            64,
            ExtractOptions { coalesce: CoalesceConfig::disabled(), ..Default::default() },
        );
        m.storage.ssd.reset_stats();
        let dio0 = m.backend.direct_stats().snapshot();
        let a_off = ex_off.extract(&nodes);
        let reads_off = m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed);
        let (useful_off, aligned_off) = {
            let (u, a) = m.backend.direct_stats().snapshot();
            (u - dio0.0, a - dio0.1)
        };

        let fb_on = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        let ex_on = extractor(&m, &ds, fb_on.clone(), 64);
        m.storage.ssd.reset_stats();
        let dio1 = m.backend.direct_stats().snapshot();
        let a_on = ex_on.extract(&nodes);
        let reads_on = m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed);
        let (useful_on, aligned_on) = {
            let (u, a) = m.backend.direct_stats().snapshot();
            (u - dio1.0, a - dio1.1)
        };

        assert_eq!(reads_off, 64, "baseline: one request per row");
        assert!(
            reads_on * 2 <= reads_off,
            "coalescing must at least halve charged requests: {reads_on} vs {reads_off}"
        );
        assert_eq!(useful_on, useful_off, "useful bytes independent of coalescing");
        assert!(
            aligned_on <= aligned_off,
            "dense rows must not amplify: {aligned_on} vs {aligned_off}"
        );

        let mut off_rows = vec![0f32; nodes.len() * ds.spec.dim];
        let mut on_rows = vec![0f32; nodes.len() * ds.spec.dim];
        fb_off.gather(&a_off, &mut off_rows);
        fb_on.gather(&a_on, &mut on_rows);
        assert_eq!(off_rows, on_rows, "extracted bytes must be identical");
        fb_on.check_invariants().unwrap();
    }

    #[test]
    fn second_extraction_reuses_buffer() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = (0..32).collect();
        ex.extract(&nodes);
        fb.release(&nodes);
        m.storage.ssd.reset_stats();
        let aliases = ex.extract(&nodes);
        // No SSD reads the second time.
        assert_eq!(
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        assert_eq!(aliases.len(), 32);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn alias_release_roundtrips_with_extraction() {
        // The engine's lifecycle: extract → gather → release_aliases (the
        // releaser never sees node ids). Slots must come back reusable and
        // a re-extraction must still hit.
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = (40..72).collect();
        let aliases = ex.extract(&nodes);
        let mut out = vec![0f32; nodes.len() * ds.spec.dim];
        fb.gather(&aliases, &mut out);
        fb.release_aliases(&aliases);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), fb.n_slots, "all references dropped");
        m.storage.ssd.reset_stats();
        let again = ex.extract(&nodes);
        assert_eq!(again, aliases, "released-by-alias rows stay resident");
        assert_eq!(
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "alias release must not evict resident rows"
        );
        fb.release_aliases(&again);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn direct_io_bypasses_page_cache() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb, 64);
        ex.extract(&(0..64).collect::<Vec<u32>>());
        // Feature extraction must not populate the page cache (D1 fix),
        // coalesced segments included.
        let feat_hits = m
            .storage
            .cache
            .stats()
            .features
            .hits
            .load(std::sync::atomic::Ordering::Relaxed);
        let feat_misses = m
            .storage
            .cache
            .stats()
            .features
            .misses
            .load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(feat_hits + feat_misses, 0, "feature reads went through page cache");
    }

    #[test]
    fn sync_mode_produces_identical_rows() {
        let (m, ds, fb) = setup();
        let staging =
            StagingBuffer::new(&m.host, 64, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb.clone(),
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            ExtractOptions { asynchronous: false, ..Default::default() },
        );
        let nodes: Vec<u32> = (10..42).collect();
        let aliases = ex.extract(&nodes);
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn sync_mode_host_target_publishes_without_pcie() {
        // The sync ablation must respect ExtractTarget::Host: rows publish
        // into the host buffer and the PCIe link stays untouched.
        let (m, ds, _) = setup();
        let host_fb = Arc::new(FeatureBuffer::in_host(&m.host, 256, ds.spec.dim).unwrap());
        let staging =
            StagingBuffer::new(&m.host, 32, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            32,
            staging,
            host_fb.clone(),
            ds.features.clone(),
            ExtractTarget::Host,
            ExtractOptions { asynchronous: false, ..Default::default() },
        );
        let pcie_before = m.pcie.transfer_count();
        let nodes: Vec<u32> = (7..23).collect();
        let aliases = ex.extract(&nodes);
        assert_eq!(m.pcie.transfer_count(), pcie_before, "Host target must skip PCIe");
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            host_fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        host_fb.check_invariants().unwrap();
    }

    #[test]
    fn sync_scratch_is_reused_across_calls() {
        let (m, ds, fb) = setup();
        let ex = extractor_with(
            &m,
            &ds,
            fb.clone(),
            64,
            ExtractOptions { asynchronous: false, ..Default::default() },
        );
        ex.extract(&(0..8).collect::<Vec<u32>>());
        let ptr1 = ex.sync_scratch.lock().unwrap().as_ptr();
        let cap1 = ex.sync_scratch.lock().unwrap().capacity();
        fb.release(&(0..8).collect::<Vec<u32>>());
        ex.extract(&(100..108).collect::<Vec<u32>>());
        let ptr2 = ex.sync_scratch.lock().unwrap().as_ptr();
        assert_eq!(ptr1, ptr2, "scratch buffer must not reallocate per call");
        assert_eq!(ex.sync_scratch.lock().unwrap().capacity(), cap1);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn buffered_mode_populates_page_cache() {
        let (m, ds, fb) = setup();
        let staging =
            StagingBuffer::new(&m.host, 64, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb,
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            ExtractOptions { asynchronous: true, direct: false, ..Default::default() },
        );
        m.storage.cache.stats().reset();
        ex.extract(&(0..32).collect::<Vec<u32>>());
        let touches = m
            .storage
            .cache
            .stats()
            .features
            .misses
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(touches > 0, "-direct ablation must go through the page cache");
    }

    #[test]
    fn concurrent_extractors_share_work() {
        let (m, ds, fb) = setup();
        let ex1 = Arc::new(extractor(&m, &ds, fb.clone(), 64));
        let ex2 = Arc::new(extractor(&m, &ds, fb.clone(), 64));
        let nodes: Vec<u32> = (0..48).collect();
        let (n1, n2) = (nodes.clone(), nodes.clone());
        let h1 = std::thread::spawn(move || ex1.extract(&n1));
        let h2 = std::thread::spawn(move || ex2.extract(&n2));
        let a1 = h1.join().unwrap();
        let a2 = h2.join().unwrap();
        assert_eq!(a1, a2, "both extractors must alias the same slots");
        let (_, _, _, loads) = fb.stats();
        assert_eq!(loads, 48, "each node loaded exactly once across extractors");
        fb.check_invariants().unwrap();
    }
}
