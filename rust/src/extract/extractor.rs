//! Asynchronous two-phase feature extraction (paper §4.2, Fig 5,
//! Algorithm 1).
//!
//! One extractor handles one mini-batch end to end, never blocking per
//! request: phase 1 submits every missing node's SSD→staging load to its
//! backend's async engine (direct I/O, large depth); phase 2 launches the
//! staging→device PCIe transfer of each node *as soon as its load
//! completes*, overlapping with outstanding loads; completion publishes the
//! node's valid bit in the feature buffer. Nodes already resident are
//! aliased (no I/O), nodes being extracted by peers are awaited at the end
//! (shared I/O).
//!
//! The extractor is backend-agnostic: it holds an [`IoBackend`] and drives
//! whatever [`AsyncIoEngine`] that backend mints (the sim io_uring, or the
//! OS-file `pread` pool), so the same pipeline runs against the simulator
//! and against real files. Completions land in lock-free staging-slot
//! handles ([`crate::membuf::SlotRef`]) — no mutex per row anywhere between
//! submit and publish.
//!
//! The returned alias list is the batch's currency downstream: the trainer
//! gathers rows by alias, and the releaser drops the references this
//! extraction took via [`FeatureBuffer::release_aliases`] — by slot index,
//! never re-resolving node ids — so the whole post-extraction lifecycle
//! stays off the coordinator's shard locks.

use crate::graph::FeatureTable;
use crate::membuf::{FeatureBuffer, StagingBuffer};
use crate::sim::Latch;
use crate::storage::api::{AsyncIoEngine, IoBackend, IoMode, Sqe};
use crate::storage::Pcie;
use std::sync::Arc;

/// Where extracted rows land (§4.4 "CPU-based Training" skips the PCIe hop).
pub enum ExtractTarget {
    /// GPU training: staging → device via asynchronous PCIe transfers.
    Device(Arc<Pcie>),
    /// CPU training: rows go straight from staging into the host-resident
    /// feature buffer.
    Host,
}

/// Ablation switches (paper mechanisms turned off individually).
#[derive(Clone, Copy, Debug)]
pub struct ExtractOptions {
    /// false → synchronous per-row reads on the extractor thread (the
    /// paper's D2 congestion mode; `-async` ablation).
    pub asynchronous: bool,
    /// false → feature reads go through the OS page cache (the paper's D1
    /// contention mode; `-direct` ablation).
    pub direct: bool,
}

impl Default for ExtractOptions {
    fn default() -> Self {
        ExtractOptions { asynchronous: true, direct: true }
    }
}

pub struct Extractor {
    engine: Box<dyn AsyncIoEngine>,
    staging: StagingBuffer,
    fb: Arc<FeatureBuffer>,
    features: FeatureTable,
    target: ExtractTarget,
    backend: Arc<dyn IoBackend>,
    opts: ExtractOptions,
}

impl Extractor {
    pub fn new(
        backend: Arc<dyn IoBackend>,
        io_depth: usize,
        staging: StagingBuffer,
        fb: Arc<FeatureBuffer>,
        features: FeatureTable,
        target: ExtractTarget,
    ) -> Self {
        Self::with_options(backend, io_depth, staging, fb, features, target, ExtractOptions::default())
    }

    pub fn with_options(
        backend: Arc<dyn IoBackend>,
        io_depth: usize,
        staging: StagingBuffer,
        fb: Arc<FeatureBuffer>,
        features: FeatureTable,
        target: ExtractTarget,
        opts: ExtractOptions,
    ) -> Self {
        Extractor {
            engine: backend.clone().async_engine(io_depth),
            staging,
            fb,
            features,
            target,
            backend,
            opts,
        }
    }

    /// Extract the feature rows of `nodes` into the feature buffer; returns
    /// the node alias list (slot per node) for the trainer.
    ///
    /// Loads exceeding the staging capacity are processed in waves — the
    /// staging buffer is intentionally small (bounded memory footprint), and
    /// a wave still keeps `staging.slots()` requests in flight.
    pub fn extract(&self, nodes: &[u32]) -> Vec<i32> {
        let plan = self.fb.begin_batch(nodes);
        let row_bytes = self.staging.row_bytes;

        if !self.opts.asynchronous {
            // Ablation: synchronous extraction — one blocking read + one
            // blocking transfer per row on this thread (no overlap).
            let mut buf = vec![0u8; row_bytes];
            for &(node, slot) in &plan.to_load {
                let off = self.features.row_offset(node as u64);
                if self.opts.direct {
                    self.backend.read_direct(&self.features.file, off, &mut buf);
                } else {
                    self.backend.read_buffered(&self.features.file, off, &mut buf);
                }
                if let ExtractTarget::Device(pcie) = &self.target {
                    pcie.transfer_sync(row_bytes);
                }
                self.fb.publish_le_bytes(node, slot, &buf);
            }
            self.fb.wait_plan(&plan);
            return plan.aliases;
        }

        let mode = if self.opts.direct { IoMode::Direct } else { IoMode::Buffered };
        for wave in plan.to_load.chunks(self.staging.slots()) {
            let latch = Arc::new(Latch::new(wave.len()));
            // Phase 1: submit all loads asynchronously. Each wave request
            // owns staging slot `i` exclusively until its CQE is harvested
            // below (the SlotRef protocol); the wave-end latch keeps the
            // next wave from reusing slots before transfers land.
            let sqes: Vec<Sqe> = wave
                .iter()
                .enumerate()
                .map(|(i, &(node, _slot))| Sqe {
                    file: self.features.file.clone(),
                    offset: self.features.row_offset(node as u64),
                    len: row_bytes,
                    dst: self.staging.slot(i),
                    dst_off: 0,
                    user_data: i as u64,
                    mode,
                })
                .collect();
            self.engine.submit_batch(sqes);

            // Phase 2: as each load completes, launch its transfer without
            // waiting for the remaining loads.
            for _ in 0..wave.len() {
                let cqe = self.engine.wait_cqe();
                let i = cqe.user_data as usize;
                let (node, slot) = wave[i];
                let staged = self.staging.slot(i);
                match &self.target {
                    ExtractTarget::Device(pcie) => {
                        let fb = self.fb.clone();
                        let latch = latch.clone();
                        pcie.transfer_async(row_bytes, move || {
                            // Decode straight from the staging bytes into
                            // the arena row — no intermediate Vec<f32>, no
                            // slot lock.
                            fb.publish_le_bytes(node, slot, staged.bytes());
                            latch.count_down();
                        });
                    }
                    ExtractTarget::Host => {
                        self.fb.publish_le_bytes(node, slot, staged.bytes());
                        latch.count_down();
                    }
                }
            }
            // All transfers of this wave must land before its staging slots
            // are reused by the next wave.
            latch.wait();
        }

        // Wait for nodes being extracted by peer extractors (pre-resolved
        // tickets: no shard locks on the wait path).
        self.fb.wait_plan(&plan);
        plan.aliases
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Machine, MachineConfig};
    use crate::graph::{Dataset, DatasetSpec};
    use crate::sim::Clock;
    use crate::storage::DeviceMemory;

    fn setup() -> (Machine, Dataset, Arc<FeatureBuffer>) {
        let m = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &m).unwrap();
        let dev = DeviceMemory::new(8 << 20);
        let fb = Arc::new(FeatureBuffer::in_device(&dev, 512, ds.spec.dim).unwrap());
        (m, ds, fb)
    }

    fn extractor(m: &Machine, ds: &Dataset, fb: Arc<FeatureBuffer>, slots: usize) -> Extractor {
        let staging =
            StagingBuffer::new(&m.host, slots, ds.features.row_bytes() as usize).unwrap();
        Extractor::new(
            m.backend.clone(),
            64,
            staging,
            fb,
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
        )
    }

    #[test]
    fn extracts_correct_rows() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = vec![5, 900, 33, 2999];
        let aliases = ex.extract(&nodes);
        assert!(aliases.iter().all(|&a| a >= 0));
        let mut out = vec![0f32; nodes.len() * ds.spec.dim];
        fb.gather(&aliases, &mut out);
        // Compare against the oracle generator.
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            ds.feature_gen.fill_row(v as u64, &mut want);
            let exp = crate::graph::FeatureGen::decode_row(&want);
            let got = &out[i * ds.spec.dim..(i + 1) * ds.spec.dim];
            assert_eq!(got, &exp[..], "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn waves_handle_batches_larger_than_staging() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 8); // tiny staging
        let nodes: Vec<u32> = (100..160).collect(); // 60 nodes, 8-slot staging
        let aliases = ex.extract(&nodes);
        assert_eq!(aliases.len(), 60);
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn second_extraction_reuses_buffer() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = (0..32).collect();
        ex.extract(&nodes);
        fb.release(&nodes);
        m.storage.ssd.reset_stats();
        let aliases = ex.extract(&nodes);
        // No SSD reads the second time.
        assert_eq!(
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        assert_eq!(aliases.len(), 32);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn alias_release_roundtrips_with_extraction() {
        // The engine's lifecycle: extract → gather → release_aliases (the
        // releaser never sees node ids). Slots must come back reusable and
        // a re-extraction must still hit.
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb.clone(), 64);
        let nodes: Vec<u32> = (40..72).collect();
        let aliases = ex.extract(&nodes);
        let mut out = vec![0f32; nodes.len() * ds.spec.dim];
        fb.gather(&aliases, &mut out);
        fb.release_aliases(&aliases);
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), fb.n_slots, "all references dropped");
        m.storage.ssd.reset_stats();
        let again = ex.extract(&nodes);
        assert_eq!(again, aliases, "released-by-alias rows stay resident");
        assert_eq!(
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "alias release must not evict resident rows"
        );
        fb.release_aliases(&again);
        fb.check_invariants().unwrap();
    }

    #[test]
    fn direct_io_bypasses_page_cache() {
        let (m, ds, fb) = setup();
        let ex = extractor(&m, &ds, fb, 64);
        ex.extract(&(0..64).collect::<Vec<u32>>());
        // Feature extraction must not populate the page cache (D1 fix).
        let feat_hits = m
            .storage
            .cache
            .stats()
            .features
            .hits
            .load(std::sync::atomic::Ordering::Relaxed);
        let feat_misses = m
            .storage
            .cache
            .stats()
            .features
            .misses
            .load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(feat_hits + feat_misses, 0, "feature reads went through page cache");
    }

    #[test]
    fn sync_mode_produces_identical_rows() {
        let (m, ds, fb) = setup();
        let staging =
            StagingBuffer::new(&m.host, 64, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb.clone(),
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            ExtractOptions { asynchronous: false, direct: true },
        );
        let nodes: Vec<u32> = (10..42).collect();
        let aliases = ex.extract(&nodes);
        let mut out = vec![0f32; ds.spec.dim];
        let mut want = vec![0u8; ds.spec.dim * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            ds.feature_gen.fill_row(v as u64, &mut want);
            assert_eq!(out, crate::graph::FeatureGen::decode_row(&want), "node {v}");
        }
        fb.check_invariants().unwrap();
    }

    #[test]
    fn buffered_mode_populates_page_cache() {
        let (m, ds, fb) = setup();
        let staging =
            StagingBuffer::new(&m.host, 64, ds.features.row_bytes() as usize).unwrap();
        let ex = Extractor::with_options(
            m.backend.clone(),
            64,
            staging,
            fb,
            ds.features.clone(),
            ExtractTarget::Device(m.pcie.clone()),
            ExtractOptions { asynchronous: true, direct: false },
        );
        m.storage.cache.stats().reset();
        ex.extract(&(0..32).collect::<Vec<u32>>());
        let touches = m
            .storage
            .cache
            .stats()
            .features
            .misses
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(touches > 0, "-direct ablation must go through the page cache");
    }

    #[test]
    fn concurrent_extractors_share_work() {
        let (m, ds, fb) = setup();
        let ex1 = Arc::new(extractor(&m, &ds, fb.clone(), 64));
        let ex2 = Arc::new(extractor(&m, &ds, fb.clone(), 64));
        let nodes: Vec<u32> = (0..48).collect();
        let (n1, n2) = (nodes.clone(), nodes.clone());
        let h1 = std::thread::spawn(move || ex1.extract(&n1));
        let h2 = std::thread::spawn(move || ex2.extract(&n2));
        let a1 = h1.join().unwrap();
        let a2 = h2.join().unwrap();
        assert_eq!(a1, a2, "both extractors must alias the same slots");
        let (_, _, _, loads) = fb.stats();
        assert_eq!(loads, 48, "each node loaded exactly once across extractors");
        fb.check_invariants().unwrap();
    }
}
