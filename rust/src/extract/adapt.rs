//! Adaptive coalescing governor: closes the feedback loop over the
//! per-device utilization counters PR 7 introduced.
//!
//! The static `--coalesce-gap`/`--coalesce-bytes` trade-off is
//! workload-dependent: on an IOPS-bound device, bridging wider gaps turns
//! many small charged requests into fewer large ones (good); on a
//! bandwidth-bound device the bridged gap bytes *are* the bottleneck and
//! narrower merges win. The [`CoalesceGovernor`] retunes the *effective*
//! per-device [`CoalesceConfig`] once per epoch from three observed
//! signals, all already collected by the storage stack:
//!
//! * **IOPS headroom** — charged requests/s vs the device model's ceiling
//!   ([`crate::storage::SsdConfig::iops`]);
//! * **bandwidth headroom** — charged bytes/s vs `read_bw`;
//! * **queue pressure** — the engine's per-device in-flight high-water mark
//!   vs `--io-depth` ([`crate::storage::AsyncIoEngine::queue_highwater`]).
//!
//! The policy is deliberately a monotone ratchet, not a model: congestion
//! signals only ever *widen* merging, abundant slack only ever *narrows* it
//! back toward the base config, and each epoch moves one power of two at
//! most — so the governor cannot oscillate within an epoch and its charged
//! request count stays within a small factor of the best static setting
//! (`benches/uring_engine.rs` gates the 10% bound of ISSUE 9).
//!
//! **Pinning.** Explicitly passed `--coalesce-gap`/`--coalesce-bytes` CLI
//! values pin the governor off: the user's setting is the experiment, and
//! an adaptive layer silently rewriting it would poison ablations. The
//! pipeline constructs the governor with `pinned = true` whenever either
//! flag was given explicitly (see `main.rs`).

use crate::extract::coalesce::CoalesceConfig;

/// One device's utilization observation for one epoch, all fractions in
/// `[0, 1]`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeviceIoObservation {
    /// Unused fraction of the device's IOPS ceiling (1.0 = idle, 0.0 =
    /// request-rate saturated).
    pub iops_headroom: f64,
    /// Unused fraction of the device's read bandwidth.
    pub bw_headroom: f64,
    /// Engine queue pressure: per-device in-flight high-water mark over
    /// `--io-depth`.
    pub queue_frac: f64,
}

impl DeviceIoObservation {
    /// Clamp-from-raw helper: `ops`/`bytes` charged over `secs` against the
    /// device's `iops`/`read_bw` ceilings, `highwater` against `depth`.
    pub fn from_charges(
        ops: u64,
        bytes: u64,
        secs: f64,
        iops_ceiling: f64,
        bw_ceiling: f64,
        highwater: u64,
        depth: usize,
    ) -> Self {
        let secs = secs.max(1e-9);
        let used_iops = ops as f64 / secs;
        let used_bw = bytes as f64 / secs;
        let frac = |used: f64, ceil: f64| {
            if ceil <= 0.0 {
                1.0 // no ceiling known: report full headroom
            } else {
                (1.0 - used / ceil).clamp(0.0, 1.0)
            }
        };
        DeviceIoObservation {
            iops_headroom: frac(used_iops, iops_ceiling),
            bw_headroom: frac(used_bw, bw_ceiling),
            queue_frac: if depth == 0 {
                0.0
            } else {
                (highwater as f64 / depth as f64).clamp(0.0, 1.0)
            },
        }
    }
}

/// Below this headroom fraction a resource counts as saturated.
const SATURATED: f64 = 0.15;
/// Above this headroom fraction a resource counts as having ample slack.
const AMPLE: f64 = 0.50;
/// Queue high-water fraction above which the submission path is congested.
const QUEUE_HOT: f64 = 0.75;
/// Widest the governor will stretch either knob, as a multiple of base.
const MAX_WIDEN: usize = 8;

/// Per-device adaptive tuner of the effective coalescing config. See the
/// module docs for the policy; the public surface is deliberately small:
/// feed one [`DeviceIoObservation`] slice per epoch, read per-device
/// configs when planning.
#[derive(Debug)]
pub struct CoalesceGovernor {
    base: CoalesceConfig,
    pinned: bool,
    per_dev: Vec<CoalesceConfig>,
}

impl CoalesceGovernor {
    /// Governor over `devices` devices starting from `base`. `pinned`
    /// freezes every device at `base` forever (explicit CLI values).
    pub fn new(base: CoalesceConfig, devices: usize, pinned: bool) -> Self {
        let devices = devices.max(1);
        CoalesceGovernor { base, pinned, per_dev: vec![base; devices] }
    }

    pub fn pinned(&self) -> bool {
        self.pinned
    }

    pub fn base(&self) -> CoalesceConfig {
        self.base
    }

    /// Effective config for `dev` (device indices past the observed set
    /// clamp to the last device, mirroring engine routing).
    pub fn config_for(&self, dev: usize) -> CoalesceConfig {
        self.per_dev[dev.min(self.per_dev.len() - 1)]
    }

    /// All effective per-device configs.
    pub fn configs(&self) -> &[CoalesceConfig] {
        &self.per_dev
    }

    /// Whether any device currently deviates from the base config.
    pub fn adapted(&self) -> bool {
        self.per_dev.iter().any(|c| *c != self.base)
    }

    /// Fold one epoch's observations in. Devices beyond `obs.len()` keep
    /// their config; a pinned or coalescing-disabled governor never moves.
    pub fn observe_epoch(&mut self, obs: &[DeviceIoObservation]) {
        if self.pinned || !self.base.enabled() {
            return;
        }
        for (dev, o) in obs.iter().enumerate().take(self.per_dev.len()) {
            let cur = &mut self.per_dev[dev];
            let iops_bound = o.iops_headroom < SATURATED;
            let queue_hot = o.queue_frac > QUEUE_HOT;
            let bw_bound = o.bw_headroom < SATURATED;
            if (iops_bound || queue_hot) && !bw_bound {
                // Request-rate congested with bandwidth to spare: bridge
                // wider gaps so more rows share one charged request.
                cur.gap_bytes = (cur.gap_bytes.max(1) * 2).min(self.base.gap_bytes * MAX_WIDEN);
                cur.max_bytes = (cur.max_bytes * 2).min(self.base.max_bytes * MAX_WIDEN);
            } else if bw_bound && o.iops_headroom > AMPLE {
                // Wire-bound with request slack: stop paying bridged gap
                // bytes, fall back toward the base merge width.
                cur.gap_bytes = (cur.gap_bytes / 2).max(self.base.gap_bytes);
                cur.max_bytes = (cur.max_bytes / 2).max(self.base.max_bytes);
            }
            // Otherwise: hold. Ambiguous epochs must not walk the config.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> CoalesceConfig {
        CoalesceConfig::default()
    }

    fn idle() -> DeviceIoObservation {
        DeviceIoObservation { iops_headroom: 1.0, bw_headroom: 1.0, queue_frac: 0.0 }
    }

    fn iops_storm() -> DeviceIoObservation {
        DeviceIoObservation { iops_headroom: 0.05, bw_headroom: 0.9, queue_frac: 0.9 }
    }

    fn bw_storm() -> DeviceIoObservation {
        DeviceIoObservation { iops_headroom: 0.9, bw_headroom: 0.05, queue_frac: 0.3 }
    }

    #[test]
    fn widens_monotonically_under_iops_pressure() {
        let mut gov = CoalesceGovernor::new(base(), 1, false);
        let mut prev = gov.config_for(0);
        for epoch in 0..6 {
            gov.observe_epoch(&[iops_storm()]);
            let cur = gov.config_for(0);
            assert!(
                cur.gap_bytes >= prev.gap_bytes && cur.max_bytes >= prev.max_bytes,
                "epoch {epoch}: shrank under sustained congestion: {prev:?} -> {cur:?}"
            );
            prev = cur;
        }
        // Saturates at the cap instead of growing forever.
        assert_eq!(prev.gap_bytes, base().gap_bytes * 8);
        assert_eq!(prev.max_bytes, base().max_bytes * 8);
        assert!(gov.adapted());
    }

    #[test]
    fn narrows_back_under_bandwidth_pressure_but_never_below_base() {
        let mut gov = CoalesceGovernor::new(base(), 1, false);
        for _ in 0..3 {
            gov.observe_epoch(&[iops_storm()]);
        }
        assert!(gov.config_for(0).gap_bytes > base().gap_bytes);
        for _ in 0..10 {
            gov.observe_epoch(&[bw_storm()]);
        }
        assert_eq!(gov.config_for(0), base(), "must floor at the base config");
        assert!(!gov.adapted());
    }

    #[test]
    fn idle_and_ambiguous_epochs_hold() {
        let mut gov = CoalesceGovernor::new(base(), 1, false);
        gov.observe_epoch(&[idle()]);
        assert_eq!(gov.config_for(0), base());
        // Both-bound (iops AND bw saturated) is ambiguous: hold.
        gov.observe_epoch(&[DeviceIoObservation {
            iops_headroom: 0.05,
            bw_headroom: 0.05,
            queue_frac: 0.9,
        }]);
        assert_eq!(gov.config_for(0), base());
    }

    #[test]
    fn pinned_governor_never_moves() {
        let mut gov = CoalesceGovernor::new(base(), 2, true);
        for _ in 0..8 {
            gov.observe_epoch(&[iops_storm(), bw_storm()]);
        }
        assert_eq!(gov.config_for(0), base());
        assert_eq!(gov.config_for(1), base());
        assert!(gov.pinned());
        assert!(!gov.adapted());
    }

    #[test]
    fn disabled_coalescing_never_moves() {
        let mut gov = CoalesceGovernor::new(CoalesceConfig::disabled(), 1, false);
        gov.observe_epoch(&[iops_storm()]);
        assert_eq!(gov.config_for(0), CoalesceConfig::disabled());
    }

    #[test]
    fn devices_adapt_independently() {
        let mut gov = CoalesceGovernor::new(base(), 3, false);
        gov.observe_epoch(&[iops_storm(), idle(), iops_storm()]);
        assert!(gov.config_for(0).gap_bytes > base().gap_bytes);
        assert_eq!(gov.config_for(1), base());
        assert!(gov.config_for(2).gap_bytes > base().gap_bytes);
        // Out-of-range device clamps to the last (engine routing rule).
        assert_eq!(gov.config_for(99), gov.config_for(2));
    }

    #[test]
    fn observation_from_charges_clamps() {
        let o = DeviceIoObservation::from_charges(
            97_000, // exactly the pm883 IOPS ceiling over 1s
            520_000_000,
            1.0,
            97_000.0,
            520e6,
            12,
            16,
        );
        assert!(o.iops_headroom.abs() < 1e-9);
        assert!(o.bw_headroom.abs() < 1e-9);
        assert!((o.queue_frac - 0.75).abs() < 1e-9);
        // Over-ceiling usage clamps to zero headroom, not negative.
        let o = DeviceIoObservation::from_charges(1000, 1000, 1e-12, 10.0, 10.0, 99, 16);
        assert_eq!(o.iops_headroom, 0.0);
        assert_eq!(o.bw_headroom, 0.0);
        assert_eq!(o.queue_frac, 1.0);
    }
}
