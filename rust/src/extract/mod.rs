//! Extract stage: asynchronous two-phase feature extraction (Algorithm 1)
//! over coalesced multi-row segments (§4.4), with per-epoch adaptive
//! coalescing ([`adapt`]) and hedged reissue of straggler segments.

pub mod adapt;
pub mod coalesce;
pub mod extractor;

pub use adapt::{CoalesceGovernor, DeviceIoObservation};
pub use coalesce::{
    plan_segments, plan_segments_striped_adaptive, CoalesceConfig, SegRow, Segment,
};
pub use extractor::{ExtractError, ExtractOptions, ExtractTarget, Extractor, HedgeConfig};
