//! Extract stage: asynchronous two-phase feature extraction (Algorithm 1)
//! over coalesced multi-row segments (§4.4).

pub mod coalesce;
pub mod extractor;

pub use coalesce::{plan_segments, CoalesceConfig, SegRow, Segment};
pub use extractor::{ExtractError, ExtractOptions, ExtractTarget, Extractor};
