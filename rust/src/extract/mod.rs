//! Extract stage: asynchronous two-phase feature extraction (Algorithm 1).

pub mod extractor;

pub use extractor::{ExtractOptions, ExtractTarget, Extractor};
