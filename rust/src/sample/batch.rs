//! Mini-batch scheduling: shuffle the train split each epoch, chunk into
//! mini-batches, and hand them to samplers via a shared cursor (multiple
//! sampler threads claim batches concurrently; completion order is then
//! naturally out-of-order — the paper's mini-batch reordering, §4.3).

use crate::util::rng::Pcg;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One epoch's batch plan.
#[derive(Debug)]
pub struct EpochPlan {
    batches: Vec<Vec<u32>>,
    cursor: AtomicUsize,
}

impl EpochPlan {
    /// Shuffle `train_ids` with (seed, epoch) and chunk into `batch_size`
    /// pieces; `cap` optionally limits the number of batches (quick benches).
    pub fn new(
        train_ids: &[u32],
        batch_size: usize,
        seed: u64,
        epoch: u64,
        cap: Option<usize>,
    ) -> Self {
        let mut ids = train_ids.to_vec();
        let mut rng = Pcg::with_stream(seed ^ 0xE90C4, epoch);
        rng.shuffle(&mut ids);
        let mut batches: Vec<Vec<u32>> =
            ids.chunks(batch_size.max(1)).map(|c| c.to_vec()).collect();
        if let Some(cap) = cap {
            batches.truncate(cap);
        }
        EpochPlan { batches, cursor: AtomicUsize::new(0) }
    }

    pub fn len(&self) -> usize {
        self.batches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }

    /// Total seed nodes across all planned batches.
    pub fn total_seeds(&self) -> usize {
        self.batches.iter().map(Vec::len).sum()
    }

    /// Claim the next batch (thread-safe; each batch handed out once).
    pub fn claim(&self) -> Option<(u64, &[u32])> {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.batches.get(i).map(|b| (i as u64, b.as_slice()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn chunks_cover_all_ids_exactly_once() {
        let ids: Vec<u32> = (0..105).collect();
        let plan = EpochPlan::new(&ids, 10, 1, 0, None);
        assert_eq!(plan.len(), 11);
        assert_eq!(plan.total_seeds(), 105);
        let mut seen = HashSet::new();
        while let Some((_, b)) = plan.claim() {
            for &v in b {
                assert!(seen.insert(v), "dup {v}");
            }
        }
        assert_eq!(seen.len(), 105);
    }

    #[test]
    fn shuffle_differs_per_epoch_but_is_deterministic() {
        let ids: Vec<u32> = (0..50).collect();
        let a = EpochPlan::new(&ids, 50, 7, 0, None);
        let b = EpochPlan::new(&ids, 50, 7, 0, None);
        let c = EpochPlan::new(&ids, 50, 7, 1, None);
        let (_, ba) = a.claim().unwrap();
        let (_, bb) = b.claim().unwrap();
        let (_, bc) = c.claim().unwrap();
        assert_eq!(ba, bb);
        assert_ne!(ba, bc);
    }

    #[test]
    fn cap_limits_batches() {
        let ids: Vec<u32> = (0..100).collect();
        let plan = EpochPlan::new(&ids, 10, 1, 0, Some(3));
        assert_eq!(plan.len(), 3);
    }

    #[test]
    fn concurrent_claims_are_disjoint() {
        let ids: Vec<u32> = (0..1000).collect();
        let plan = Arc::new(EpochPlan::new(&ids, 10, 1, 0, None));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let plan = plan.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some((id, _)) = plan.claim() {
                        got.push(id);
                    }
                    got
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<u64>>());
    }
}
