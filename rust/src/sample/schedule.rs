//! Replayable sampling schedule: one value that pins everything the batch
//! sequence depends on — seed, batch size, fanouts, per-epoch cap — so two
//! independent consumers (the online training pipeline and the offline
//! `layout/` pre-sampler) derive *bit-identical* batches and sampled node
//! sets for every (epoch, batch_id).
//!
//! Determinism contract: `plan` shuffles with `Pcg::with_stream(seed ^ …,
//! epoch)` and `sampler` draws with `Pcg::with_stream(sampler_seed ^ …,
//! batch_id)`, so results depend only on (schedule, epoch, batch_id) — never
//! on which thread claims a batch or in what order batches complete. The
//! packed-layout handshake (`layout::PackedLayout`) verifies a dataset's
//! recorded schedule against the one the trainer is about to run.

use super::batch::EpochPlan;
use super::sampler::Sampler;

/// Everything the deterministic batch sequence depends on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScheduleSpec {
    pub seed: u64,
    pub batch_size: usize,
    pub fanouts: Vec<usize>,
    /// Optional cap on batches per epoch (quick runs / benches); `None`
    /// covers the whole shuffled train split.
    pub batches_per_epoch: Option<usize>,
}

impl ScheduleSpec {
    /// The epoch's batch plan — the exact shuffle + chunking the pipeline
    /// engine runs (`EpochPlan::new` with this spec's knobs).
    pub fn plan(&self, train_ids: &[u32], epoch: u64) -> EpochPlan {
        EpochPlan::new(train_ids, self.batch_size, self.seed, epoch, self.batches_per_epoch)
    }

    /// The epoch's sampler. Seeding matches the pipeline engine
    /// (`seed ^ (epoch << 8)`), and sampling itself is keyed per batch_id,
    /// so one sampler replayed serially equals N samplers racing over the
    /// shared cursor.
    pub fn sampler(&self, epoch: u64) -> Sampler {
        Sampler::new(self.fanouts.clone(), self.seed ^ (epoch << 8))
    }

    /// Fanouts in the canonical `meta.toml` form (`"10,10,10"`).
    pub fn fanouts_str(&self) -> String {
        self.fanouts.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ScheduleSpec {
        ScheduleSpec { seed: 17, batch_size: 8, fanouts: vec![4, 4], batches_per_epoch: Some(3) }
    }

    #[test]
    fn plan_matches_direct_epoch_plan() {
        let ids: Vec<u32> = (0..100).collect();
        let a = spec().plan(&ids, 2);
        let b = EpochPlan::new(&ids, 8, 17, 2, Some(3));
        assert_eq!(a.len(), b.len());
        while let (Some((ia, ba)), Some((ib, bb))) = (a.claim(), b.claim()) {
            assert_eq!(ia, ib);
            assert_eq!(ba, bb);
        }
    }

    #[test]
    fn sampler_seed_matches_engine_rule() {
        let s = spec().sampler(3);
        assert_eq!(s.seed, 17 ^ (3u64 << 8));
        assert_eq!(s.fanouts, vec![4, 4]);
    }

    #[test]
    fn fanouts_str_roundtrips() {
        assert_eq!(spec().fanouts_str(), "4,4");
    }
}
