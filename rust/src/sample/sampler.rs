//! k-hop uniform neighbor sampling over disk-resident topology.
//!
//! This is the paper's *sample* stage: for each expansion level, every node
//! in the current prefix reads its in-neighbor list from SSD (through the
//! page cache — the I/O that memory contention slows down) and uniformly
//! samples up to `fanout` of them without replacement. Results are
//! deduplicated into the prefix-ordered node list of
//! [`SampledSubgraph`](super::subgraph::SampledSubgraph).

use super::subgraph::{LayerAdj, SampledSubgraph};
use crate::graph::Dataset;
use crate::storage::IoBackend;
use crate::util::fxhash::FxHashMap;
use crate::util::rng::Pcg;
use std::cell::RefCell;

/// Sampling policy. Uniform is the paper's default; `Full` takes every
/// neighbor up to the fanout cap deterministically (tests, ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplePolicy {
    Uniform,
    Full,
}

/// Per-sampler scratch reused across `sample_batch` calls: the dedup map,
/// neighbor list, and disk-read byte buffer were reallocated per batch (at
/// thousands of batches per epoch), and the dedup map dominates. Vectors
/// that are moved into the returned subgraph (`nodes`, per-level `idx`)
/// can't be reused, but their initial capacity follows the high-water mark
/// of previous batches so they allocate once instead of growing.
#[derive(Clone, Default)]
struct SampleScratch {
    pos: FxHashMap<u32, i32>,
    nbrs: Vec<u32>,
    bytes: Vec<u8>,
    /// Largest node count any batch produced (capacity hint).
    nodes_hint: usize,
}

#[derive(Clone)]
pub struct Sampler {
    pub fanouts: Vec<usize>,
    pub policy: SamplePolicy,
    pub seed: u64,
    /// Nodes whose adjacency lists are held in an in-memory neighbor cache
    /// (Ginex §2): reading them charges no device time.
    pub topo_cache: Option<std::sync::Arc<std::collections::HashSet<u32>>>,
    /// Interior mutability keeps `sample_batch(&self)` — samplers are
    /// per-thread (moved into their worker), never shared by reference.
    scratch: RefCell<SampleScratch>,
}

impl Sampler {
    pub fn new(fanouts: Vec<usize>, seed: u64) -> Self {
        Sampler {
            fanouts,
            policy: SamplePolicy::Uniform,
            seed,
            topo_cache: None,
            scratch: RefCell::new(SampleScratch::default()),
        }
    }

    pub fn with_topo_cache(
        mut self,
        cache: std::sync::Arc<std::collections::HashSet<u32>>,
    ) -> Self {
        self.topo_cache = Some(cache);
        self
    }

    /// Sample the k-hop subgraph for one mini-batch of seed nodes.
    /// Deterministic in (sampler seed, batch_id).
    pub fn sample_batch(
        &self,
        ds: &Dataset,
        io: &dyn IoBackend,
        batch_id: u64,
        seeds: &[u32],
    ) -> SampledSubgraph {
        let _busy = crate::metrics::state::enter(crate::metrics::state::State::Busy);
        let mut rng = Pcg::with_stream(self.seed ^ 0x5A17, batch_id);
        let mut scr = self.scratch.borrow_mut();
        let SampleScratch { pos, nbrs, bytes: scratch, nodes_hint } = &mut *scr;
        pos.clear();
        pos.reserve(seeds.len() * 8); // no-op once warm
        let mut nodes: Vec<u32> = Vec::with_capacity((*nodes_hint).max(seeds.len() * 8));
        for &s in seeds {
            if pos.insert(s, nodes.len() as i32).is_none() {
                nodes.push(s);
            }
        }
        let mut cum = vec![nodes.len()];
        let mut adjs = Vec::with_capacity(self.fanouts.len());

        for &fanout in &self.fanouts {
            let dst_count = *cum.last().unwrap();
            let mut idx = vec![-1i32; dst_count * fanout];
            for d in 0..dst_count {
                let v = nodes[d];
                nbrs.clear();
                match &self.topo_cache {
                    Some(cache) if cache.contains(&v) => {
                        ds.graph.neighbors_into_nocharge(v, nbrs)
                    }
                    _ => ds.graph.neighbors_into_scratch(io, v, nbrs, scratch),
                }
                let deg = nbrs.len();
                if deg == 0 {
                    continue;
                }
                let take = fanout.min(deg);
                // Partial Fisher–Yates: uniform sample without replacement.
                if self.policy == SamplePolicy::Uniform && deg > take {
                    for i in 0..take {
                        let j = rng.range(i, deg);
                        nbrs.swap(i, j);
                    }
                }
                for (f, &src) in nbrs.iter().take(take).enumerate() {
                    let local = match pos.get(&src) {
                        Some(&l) => l,
                        None => {
                            let l = nodes.len() as i32;
                            pos.insert(src, l);
                            nodes.push(src);
                            l
                        }
                    };
                    idx[d * fanout + f] = local;
                }
            }
            adjs.push(LayerAdj { fanout, idx });
            cum.push(nodes.len());
        }

        *nodes_hint = (*nodes_hint).max(nodes.len());
        let labels = seeds.iter().map(|&s| ds.labels[s as usize]).collect();
        SampledSubgraph { batch_id, nodes, cum, adjs, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Machine, MachineConfig};
    use crate::graph::DatasetSpec;
    use crate::sim::Clock;
    use crate::util::prop;

    fn setup() -> (Machine, Dataset) {
        let m = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &m).unwrap();
        (m, ds)
    }

    #[test]
    fn sample_has_valid_structure() {
        let (m, ds) = setup();
        let sampler = Sampler::new(vec![5, 5], 1);
        let seeds: Vec<u32> = ds.train_ids.iter().take(32).copied().collect();
        let sub = sampler.sample_batch(&ds, &m.storage, 0, &seeds);
        sub.check_invariants().unwrap();
        assert_eq!(sub.seeds(), &seeds[..]);
        assert_eq!(sub.levels(), 2);
        // Expansion actually expanded.
        assert!(sub.cum[1] > sub.cum[0]);
        assert!(sub.nodes.len() >= sub.cum[1]);
        // Labels match the dataset.
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(sub.labels[i], ds.labels[s as usize]);
        }
    }

    #[test]
    fn deterministic_per_seed_and_batch() {
        let (m, ds) = setup();
        let sampler = Sampler::new(vec![4, 4], 7);
        let seeds: Vec<u32> = ds.train_ids.iter().take(16).copied().collect();
        let a = sampler.sample_batch(&ds, &m.storage, 3, &seeds);
        let b = sampler.sample_batch(&ds, &m.storage, 3, &seeds);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.adjs[0].idx, b.adjs[0].idx);
        let c = sampler.sample_batch(&ds, &m.storage, 4, &seeds);
        assert_ne!(a.adjs[0].idx, c.adjs[0].idx); // different batch → different draw
    }

    #[test]
    fn full_policy_takes_prefix_of_neighbors() {
        let (m, ds) = setup();
        let mut sampler = Sampler::new(vec![3], 1);
        sampler.policy = SamplePolicy::Full;
        let seeds = vec![ds.train_ids[0]];
        let sub = sampler.sample_batch(&ds, &m.storage, 0, &seeds);
        let nbrs = ds.graph.neighbors(&m.storage, seeds[0]);
        let want: Vec<u32> = nbrs.iter().take(3).copied().collect();
        let got: Vec<u32> = sub.adjs[0]
            .idx
            .iter()
            .filter(|&&ix| ix >= 0)
            .map(|&ix| sub.nodes[ix as usize])
            .collect();
        // Same multiset (dedup may reorder locals but prefix is preserved
        // in order here since each neighbor is new or repeated).
        assert_eq!(got.len(), want.len().min(3));
        for w in &want {
            assert!(got.contains(w) || seeds.contains(w));
        }
    }

    #[test]
    fn charges_topology_io() {
        let (m, ds) = setup();
        let sampler = Sampler::new(vec![8, 8], 2);
        let seeds: Vec<u32> = ds.train_ids.iter().take(64).copied().collect();
        m.storage.ssd.reset_stats();
        sampler.sample_batch(&ds, &m.storage, 0, &seeds);
        let topo_misses = m
            .storage
            .cache
            .stats()
            .topology
            .misses
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(topo_misses > 0, "sampling should read topology pages");
    }

    #[test]
    fn property_sampled_subgraphs_always_valid() {
        let (m, ds) = setup();
        prop::check_noshrink(
            prop::Config::default().cases(20).sizes(1, 40),
            "sampled subgraph invariants",
            |rng, size| {
                let seeds: Vec<u32> =
                    (0..size).map(|_| rng.below(ds.spec.nodes)).collect();
                let fanouts = vec![1 + rng.below(6) as usize, 1 + rng.below(6) as usize];
                let batch = rng.next_u64() % 1000;
                (seeds, fanouts, batch)
            },
            |(seeds, fanouts, batch)| {
                // Dedup seeds (the batcher guarantees this in production).
                let mut uniq: Vec<u32> = Vec::new();
                for &s in seeds {
                    if !uniq.contains(&s) {
                        uniq.push(s);
                    }
                }
                if uniq.is_empty() {
                    return Ok(());
                }
                let sampler = Sampler::new(fanouts.clone(), 99);
                let sub = sampler.sample_batch(&ds, &m.storage, *batch, &uniq);
                sub.check_invariants()?;
                // Every non-padding adjacency entry resolves to a real node
                // that is an in-neighbor of its dst.
                for (i, adj) in sub.adjs.iter().enumerate() {
                    for d in 0..sub.cum[i].min(8) {
                        let v = sub.nodes[d];
                        let nbrs = ds.graph.neighbors(&m.storage, v);
                        for f in 0..adj.fanout {
                            let ix = adj.idx[d * adj.fanout + f];
                            if ix >= 0 {
                                let src = sub.nodes[ix as usize];
                                if !nbrs.contains(&src) {
                                    return Err(format!(
                                        "level {i}: {src} not an in-neighbor of {v}"
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
