//! Layered sampled subgraphs.
//!
//! A mini-batch's k-hop sample is stored as one deduplicated node list with
//! the *prefix property*: `nodes[..cum[i]]` is exactly the node set needed
//! at expansion level `i` (seeds are `nodes[..cum[0]]`). Level-`i` adjacency
//! maps each of the first `cum[i]` nodes to `fanout_i` sampled in-neighbors
//! as local indices into the prefix `cum[i+1]` (`-1` = padding/missing).
//! A GNN with L layers runs t = L..1 over levels L-t, shrinking the active
//! prefix each layer until only the seeds remain.

/// Adjacency for one expansion level.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAdj {
    pub fanout: usize,
    /// `idx[d * fanout + f]`: local index of dst-d's f-th sampled neighbor,
    /// or -1. Length = `dst_count * fanout`.
    pub idx: Vec<i32>,
}

impl LayerAdj {
    pub fn dst_count(&self) -> usize {
        if self.fanout == 0 {
            0
        } else {
            self.idx.len() / self.fanout
        }
    }
}

/// The sampled subgraph for one mini-batch.
#[derive(Clone, Debug)]
pub struct SampledSubgraph {
    /// Mini-batch sequence number (for reordering bookkeeping).
    pub batch_id: u64,
    /// Deduplicated global node ids; seeds first.
    pub nodes: Vec<u32>,
    /// Prefix sizes per level: `cum[0]` = #seeds … `cum[L]` = nodes.len().
    pub cum: Vec<usize>,
    /// `adjs[i]` connects prefix `cum[i]` (dst) to prefix `cum[i+1]` (src).
    pub adjs: Vec<LayerAdj>,
    /// Seed labels (training targets), one per seed.
    pub labels: Vec<u16>,
}

impl SampledSubgraph {
    pub fn seeds(&self) -> &[u32] {
        &self.nodes[..self.cum[0]]
    }

    pub fn levels(&self) -> usize {
        self.adjs.len()
    }

    /// Validate the structural invariants (used by tests & property checks).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.cum.len() != self.adjs.len() + 1 {
            return Err("cum/adjs length mismatch".into());
        }
        if *self.cum.last().unwrap() != self.nodes.len() {
            return Err("cum[L] != nodes.len()".into());
        }
        if self.labels.len() != self.cum[0] {
            return Err("labels != seed count".into());
        }
        for w in self.cum.windows(2) {
            if w[0] > w[1] {
                return Err("cum not monotone".into());
            }
        }
        // Dedup check.
        let mut seen = std::collections::HashSet::with_capacity(self.nodes.len());
        for &v in &self.nodes {
            if !seen.insert(v) {
                return Err(format!("duplicate node {v}"));
            }
        }
        for (i, adj) in self.adjs.iter().enumerate() {
            if adj.dst_count() != self.cum[i] {
                return Err(format!("adj {i} dst_count {} != cum {}", adj.dst_count(), self.cum[i]));
            }
            for &ix in &adj.idx {
                if ix < -1 || ix >= self.cum[i + 1] as i32 {
                    return Err(format!("adj {i} index {ix} out of prefix {}", self.cum[i + 1]));
                }
            }
        }
        Ok(())
    }

    /// Pad (and if necessary truncate) to fixed AOT shapes: node prefix caps
    /// per level and fixed fanouts. Returns flat arrays ready for literal
    /// packing. Truncated adjacency entries (pointing past a cap) become -1;
    /// padded node slots use node id 0 (their rows are never selected).
    pub fn pad(&self, caps: &[usize], fanouts: &[usize]) -> PaddedSubgraph {
        assert_eq!(caps.len(), self.cum.len(), "caps must cover every level");
        assert_eq!(fanouts.len(), self.adjs.len());
        let total_cap = *caps.last().unwrap();
        let mut nodes = Vec::with_capacity(total_cap);
        nodes.extend(self.nodes.iter().take(total_cap).copied());
        let truncated_nodes = self.nodes.len().saturating_sub(total_cap);
        nodes.resize(total_cap, 0);

        let mut adjs = Vec::with_capacity(self.adjs.len());
        let mut truncated_edges = 0usize;
        for (i, adj) in self.adjs.iter().enumerate() {
            let dst_cap = caps[i];
            let src_cap = caps[i + 1];
            let f_out = fanouts[i];
            let mut out = vec![-1i32; dst_cap * f_out];
            let dst_real = adj.dst_count().min(dst_cap);
            for d in 0..dst_real {
                for f in 0..adj.fanout.min(f_out) {
                    let ix = adj.idx[d * adj.fanout + f];
                    if ix >= 0 && (ix as usize) < src_cap {
                        out[d * f_out + f] = ix;
                    } else if ix >= 0 {
                        truncated_edges += 1;
                    }
                }
            }
            adjs.push(LayerAdj { fanout: f_out, idx: out });
        }

        let seed_cap = caps[0];
        let mut labels: Vec<i32> = self.labels.iter().take(seed_cap).map(|&l| l as i32).collect();
        let real_seeds = labels.len();
        labels.resize(seed_cap, -1); // -1 = padded seed, masked out of the loss

        PaddedSubgraph {
            batch_id: self.batch_id,
            real_nodes: self.nodes.len().min(total_cap),
            nodes,
            adjs,
            labels,
            real_seeds,
            truncated_nodes,
            truncated_edges,
        }
    }
}

/// Fixed-shape padded form matching an AOT artifact's input signature.
#[derive(Clone, Debug)]
pub struct PaddedSubgraph {
    pub batch_id: u64,
    /// How many leading entries of `nodes` are real (non-padding).
    pub real_nodes: usize,
    /// Global node ids, length = cap\[L\]; slot 0-padded.
    pub nodes: Vec<u32>,
    /// Fixed-fanout adjacencies (−1-padded), lengths = cap\[i\]·fanout\[i\].
    pub adjs: Vec<LayerAdj>,
    /// Seed labels, −1 for padded seed slots; length = cap\[0\].
    pub labels: Vec<i32>,
    pub real_seeds: usize,
    pub truncated_nodes: usize,
    pub truncated_edges: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two seeds {10, 11}; level 0 fanout 2 sampling {12, 13}; level 1
    /// fanout 1 over prefix 4.
    fn sample() -> SampledSubgraph {
        SampledSubgraph {
            batch_id: 0,
            nodes: vec![10, 11, 12, 13, 14],
            cum: vec![2, 4, 5],
            adjs: vec![
                LayerAdj { fanout: 2, idx: vec![2, 3, 3, -1] },
                LayerAdj { fanout: 1, idx: vec![2, 3, 4, -1] },
            ],
            labels: vec![1, 0],
        }
    }

    #[test]
    fn invariants_hold_for_sample() {
        sample().check_invariants().unwrap();
    }

    #[test]
    fn invariants_catch_corruption() {
        let mut s = sample();
        s.adjs[0].idx[0] = 4; // outside prefix cum[1] = 4
        assert!(s.check_invariants().is_err());
        let mut s = sample();
        s.nodes[4] = 10; // duplicate
        assert!(s.check_invariants().is_err());
        let mut s = sample();
        s.cum[1] = 1; // not monotone w.r.t. adj dst_count
        assert!(s.check_invariants().is_err());
    }

    #[test]
    fn pad_expands_to_caps() {
        let p = sample().pad(&[4, 8, 16], &[2, 2]);
        assert_eq!(p.nodes.len(), 16);
        assert_eq!(p.nodes[..5], [10, 11, 12, 13, 14]);
        assert!(p.nodes[5..].iter().all(|&v| v == 0));
        assert_eq!(p.adjs[0].idx.len(), 4 * 2);
        assert_eq!(&p.adjs[0].idx[..4], &[2, 3, 3, -1]);
        assert!(p.adjs[0].idx[4..].iter().all(|&x| x == -1));
        assert_eq!(p.labels, vec![1, 0, -1, -1]);
        assert_eq!(p.real_seeds, 2);
        assert_eq!(p.truncated_nodes, 0);
        assert_eq!(p.truncated_edges, 0);
    }

    #[test]
    fn pad_truncates_overflow() {
        // Caps smaller than the sample: total cap 4 (drops node 14),
        // src cap at level 1 is 4 so index 4 is truncated to -1.
        let p = sample().pad(&[2, 4, 4], &[2, 1]);
        assert_eq!(p.nodes.len(), 4);
        assert_eq!(p.truncated_nodes, 1);
        assert_eq!(p.adjs[1].idx, vec![2, 3, -1, -1]);
        assert_eq!(p.truncated_edges, 1);
    }

    #[test]
    fn pad_narrows_fanout() {
        let p = sample().pad(&[2, 4, 5], &[1, 1]);
        // Only the first neighbor of each dst survives fanout narrowing.
        assert_eq!(p.adjs[0].idx, vec![2, 3]);
    }
}
