//! Sample stage: mini-batch planning, k-hop neighbor sampling, layered
//! subgraphs and AOT-shape padding.

pub mod batch;
pub mod sampler;
pub mod schedule;
pub mod subgraph;

pub use batch::EpochPlan;
pub use sampler::{SamplePolicy, Sampler};
pub use schedule::ScheduleSpec;
pub use subgraph::{LayerAdj, PaddedSubgraph, SampledSubgraph};
