//! Experiment harness: one function per paper table/figure, producing the
//! same rows/series the paper reports (DESIGN.md §6 maps each to its bench
//! target). Both the `gnndrive figure <id>` CLI and the `cargo bench`
//! targets call these.
//!
//! `quick` mode (default) trims sweeps so the whole suite completes on the
//! single-core CI box; set `GNNDRIVE_BENCH_FULL=1` for the full grids.
//! Absolute numbers are simulated-testbed numbers at 1/256 scale — the
//! *shape* (who wins, rough factors, crossovers) is the reproduction claim;
//! EXPERIMENTS.md records paper-vs-measured per experiment.

use crate::baselines::{build_system, SystemKind};
use crate::config::{Machine, MachineConfig, TrainConfig};
use crate::graph::{Dataset, DatasetSpec};
use crate::metrics::timeline::{bucketize, render, TimelineRecorder};
use crate::pipeline::{EpochStats, Variant};
use crate::runtime::simcompute::ModelKind;
use crate::sim::Clock;
use crate::util::units::{fmt_dur, fmt_rate};
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

pub fn is_full() -> bool {
    std::env::var("GNNDRIVE_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

fn clock() -> Clock {
    Clock::from_env()
}

/// The paper's workload defaults (§5), trimmed per mode.
fn workload(quick: bool) -> TrainConfig {
    TrainConfig {
        batch_size: 1000,
        fanouts: vec![10, 10, 10],
        batches_per_epoch: Some(if quick { 5 } else { 10 }),
        samplers: 4,
        extractors: 4,
        io_depth: 128,
        ..TrainConfig::default()
    }
}

/// Fig 2 measurement config: a single loader worker isolates the page-cache
/// contention effect on this 1-core host (multi-worker CPU contention would
/// otherwise pollute summed sampling time; DESIGN.md §3).
fn fig2_cfg(kind: SystemKind, quick: bool) -> TrainConfig {
    let mut cfg = workload(quick);
    cfg.samplers = 1;
    cfg.extractors = match kind {
        SystemKind::PygPlus => 0, // PyG+ workers = samplers+extractors
        _ => 1,
    };
    cfg
}

/// One measurement cell: fresh caches, one warm-up epoch (the paper
/// averages over 10 warm epochs), then the measured epoch.
fn run_epoch_cell(
    machine: &Arc<Machine>,
    ds: &Arc<Dataset>,
    kind: SystemKind,
    cfg: TrainConfig,
    model: ModelKind,
    epoch: u64,
) -> Result<EpochStats, String> {
    machine.storage.cache.drop_all();
    machine.storage.cache.stats().reset();
    let mut sys =
        build_system(kind, machine, ds, cfg, model).map_err(|e| format!("OOM ({e})"))?;
    sys.run_epoch(epoch).map_err(|e| format!("OOM ({e})"))?; // warm-up
    sys.run_epoch(epoch + 1).map_err(|e| format!("OOM ({e})"))
}

// ---------------------------------------------------------------------------
// Fig 2 — sampling time, `-only` vs `-all`, across feature dimensions
// ---------------------------------------------------------------------------

pub fn fig02(quick: bool) -> String {
    let dims: &[usize] = if quick { &[64, 128, 512] } else { &[64, 128, 256, 512] };
    let systems = [SystemKind::PygPlus, SystemKind::Ginex, SystemKind::GnnDriveGpu];
    let mut out = String::new();
    writeln!(
        out,
        "# Fig 2 — sampling time (s) with varying feature dimension, papers100m-mini, GraphSAGE\n\
         # '-only' = sample stage alone per epoch; '-all' = sampling time within a full SET epoch\n\
         dim\tsystem\tsample_only_s\tsample_all_s\tslowdown"
    )
    .unwrap();
    for &dim in dims {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
        let spec = DatasetSpec::papers100m_mini().with_dim(dim);
        let ds = match Dataset::materialize(&spec, &machine) {
            Ok(d) => Arc::new(d),
            Err(e) => {
                writeln!(out, "{dim}\t-\tOOM ({e})").unwrap();
                continue;
            }
        };
        for kind in systems {
            let cfg = fig2_cfg(kind, quick);
            machine.storage.cache.drop_all();
            let only = match build_system(kind, &machine, &ds, cfg.clone(), ModelKind::GraphSage)
            {
                Ok(mut sys) => {
                    sys.run_sample_only(0); // warm the page cache
                    sys.run_sample_only(1)
                }
                Err(e) => {
                    writeln!(out, "{dim}\t{}\tOOM ({e})", kind.label()).unwrap();
                    continue;
                }
            };
            let all = match run_epoch_cell(&machine, &ds, kind, cfg, ModelKind::GraphSage, 1) {
                Ok(st) => st.sample_time,
                Err(e) => {
                    writeln!(out, "{dim}\t{}\t{:.3}\t{e}", kind.label(), only.as_secs_f64())
                        .unwrap();
                    continue;
                }
            };
            writeln!(
                out,
                "{dim}\t{}\t{:.3}\t{:.3}\t{:.2}x",
                kind.label(),
                only.as_secs_f64(),
                all.as_secs_f64(),
                all.as_secs_f64() / only.as_secs_f64().max(1e-9),
            )
            .unwrap();
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Figs 3 & 11 — CPU/GPU utilization + iowait timelines
// ---------------------------------------------------------------------------

pub fn fig03_fig11(quick: bool) -> String {
    let epochs = if quick { 1 } else { 3 };
    let systems = [
        SystemKind::PygPlus,
        SystemKind::Ginex,
        SystemKind::MariusGnn,
        SystemKind::GnnDriveGpu,
        SystemKind::GnnDriveCpu,
    ];
    let mut out = String::new();
    writeln!(
        out,
        "# Figs 3 & 11 — CPU util / GPU util / iowait over {epochs} epoch(s), papers100m-mini, GraphSAGE"
    )
    .unwrap();
    for kind in systems {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::papers100m_mini(), &machine).unwrap());
        let cfg = workload(quick);
        let mut sys = match build_system(kind, &machine, &ds, cfg, ModelKind::GraphSage) {
            Ok(s) => s,
            Err(e) => {
                writeln!(out, "\n== {} == OOM ({e})", kind.label()).unwrap();
                continue;
            }
        };
        let rec = TimelineRecorder::start(machine.clock.clone(), Duration::from_millis(10));
        let mut failed = None;
        for e in 0..epochs {
            if let Err(err) = sys.run_epoch(e) {
                failed = Some(err);
                break;
            }
        }
        let samples = rec.finish();
        writeln!(out, "\n== {} ==", kind.label()).unwrap();
        if let Some(err) = failed {
            writeln!(out, "OOM ({err})").unwrap();
            continue;
        }
        out.push_str(&render(&bucketize(&samples, 24)));
        let mean_io =
            samples.iter().map(|s| s.iowait).sum::<f64>() / samples.len().max(1) as f64;
        let mean_cpu = samples.iter().map(|s| s.cpu).sum::<f64>() / samples.len().max(1) as f64;
        let mean_gpu = samples.iter().map(|s| s.gpu).sum::<f64>() / samples.len().max(1) as f64;
        writeln!(
            out,
            "mean\tcpu {:.0}%\tgpu {:.0}%\tiowait {:.0}%",
            mean_cpu * 100.0,
            mean_gpu * 100.0,
            mean_io * 100.0
        )
        .unwrap();
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 8 — epoch time vs feature dimension (datasets × models × systems)
// ---------------------------------------------------------------------------

pub fn fig08(quick: bool) -> String {
    let datasets: Vec<DatasetSpec> = if quick {
        vec![DatasetSpec::papers100m_mini(), DatasetSpec::twitter_mini()]
    } else {
        DatasetSpec::all_minis()
    };
    let dims: &[usize] = if quick { &[64, 128, 512] } else { &[64, 128, 256, 512] };
    let models: &[ModelKind] = if quick {
        &[ModelKind::GraphSage]
    } else {
        &[ModelKind::GraphSage, ModelKind::Gcn, ModelKind::Gat]
    };
    let systems = [
        SystemKind::PygPlus,
        SystemKind::Ginex,
        SystemKind::GnnDriveGpu,
        SystemKind::GnnDriveCpu,
    ];
    let mut out = String::new();
    writeln!(
        out,
        "# Fig 8 — epoch time (s) with varying feature dimensions\n\
         dataset\tmodel\tdim\tsystem\tepoch_s\tsample_s\textract_s\ttrain_s"
    )
    .unwrap();
    for spec0 in &datasets {
        for &model in models {
            for &dim in dims {
                let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
                let spec = spec0.clone().with_dim(dim);
                let ds = Arc::new(Dataset::materialize(&spec, &machine).unwrap());
                for kind in systems {
                    let row_head =
                        format!("{}\t{}\t{dim}\t{}", spec0.name, model.name(), kind.label());
                    match run_epoch_cell(&machine, &ds, kind, workload(quick), model, 0) {
                        Ok(st) => writeln!(
                            out,
                            "{row_head}\t{:.3}\t{:.3}\t{:.3}\t{:.3}",
                            st.epoch_time.as_secs_f64(),
                            st.sample_time.as_secs_f64(),
                            st.extract_time.as_secs_f64(),
                            st.train_time.as_secs_f64(),
                        )
                        .unwrap(),
                        Err(e) => writeln!(out, "{row_head}\t{e}").unwrap(),
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 9 — epoch time vs host memory capacity (dim 512)
// ---------------------------------------------------------------------------

pub fn fig09(quick: bool) -> String {
    let gbs: &[u64] = if quick { &[8, 32, 128] } else { &[8, 16, 32, 64, 128] };
    let datasets: Vec<DatasetSpec> = if quick {
        vec![DatasetSpec::papers100m_mini(), DatasetSpec::twitter_mini()]
    } else {
        DatasetSpec::all_minis()
    };
    let systems = [
        SystemKind::PygPlus,
        SystemKind::Ginex,
        SystemKind::GnnDriveGpu,
        SystemKind::GnnDriveCpu,
    ];
    let mut out = String::new();
    writeln!(
        out,
        "# Fig 9 — epoch time (s) with varying host memory (paper-scale GB; simulated = GB/256), dim 512\n\
         dataset\tmem_gb\tsystem\tepoch_s"
    )
    .unwrap();
    for spec0 in &datasets {
        for &gb in gbs {
            let machine = Arc::new(Machine::new(
                MachineConfig::paper().with_paper_host_gb(gb),
                clock(),
            ));
            let spec = spec0.clone().with_dim(512);
            let ds = match Dataset::materialize(&spec, &machine) {
                Ok(d) => Arc::new(d),
                Err(e) => {
                    writeln!(out, "{}\t{gb}\t-\tOOM ({e})", spec0.name).unwrap();
                    continue;
                }
            };
            for kind in systems {
                match run_epoch_cell(&machine, &ds, kind, workload(quick), ModelKind::GraphSage, 0)
                {
                    Ok(st) => writeln!(
                        out,
                        "{}\t{gb}\t{}\t{:.3}",
                        spec0.name,
                        kind.label(),
                        st.epoch_time.as_secs_f64()
                    )
                    .unwrap(),
                    Err(e) => {
                        writeln!(out, "{}\t{gb}\t{}\t{e}", spec0.name, kind.label()).unwrap()
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 10 — epoch time vs mini-batch size
// ---------------------------------------------------------------------------

pub fn fig10(quick: bool) -> String {
    let batch_sizes: &[usize] = &[500, 1000, 2000, 4000];
    let datasets: Vec<DatasetSpec> = if quick {
        vec![DatasetSpec::papers100m_mini()]
    } else {
        vec![DatasetSpec::papers100m_mini(), DatasetSpec::friendster_mini()]
    };
    let systems = [SystemKind::PygPlus, SystemKind::Ginex, SystemKind::GnnDriveGpu];
    let mut out = String::new();
    writeln!(
        out,
        "# Fig 10 — epoch time (s) with varying mini-batch size (same total seeds per epoch)\n\
         dataset\tbatch\tsystem\tepoch_s\tsample_s"
    )
    .unwrap();
    for spec in &datasets {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
        let ds = Arc::new(Dataset::materialize(spec, &machine).unwrap());
        for &b in batch_sizes {
            let mut cfg = workload(quick);
            // Hold total seeds ≈ constant so epochs are comparable.
            let total_seeds = cfg.batches_per_epoch.unwrap_or(4) * cfg.batch_size;
            cfg.batch_size = b;
            cfg.batches_per_epoch = Some((total_seeds / b).max(1));
            for kind in systems {
                match run_epoch_cell(&machine, &ds, kind, cfg.clone(), ModelKind::GraphSage, 0) {
                    Ok(st) => writeln!(
                        out,
                        "{}\t{b}\t{}\t{:.3}\t{:.3}",
                        spec.name,
                        kind.label(),
                        st.epoch_time.as_secs_f64(),
                        st.sample_time.as_secs_f64()
                    )
                    .unwrap(),
                    Err(e) => writeln!(out, "{}\t{b}\t{}\t{e}", spec.name, kind.label()).unwrap(),
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 12 — feature buffer size sweep (1×–8× the minimum)
// ---------------------------------------------------------------------------

pub fn fig12(quick: bool) -> String {
    use crate::baselines::{shared_caps, sim_trainer};
    use crate::pipeline::GnnDrive;
    let mults: &[usize] = &[1, 2, 4, 8];
    let datasets: Vec<DatasetSpec> = if quick {
        vec![DatasetSpec::papers100m_mini()]
    } else {
        vec![DatasetSpec::papers100m_mini(), DatasetSpec::twitter_mini()]
    };
    let mut out = String::new();
    writeln!(
        out,
        "# Fig 12 — GNNDrive epoch time (s) vs feature buffer size (multiple of the minimum)\n\
         dataset\tmult\tepoch_s\tbuffer_hits\tbuffer_loads"
    )
    .unwrap();
    for spec in &datasets {
        for &mult in mults {
            let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
            let ds = Arc::new(Dataset::materialize(spec, &machine).unwrap());
            let mut cfg = workload(quick);
            cfg.feature_buffer_mult = mult;
            // The per-epoch working set must exceed the 1x buffer for the
            // locality effect to be visible (the paper's epochs touch ~50x
            // the buffer): 12 batches ≈ 1.7x the minimum buffer here.
            cfg.batches_per_epoch = Some(if quick { 16 } else { 24 });
            let caps = shared_caps(&machine, &ds, &cfg, Variant::Gpu);
            let trainer = Box::new(crate::runtime::simcompute::SimTrainStep::new(
                machine.cfg.gpu,
                machine.clock.clone(),
                ModelKind::GraphSage,
                caps,
                cfg.fanouts.clone(),
                ds.spec.dim,
                256,
                ds.spec.classes,
            ));
            let _ = sim_trainer; // (trainer built inline to pin caps)
            match GnnDrive::new(&machine, &ds, cfg, Variant::Gpu, trainer) {
                Ok(engine) => {
                    engine.run_epoch(0); // warm
                    let st = engine.run_epoch(1);
                    let (hits, _, _, loads) = engine.feature_buffer().stats();
                    writeln!(
                        out,
                        "{}\t{mult}x\t{:.3}\t{hits}\t{loads}",
                        spec.name,
                        st.epoch_time.as_secs_f64()
                    )
                    .unwrap();
                }
                Err(e) => writeln!(out, "{}\t{mult}x\tOOM ({e})", spec.name).unwrap(),
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 13 — multi-GPU scalability (K80 machine)
// ---------------------------------------------------------------------------

pub fn fig13(quick: bool) -> String {
    use crate::parallel::run_parallel_epoch;
    let workers: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 6, 8] };
    let specs: Vec<DatasetSpec> = if quick {
        vec![DatasetSpec::papers100m_mini()]
    } else {
        vec![DatasetSpec::papers100m_mini(), DatasetSpec::mag240m_mini()]
    };
    let mut out = String::new();
    writeln!(
        out,
        "# Fig 13 — GNNDrive multi-GPU scalability on the K80 machine (8x K80, S3510 SSD)\n\
         dataset\tvariant\tworkers\tepoch_s\tspeedup"
    )
    .unwrap();
    for spec in &specs {
        for variant in [Variant::Gpu, Variant::Cpu] {
            let mut base = None;
            for &w in workers {
                let machine = Arc::new(Machine::new(MachineConfig::k80(), clock()));
                let ds = Arc::new(Dataset::materialize(spec, &machine).unwrap());
                let mut cfg = workload(quick);
                // Fixed total work split across workers.
                let total = cfg.batches_per_epoch.unwrap_or(4) * 2;
                cfg.batches_per_epoch = Some((total / w).max(1));
                match run_parallel_epoch(
                    &machine,
                    &ds,
                    &cfg,
                    ModelKind::GraphSage,
                    variant,
                    w,
                    0,
                ) {
                    Ok(pt) => {
                        let t = pt.epoch_time.as_secs_f64();
                        let speedup = base.map(|b: f64| b / t).unwrap_or(1.0);
                        if base.is_none() {
                            base = Some(t);
                        }
                        writeln!(
                            out,
                            "{}\t{:?}\t{w}\t{:.3}\t{:.2}x",
                            spec.name, variant, t, speedup
                        )
                        .unwrap();
                    }
                    Err(e) => {
                        writeln!(out, "{}\t{variant:?}\t{w}\tOOM ({e})", spec.name).unwrap()
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 14 — time-to-accuracy with REAL PJRT training (papers-tiny)
// ---------------------------------------------------------------------------

pub fn fig14(quick: bool) -> String {
    use crate::runtime::TrainHandle;
    use crate::train::convergence::ConvergenceTrace;

    let artifacts = crate::runtime::ArtifactMeta::default_dir();
    if !artifacts.join("sage_mini.hlo.txt").exists() {
        return "# Fig 14 skipped: artifacts not built (run `make artifacts`)\n".into();
    }
    let epochs = if quick { 3 } else { 6 };
    let systems = [SystemKind::GnnDriveGpu, SystemKind::PygPlus, SystemKind::Ginex];
    let mut out = String::new();
    writeln!(
        out,
        "# Fig 14 — time-to-accuracy, papers-tiny, GraphSAGE via the REAL PJRT artifact\n\
         # (loss/accuracy are genuine numerics from the AOT-compiled JAX/Pallas train step)\n\
         system\ttime_s\tepoch\tloss\taccuracy"
    )
    .unwrap();
    for kind in systems {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::papers_tiny(), &machine).unwrap());
        let handle = match TrainHandle::spawn(artifacts.clone(), "sage_mini".into()) {
            Ok(h) => h,
            Err(e) => {
                writeln!(out, "{}\tartifact load failed: {e}", kind.label()).unwrap();
                continue;
            }
        };
        let mut cfg = workload(quick);
        cfg.batch_size = 64; // artifact shapes: B=64, fanouts (5,5)
        cfg.fanouts = vec![5, 5];
        cfg.batches_per_epoch = Some(if quick { 24 } else { 48 });
        let mut sys = match kind {
            SystemKind::GnnDriveGpu => {
                let engine = crate::pipeline::GnnDrive::new(
                    &machine,
                    &ds,
                    cfg,
                    Variant::Gpu,
                    Box::new(handle),
                );
                match engine {
                    Ok(e) => Box::new(EngineAdapter(e)) as Box<dyn crate::baselines::TrainingSystem + '_>,
                    Err(e) => {
                        writeln!(out, "{}\tOOM ({e})", kind.label()).unwrap();
                        continue;
                    }
                }
            }
            SystemKind::PygPlus => Box::new(crate::baselines::PygPlus::new(
                &machine,
                &ds,
                cfg,
                Box::new(handle),
            )),
            SystemKind::Ginex => match crate::baselines::Ginex::new(
                &machine,
                &ds,
                cfg,
                Box::new(handle),
            ) {
                Ok(g) => Box::new(g) as Box<dyn crate::baselines::TrainingSystem + '_>,
                Err(e) => {
                    writeln!(out, "{}\tOOM ({e})", kind.label()).unwrap();
                    continue;
                }
            },
            _ => unreachable!(),
        };
        let mut trace = ConvergenceTrace::default();
        let t0 = machine.clock.now();
        for e in 0..epochs {
            match sys.run_epoch(e as u64) {
                Ok(st) => {
                    trace.record(
                        machine.clock.now().saturating_sub(t0),
                        e,
                        st.train.mean_loss(),
                        st.train.accuracy(),
                    );
                }
                Err(err) => {
                    writeln!(out, "{}\tepoch {e}: {err}", kind.label()).unwrap();
                    break;
                }
            }
        }
        for p in &trace.points {
            writeln!(
                out,
                "{}\t{:.2}\t{}\t{:.4}\t{:.4}",
                kind.label(),
                p.time.as_secs_f64(),
                p.epoch,
                p.loss,
                p.accuracy
            )
            .unwrap();
        }
    }
    out
}

/// Local adapter (fig14 builds engines directly to inject the PJRT trainer).
struct EngineAdapter(crate::pipeline::GnnDrive);

impl crate::baselines::TrainingSystem for EngineAdapter {
    fn name(&self) -> &'static str {
        "GNNDrive(GPU)"
    }
    fn run_epoch(&mut self, epoch: u64) -> anyhow::Result<EpochStats> {
        Ok(self.0.run_epoch(epoch))
    }
    fn run_sample_only(&mut self, epoch: u64) -> Duration {
        self.0.run_sample_only(epoch)
    }
}

// ---------------------------------------------------------------------------
// Table 2 — MariusGNN vs GNNDrive (data preparation / training / overall)
// ---------------------------------------------------------------------------

pub fn tab02(quick: bool) -> String {
    let specs = [DatasetSpec::papers100m_mini(), DatasetSpec::mag240m_mini()];
    let mut out = String::new();
    writeln!(
        out,
        "# Table 2 — runtime of one epoch (s): data preparation vs training vs overall\n\
         system\tdataset\tprep_s\ttrain_s\toverall_s"
    )
    .unwrap();
    let rows: Vec<(SystemKind, u64)> = vec![
        (SystemKind::GnnDriveGpu, 32),
        (SystemKind::GnnDriveCpu, 32),
        (SystemKind::PygPlus, 32),
        (SystemKind::Ginex, 32),
        (SystemKind::MariusGnn, 32),
        (SystemKind::MariusGnn, 128),
    ];
    for spec in &specs {
        for &(kind, gb) in &rows {
            let machine = Arc::new(Machine::new(
                MachineConfig::paper().with_paper_host_gb(gb),
                clock(),
            ));
            let ds = Arc::new(Dataset::materialize(spec, &machine).unwrap());
            let label = if gb == 32 {
                kind.label().to_string()
            } else {
                format!("{}-{gb}G", kind.label())
            };
            match run_epoch_cell(&machine, &ds, kind, workload(quick), ModelKind::GraphSage, 0) {
                Ok(st) => {
                    let work = st.epoch_time.saturating_sub(st.prep_time);
                    writeln!(
                        out,
                        "{label}\t{}\t{:.3}\t{:.3}\t{:.3}",
                        spec.name,
                        st.prep_time.as_secs_f64(),
                        work.as_secs_f64(),
                        st.epoch_time.as_secs_f64()
                    )
                    .unwrap();
                }
                Err(e) => writeln!(out, "{label}\t{}\t{e}", spec.name).unwrap(),
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fig B.1 — fio-style sync-vs-async I/O microbenchmark on the SSD model
// ---------------------------------------------------------------------------

pub fn figb1(quick: bool) -> String {
    use crate::membuf::{SlotRef, StagingArena};
    use crate::storage::uring::{IoMode, Sqe, Uring};
    use crate::storage::{AsyncIoEngine as _, DataKind, FileId, MemBacking, SimFile};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Instant;

    let ops_per_point = if quick { 1200 } else { 6000 };
    let threads_sweep: &[usize] = if quick { &[1, 4, 16, 64] } else { &[1, 2, 4, 8, 16, 32, 64] };
    let depth_sweep: &[usize] = if quick { &[1, 4, 16, 64, 256] } else { &[1, 2, 4, 8, 16, 32, 64, 128, 256] };
    let mut out = String::new();
    writeln!(
        out,
        "# Fig B.1 — 512 B random reads on the simulated PM883: sync (threads) vs async (iodepth)\n\
         mode\tio\tparam\tbandwidth\tavg_latency"
    )
    .unwrap();

    let make = || {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
        let bytes: Vec<u8> = vec![0u8; 8 << 20];
        let file = SimFile::new(
            FileId::new(999, DataKind::Other),
            Arc::new(MemBacking::new(bytes)),
        );
        (machine, file)
    };

    for buffered in [false, true] {
        let io_name = if buffered { "buffered" } else { "direct" };
        // Synchronous reads with T threads.
        for &t in threads_sweep {
            let (machine, file) = make();
            let cursor = AtomicUsize::new(0);
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for _ in 0..t {
                    let cursor = &cursor;
                    let machine = &machine;
                    let file = &file;
                    s.spawn(move || {
                        let mut buf = vec![0u8; 512];
                        let mut rng = crate::util::rng::Pcg::new(7);
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= ops_per_point {
                                break;
                            }
                            let off = (rng.below(16 * 1024) as u64) * 512;
                            if buffered {
                                machine.storage.read_buffered(file, off, &mut buf);
                            } else {
                                machine.storage.read_direct(file, off, &mut buf);
                            }
                        }
                    });
                }
            });
            let wall = machine.clock.to_sim(t0.elapsed());
            let bw = ops_per_point as f64 * 512.0 / wall.as_secs_f64();
            let lat = machine.storage.ssd.latency_hist().mean();
            writeln!(
                out,
                "sync\t{io_name}\t{t} thr\t{}\t{}",
                fmt_rate(bw),
                fmt_dur(lat)
            )
            .unwrap();
        }
        // Asynchronous reads through one ring with varying iodepth.
        for &d in depth_sweep {
            let (machine, file) = make();
            let ring = Uring::new(Arc::new(machine.storage.clone()), d);
            // One staging slot per possibly-in-flight request (SQ depth +
            // worker chunks), so concurrent completions never share bytes.
            let slots = 1024;
            let arena = StagingArena::new(slots, 512);
            let mut rng = crate::util::rng::Pcg::new(9);
            let t0 = Instant::now();
            let sqes: Vec<Sqe> = (0..ops_per_point)
                .map(|i| Sqe {
                    file: file.clone(),
                    offset: (rng.below(16 * 1024) as u64) * 512,
                    len: 512,
                    useful: 512,
                    dst: SlotRef::new(arena.clone(), i % slots),
                    dst_off: 0,
                    user_data: i as u64,
                    mode: if buffered { IoMode::Buffered } else { IoMode::Direct },
                })
                .collect();
            ring.submit_batch(sqes);
            ring.wait_cqes(ops_per_point);
            let wall = machine.clock.to_sim(t0.elapsed());
            let bw = ops_per_point as f64 * 512.0 / wall.as_secs_f64();
            let lat = machine.storage.ssd.latency_hist().mean();
            writeln!(
                out,
                "async\t{io_name}\tqd {d}\t{}\t{}",
                fmt_rate(bw),
                fmt_dur(lat)
            )
            .unwrap();
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Table 1 — dataset summary
// ---------------------------------------------------------------------------

pub fn table1() -> String {
    let machine = Machine::new(
        MachineConfig::paper().with_host_mem(1 << 30),
        clock(),
    );
    let mut out = String::new();
    writeln!(
        out,
        "# Table 1 — dataset analogs (1/256 scale)\n{:<18} {:>9} {:>10} {:>5} {:>7} {:>10} {:>10}",
        "dataset", "#nodes", "#edges", "dim", "#class", "topo", "feat"
    )
    .unwrap();
    for spec in DatasetSpec::all_minis().iter().chain([DatasetSpec::papers_tiny()].iter()) {
        match Dataset::materialize(spec, &machine) {
            Ok(ds) => writeln!(out, "{}", ds.table1_row()).unwrap(),
            Err(e) => writeln!(out, "{}: {e}", spec.name).unwrap(),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Ablation — each GNNDrive mechanism disabled individually (DESIGN.md §10)
// ---------------------------------------------------------------------------

pub fn ablation(quick: bool) -> String {
    use crate::baselines::sim_trainer;
    use crate::pipeline::GnnDrive;
    let mut out = String::new();
    writeln!(
        out,
        "# Ablation — GNNDrive with one mechanism disabled at a time\n\
         # (papers100m-mini, GraphSAGE, dim 128, warm epoch)\n\
         variant\tepoch_s\tsample_s\textract_s\tvs_full"
    )
    .unwrap();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), clock()));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::papers100m_mini(), &machine).unwrap());
    let variants: [(&str, fn(&mut TrainConfig)); 4] = [
        ("full", |_| {}),
        ("-async (sync extraction)", |c| c.sync_extract = true),
        ("-direct (buffered feature I/O)", |c| c.buffered_features = true),
        ("-reorder (in-order training)", |c| c.enforce_order = true),
    ];
    let mut full_time = None;
    for (name, tweak) in variants {
        let mut cfg = workload(quick);
        tweak(&mut cfg);
        machine.storage.cache.drop_all();
        let trainer =
            sim_trainer(&machine, &ds, &cfg, ModelKind::GraphSage, Variant::Gpu, 256);
        match GnnDrive::new(&machine, &ds, cfg, Variant::Gpu, trainer) {
            Ok(engine) => {
                engine.run_epoch(0); // warm
                let st = engine.run_epoch(1);
                let t = st.epoch_time.as_secs_f64();
                let rel = full_time.map(|f: f64| t / f).unwrap_or(1.0);
                if full_time.is_none() {
                    full_time = Some(t);
                }
                writeln!(
                    out,
                    "{name}\t{:.3}\t{:.3}\t{:.3}\t{:.2}x",
                    t,
                    st.sample_time.as_secs_f64(),
                    st.extract_time.as_secs_f64(),
                    rel
                )
                .unwrap();
            }
            Err(e) => writeln!(out, "{name}\tOOM ({e})").unwrap(),
        }
    }
    out
}

/// Dispatch by figure id (CLI + bench targets).
pub fn run_figure(id: &str, quick: bool) -> Option<String> {
    Some(match id {
        "2" | "fig2" => fig02(quick),
        "3" | "11" | "fig3" | "fig11" => fig03_fig11(quick),
        "8" | "fig8" => fig08(quick),
        "9" | "fig9" => fig09(quick),
        "10" | "fig10" => fig10(quick),
        "12" | "fig12" => fig12(quick),
        "13" | "fig13" => fig13(quick),
        "14" | "fig14" => fig14(quick),
        "tab1" | "table1" => table1(),
        "tab2" | "table2" => tab02(quick),
        "b1" | "figb1" => figb1(quick),
        "ablation" | "ablations" => ablation(quick),
        _ => return None,
    })
}
