//! Fx-style multiplicative hasher (no external crates offline).
//!
//! The sampler's dedup map and the feature buffer's mapping table hash
//! millions of small integer keys per epoch; std's SipHash costs ~3× more
//! than a multiplicative mix for these keys. Same construction as rustc's
//! FxHasher (not DoS-resistant — keys are internal node ids, never
//! attacker-controlled).

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_and_distributes() {
        let mut m: FxHashMap<u32, u32> = FxHashMap::default();
        for i in 0..10_000u32 {
            m.insert(i, i * 2);
        }
        assert_eq!(m.len(), 10_000);
        for i in (0..10_000u32).step_by(97) {
            assert_eq!(m[&i], i * 2);
        }
        // Distinct keys hash differently (sanity, not a statistical test).
        let mut h1 = FxHasher::default();
        h1.write_u32(1);
        let mut h2 = FxHasher::default();
        h2.write_u32(2);
        assert_ne!(h1.finish(), h2.finish());
    }
}
