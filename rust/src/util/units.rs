//! Byte- and time-unit parsing and human-readable formatting used by the
//! config system, the CLI and every report table.

use std::time::Duration;

/// Parse a byte count: `"128MiB"`, `"32G"`, `"512"`, `"4k"`. Decimal (k/M/G)
/// multipliers are powers of 1000; binary (`Ki`/`Mi`/`Gi`) are powers of 1024.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let split = s.find(|c: char| !(c.is_ascii_digit() || c == '.')).unwrap_or(s.len());
    let (num, suffix) = s.split_at(split);
    let num: f64 = num.parse().map_err(|_| format!("bad byte count {s:?}"))?;
    let suffix = suffix.trim().trim_end_matches(['b', 'B']);
    let mult: u64 = match suffix.to_ascii_lowercase().as_str() {
        "" => 1,
        "k" => 1000,
        "m" => 1000_u64.pow(2),
        "g" => 1000_u64.pow(3),
        "t" => 1000_u64.pow(4),
        "ki" => 1024,
        "mi" => 1024_u64.pow(2),
        "gi" => 1024_u64.pow(3),
        "ti" => 1024_u64.pow(4),
        other => return Err(format!("unknown byte suffix {other:?} in {s:?}")),
    };
    Ok((num * mult as f64).round() as u64)
}

/// Parse a duration: `"90us"`, `"1.5ms"`, `"3s"`, `"2m"`.
pub fn parse_duration(s: &str) -> Result<Duration, String> {
    let s = s.trim();
    let split = s.find(|c: char| !(c.is_ascii_digit() || c == '.')).unwrap_or(s.len());
    let (num, suffix) = s.split_at(split);
    let num: f64 = num.parse().map_err(|_| format!("bad duration {s:?}"))?;
    let secs = match suffix.trim() {
        "ns" => num * 1e-9,
        "us" | "µs" => num * 1e-6,
        "ms" => num * 1e-3,
        "" | "s" => num,
        "m" | "min" => num * 60.0,
        "h" => num * 3600.0,
        other => return Err(format!("unknown time suffix {other:?} in {s:?}")),
    };
    Ok(Duration::from_secs_f64(secs))
}

/// Format bytes with binary units: `"1.50 MiB"`.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut i = 0;
    while v >= 1024.0 && i + 1 < UNITS.len() {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[i])
    }
}

/// Format a duration adaptively: `"91.0us"`, `"12.3ms"`, `"4.56s"`.
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}m", s / 60.0)
    }
}

/// Format a rate in bytes/second: `"520.0 MB/s"` (decimal units, like fio).
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    const UNITS: [&str; 4] = ["B/s", "KB/s", "MB/s", "GB/s"];
    let mut v = bytes_per_sec;
    let mut i = 0;
    while v >= 1000.0 && i + 1 < UNITS.len() {
        v /= 1000.0;
        i += 1;
    }
    format!("{v:.1} {}", UNITS[i])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip() {
        assert_eq!(parse_bytes("512").unwrap(), 512);
        assert_eq!(parse_bytes("4k").unwrap(), 4000);
        assert_eq!(parse_bytes("4KiB").unwrap(), 4096);
        assert_eq!(parse_bytes("128MiB").unwrap(), 128 << 20);
        assert_eq!(parse_bytes("32GiB").unwrap(), 32 << 30);
        assert_eq!(parse_bytes("1.5Ki").unwrap(), 1536);
        assert!(parse_bytes("12xx").is_err());
    }

    #[test]
    fn durations() {
        assert_eq!(parse_duration("90us").unwrap(), Duration::from_micros(90));
        assert_eq!(parse_duration("1.5ms").unwrap(), Duration::from_micros(1500));
        assert_eq!(parse_duration("3s").unwrap(), Duration::from_secs(3));
        assert!(parse_duration("3parsecs").is_err());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_dur(Duration::from_micros(91)), "91.0us");
        assert_eq!(fmt_dur(Duration::from_millis(12)), "12.0ms");
        assert_eq!(fmt_rate(520e6), "520.0 MB/s");
    }
}
