//! Minimal TOML-subset parser for config files.
//!
//! The offline environment has no `serde`/`toml` crates, so configuration is
//! parsed by this hand-rolled reader. Supported subset (all this project
//! needs): `[section]` and `[section.sub]` headers, `key = value` pairs with
//! string / integer / float / boolean / flat-array values, `#` comments.
//! Keys are exposed flattened as `"section.sub.key"`.

use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A flat view of a parsed document: dotted path → value.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                prefix = format!("{name}.");
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            entries.insert(format!("{prefix}{key}"), val);
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_i64)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    /// Keys that live directly under `section.` (one level).
    pub fn section_keys<'a>(&'a self, section: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{section}.");
        self.entries.keys().filter_map(move |k| k.strip_prefix(&want))
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.rfind('"').ok_or("unterminated string")?;
        return Ok(Value::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split on commas not inside quotes (arrays are flat, so no nesting).
fn split_top(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
            # machine preset
            title = "paper"
            [ssd]
            read_bw = "520MB"   # string, parsed later by units
            iops = 98000
            latency_us = 90.0
            [memory]
            enforce = true
            sweep = [32, 64, 128]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("title"), Some("paper"));
        assert_eq!(doc.get_str("ssd.read_bw"), Some("520MB"));
        assert_eq!(doc.get_i64("ssd.iops"), Some(98000));
        assert_eq!(doc.get_f64("ssd.latency_us"), Some(90.0));
        assert_eq!(doc.get_bool("memory.enforce"), Some(true));
        match doc.get("memory.sweep").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 3),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn comments_and_errors() {
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("keyonly").is_err());
        assert!(Doc::parse("k = ").is_err());
        let doc = Doc::parse("s = \"a # not comment\" # real comment").unwrap();
        assert_eq!(doc.get_str("s"), Some("a # not comment"));
    }

    #[test]
    fn section_keys_iterates() {
        let doc = Doc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<&str> = doc.section_keys("a").collect();
        assert_eq!(keys, vec!["x", "y"]);
    }
}
