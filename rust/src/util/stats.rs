//! Small statistics helpers: online mean/variance, percentile histograms and
//! EWMA. Used by the metrics layer and the bench harness.

use std::time::Duration;

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Log-bucketed latency histogram (~4 % resolution) with percentile queries.
/// Fixed memory, lock-free-friendly (callers own it or shard it).
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
}

const HIST_BUCKETS: usize = 512;

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { buckets: vec![0; HIST_BUCKETS], count: 0, sum_ns: 0 }
    }
}

impl LatencyHist {
    fn index(ns: u64) -> usize {
        // 16 sub-buckets per power of two starting at 64 ns.
        if ns < 64 {
            return 0;
        }
        let lz = 63 - ns.leading_zeros() as u64; // floor(log2)
        let base = (lz - 6) * 16;
        let frac = (ns >> (lz.saturating_sub(4))) & 0xF;
        ((base + frac) as usize).min(HIST_BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> u64 {
        if i == 0 {
            return 64;
        }
        let pow = (i / 16) as u64 + 6;
        let frac = (i % 16) as u64;
        (1u64 << pow) + (frac << pow.saturating_sub(4))
    }

    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[Self::index(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.sum_ns / self.count as u128) as u64)
    }

    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_nanos(Self::bucket_value(i));
            }
        }
        Duration::from_nanos(Self::bucket_value(HIST_BUCKETS - 1))
    }
}

/// Exponentially-weighted moving average.
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_moments() {
        let mut o = Online::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            o.push(x);
        }
        assert_eq!(o.count(), 8);
        assert!((o.mean() - 5.0).abs() < 1e-9);
        assert!((o.std() - 2.138).abs() < 0.01);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn hist_percentiles_roughly_right() {
        let mut h = LatencyHist::default();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.percentile(50.0).as_micros() as f64;
        let p99 = h.percentile(99.0).as_micros() as f64;
        assert!((p50 - 500.0).abs() / 500.0 < 0.1, "p50={p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.1, "p99={p99}");
        assert!(h.mean().as_micros() > 400 && h.mean().as_micros() < 600);
    }

    #[test]
    fn hist_merge_adds() {
        let mut a = LatencyHist::default();
        let mut b = LatencyHist::default();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        e.push(0.0);
        for _ in 0..20 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 0.01);
    }
}
