//! Small statistics helpers: online mean/variance, percentile histograms and
//! EWMA. Used by the metrics layer and the bench harness.

use std::time::Duration;

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Log-bucketed latency histogram (~4 % resolution) with percentile queries.
/// Fixed memory, lock-free-friendly (callers own it or shard it).
///
/// The histogram is **mergeable**: `merge` is a commutative, associative
/// bucket-wise sum, so per-worker histograms recorded independently and
/// merged at the end report exactly the same quantiles as one histogram fed
/// every sample — the contract the serving frontend's per-worker stage
/// recording relies on. The true maximum is tracked exactly (not bucketed)
/// so the extreme tail is never under-reported.
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

const HIST_BUCKETS: usize = 512;

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { buckets: vec![0; HIST_BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl LatencyHist {
    fn index(ns: u64) -> usize {
        // 16 sub-buckets per power of two starting at 64 ns.
        if ns < 64 {
            return 0;
        }
        let lz = 63 - ns.leading_zeros() as u64; // floor(log2)
        let base = (lz - 6) * 16;
        let frac = (ns >> (lz.saturating_sub(4))) & 0xF;
        ((base + frac) as usize).min(HIST_BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> u64 {
        if i == 0 {
            return 64;
        }
        let pow = (i / 16) as u64 + 6;
        let frac = (i % 16) as u64;
        (1u64 << pow) + (frac << pow.saturating_sub(4))
    }

    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[Self::index(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.sum_ns / self.count as u128) as u64)
    }

    /// Largest recorded value, exact (not bucket-quantized).
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                // The top bucket's representative value can undershoot an
                // extreme outlier; the exact max caps the answer honestly.
                return Duration::from_nanos(Self::bucket_value(i).min(self.max_ns));
            }
        }
        Duration::from_nanos(self.max_ns)
    }

    /// `percentile` over a `[0, 1]` quantile (the serving layer speaks
    /// quantiles; figures speak percentiles — same histogram walk).
    pub fn quantile(&self, q: f64) -> Duration {
        self.percentile(q * 100.0)
    }

    pub fn p50(&self) -> Duration {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(99.0)
    }

    /// One-line tail summary: `p50 1.2ms  p95 3.4ms  p99 5.6ms (n=100)`.
    pub fn summary(&self) -> String {
        format!(
            "p50 {:>8}  p95 {:>8}  p99 {:>8} (n={})",
            crate::util::units::fmt_dur(self.p50()),
            crate::util::units::fmt_dur(self.p95()),
            crate::util::units::fmt_dur(self.p99()),
            self.count
        )
    }
}

/// Exponentially-weighted moving average.
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_moments() {
        let mut o = Online::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            o.push(x);
        }
        assert_eq!(o.count(), 8);
        assert!((o.mean() - 5.0).abs() < 1e-9);
        assert!((o.std() - 2.138).abs() < 0.01);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn hist_percentiles_roughly_right() {
        let mut h = LatencyHist::default();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.percentile(50.0).as_micros() as f64;
        let p99 = h.percentile(99.0).as_micros() as f64;
        assert!((p50 - 500.0).abs() / 500.0 < 0.1, "p50={p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.1, "p99={p99}");
        assert!(h.mean().as_micros() > 400 && h.mean().as_micros() < 600);
    }

    #[test]
    fn hist_merge_adds() {
        let mut a = LatencyHist::default();
        let mut b = LatencyHist::default();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), Duration::from_micros(1000));
    }

    /// Deterministic pseudo-random latency stream for the merge/quantile
    /// properties (spans ns..ms so many distinct buckets are hit).
    fn stream(seed: u64, n: u64) -> impl Iterator<Item = Duration> {
        (0..n).map(move |i| {
            let h = crate::util::rng::hash2(seed, i);
            Duration::from_nanos(64 + h % 5_000_000)
        })
    }

    fn quantile_grid(h: &LatencyHist) -> Vec<Duration> {
        (0..=100).map(|p| h.percentile(p as f64)).collect()
    }

    #[test]
    fn hist_merge_is_associative_and_commutative() {
        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) == one histogram fed every sample —
        // identical counts, mean, max and the full quantile grid. This is
        // the contract that lets per-worker serving histograms merge into
        // one honest tail report.
        let mut parts: Vec<LatencyHist> = Vec::new();
        let mut whole = LatencyHist::default();
        for s in 0..3u64 {
            let mut h = LatencyHist::default();
            for d in stream(s * 7 + 1, 500) {
                h.record(d);
                whole.record(d);
            }
            parts.push(h);
        }
        let (a, b, c) = (&parts[0], &parts[1], &parts[2]);

        let mut left = a.clone(); // (a ⊕ b) ⊕ c
        left.merge(b);
        left.merge(c);
        let mut right = a.clone(); // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(c);
        right.merge(&bc);
        let mut swapped = c.clone(); // c ⊕ b ⊕ a (commutativity)
        swapped.merge(b);
        swapped.merge(a);

        for m in [&left, &right, &swapped] {
            assert_eq!(m.count(), whole.count());
            assert_eq!(m.mean(), whole.mean());
            assert_eq!(m.max(), whole.max());
            assert_eq!(quantile_grid(m), quantile_grid(&whole));
        }
        // Merging an empty histogram is the identity.
        let mut id = whole.clone();
        id.merge(&LatencyHist::default());
        assert_eq!(quantile_grid(&id), quantile_grid(&whole));
    }

    #[test]
    fn hist_quantiles_are_monotone_and_bounded() {
        let mut h = LatencyHist::default();
        let mut lo = Duration::MAX;
        for d in stream(42, 2000) {
            h.record(d);
            lo = lo.min(d);
        }
        let grid = quantile_grid(&h);
        for w in grid.windows(2) {
            assert!(w[0] <= w[1], "quantiles must be monotone: {w:?}");
        }
        // q ∈ [0,1] sugar agrees with the percentile walk.
        assert_eq!(h.quantile(0.5), h.p50());
        assert_eq!(h.quantile(0.95), h.p95());
        assert_eq!(h.quantile(0.99), h.p99());
        // Bounds: the whole grid sits inside [~min bucket edge, exact max].
        assert!(*grid.last().unwrap() <= h.max());
        assert!(grid[0] <= lo, "p0 must not exceed the smallest sample");
        assert!(h.p50() <= h.p95() && h.p95() <= h.p99());
        assert!(h.summary().contains("n=2000"));
        // Empty histogram degenerates to zeros.
        let e = LatencyHist::default();
        assert!(e.is_empty());
        assert_eq!(e.p99(), Duration::ZERO);
        assert_eq!(e.max(), Duration::ZERO);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        e.push(0.0);
        for _ in 0..20 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 0.01);
    }
}
