//! Minimal JSON reader/writer for artifact metadata sidecars.
//!
//! `python/compile/aot.py` writes a `<name>.meta.json` next to every HLO
//! artifact (shapes, dtypes, parameter layout, hyperparameters); the Rust
//! runtime reads it back with this module. No serde in the offline build, so
//! the subset implemented here is exactly what those sidecars use: objects,
//! arrays, strings (with standard escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `[1,2,3]` → `vec![1,2,3]`, for shape lists.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("unknown escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // advance one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_meta_shape() {
        let text = r#"{
            "name": "sage_mini",
            "batch": 64,
            "layer_caps": [64, 384, 2048],
            "dims": {"in": 64, "hidden": 64, "classes": 16},
            "lr": 0.01,
            "nested": [{"a": [1, 2]}, null, true]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("sage_mini"));
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(64));
        assert_eq!(
            j.get("layer_caps").unwrap().as_usize_vec(),
            Some(vec![64, 384, 2048])
        );
        assert_eq!(j.get("dims").unwrap().get("in").unwrap().as_usize(), Some(64));
        // serialize → parse → equal
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\"cA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\"cA"));
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }
}
