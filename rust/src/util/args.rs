//! Tiny CLI argument parser (no `clap` in the offline build).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with declared options for `--help` generation. Used by the `gnndrive`
//! binary, the examples and every bench harness.

use std::collections::BTreeMap;

/// Declared option for help text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments plus declarations for `--help`.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    specs: Vec<OptSpec>,
    program: String,
    about: &'static str,
}

impl Args {
    /// Build a parser: declare options first, then call `parse`.
    pub fn new(about: &'static str) -> Self {
        Args { about, ..Default::default() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse process args; prints help and exits on `--help`.
    pub fn parse(self) -> Self {
        let argv: Vec<String> = std::env::args().collect();
        match self.parse_from(&argv) {
            Ok(a) => a,
            Err(Help) => {
                // help was printed
                std::process::exit(0);
            }
        }
    }

    /// Parse an explicit argv (first element is the program name).
    pub fn parse_from(mut self, argv: &[String]) -> Result<Self, Help> {
        self.program = argv.first().cloned().unwrap_or_default();
        let is_flag = |specs: &[OptSpec], name: &str| {
            specs.iter().any(|s| s.is_flag && s.name == name)
        };
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                self.print_help();
                return Err(Help);
            }
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    self.options.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if is_flag(&self.specs, body) {
                    self.flags.push(body.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    self.options.insert(body.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    // unknown bare `--name`: treat as a flag
                    self.flags.push(body.to_string());
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn print_help(&self) {
        println!("{}\n", self.about);
        println!("OPTIONS:");
        for s in &self.specs {
            let kind = if s.is_flag { "".to_string() } else { " <value>".to_string() };
            let def = s
                .default
                .filter(|d| !d.is_empty())
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            println!("  --{}{kind}\n      {}{def}", s.name, s.help);
        }
        println!("  --help\n      print this message");
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Value or declared default; panics if the option was never declared
    /// with a default (programming error, not user error).
    pub fn get_or_default(&self, key: &str) -> &str {
        if let Some(v) = self.get(key) {
            return v;
        }
        self.specs
            .iter()
            .find(|s| s.name == key)
            .and_then(|s| s.default)
            .unwrap_or_else(|| panic!("option --{key} has no declared default"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, String> {
        self.get_or_default(key)
            .parse()
            .map_err(|_| format!("--{key} expects an integer, got {:?}", self.get_or_default(key)))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, String> {
        self.get_or_default(key)
            .parse()
            .map_err(|_| format!("--{key} expects a number, got {:?}", self.get_or_default(key)))
    }
}

/// Marker: `--help` was requested and printed.
#[derive(Debug)]
pub struct Help;

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        std::iter::once("prog").chain(s.iter().copied()).map(String::from).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::new("t")
            .opt("dataset", "papers100m-mini", "dataset name")
            .opt("epochs", "1", "epoch count")
            .flag("verbose", "chatty")
            .parse_from(&argv(&["train", "--dataset=twitter-mini", "--epochs", "3", "--verbose"]))
            .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("dataset"), Some("twitter-mini"));
        assert_eq!(a.get_usize("epochs").unwrap(), 3);
        assert!(a.has("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t")
            .opt("epochs", "2", "epoch count")
            .parse_from(&argv(&[]))
            .unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), 2);
        assert!(a.get_f64("epochs").is_ok());
    }

    #[test]
    fn unknown_bare_option_is_flag() {
        let a = Args::new("t").parse_from(&argv(&["--quick"])).unwrap();
        assert!(a.has("quick"));
    }
}
