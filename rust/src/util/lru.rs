//! Intrusive O(1) LRU list over hashable keys, needing `touch` / `pop_lru`
//! / `remove-by-key` in constant time.
//!
//! Used by the simulated OS page cache and by the preserved mutex-LRU
//! feature-buffer baseline (`membuf/mutex_lru.rs`). The production feature
//! buffer no longer uses this type: its standby "list" is implicit in the
//! packed per-slot atomic words, evicted by a second-chance clock sweep
//! (see `membuf/feature_buffer.rs`), so exact-LRU bookkeeping — and the
//! mutex it needs — is off the allocation hot path entirely.

use std::collections::HashMap;
use std::hash::Hash;

const NIL: usize = usize::MAX;

#[derive(Debug)]
struct Node<K> {
    key: K,
    prev: usize,
    next: usize,
}

/// Doubly-linked LRU: head = most-recently-used, tail = least-recently-used.
#[derive(Debug)]
pub struct Lru<K: Eq + Hash + Clone> {
    map: HashMap<K, usize>,
    nodes: Vec<Node<K>>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl<K: Eq + Hash + Clone> Default for Lru<K> {
    fn default() -> Self {
        Lru { map: HashMap::new(), nodes: Vec::new(), free: Vec::new(), head: NIL, tail: NIL }
    }
}

impl<K: Eq + Hash + Clone> Lru<K> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Preallocate for `cap` keys (callers like the mutex-LRU baseline know
    /// their slot population up front; avoids rehash/regrow churn).
    pub fn with_capacity(cap: usize) -> Self {
        Lru {
            map: HashMap::with_capacity(cap),
            nodes: Vec::with_capacity(cap),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.nodes[idx].prev, self.nodes[idx].next);
        if prev != NIL {
            self.nodes[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.nodes[idx].prev = NIL;
        self.nodes[idx].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Insert as MRU (or touch if present). Returns true if newly inserted.
    pub fn insert(&mut self, key: K) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.unlink(idx);
            self.push_front(idx);
            return false;
        }
        let idx = if let Some(idx) = self.free.pop() {
            self.nodes[idx] = Node { key: key.clone(), prev: NIL, next: NIL };
            idx
        } else {
            self.nodes.push(Node { key: key.clone(), prev: NIL, next: NIL });
            self.nodes.len() - 1
        };
        self.map.insert(key, idx);
        self.push_front(idx);
        true
    }

    /// Move to MRU if present. Returns whether the key was present.
    pub fn touch(&mut self, key: &K) -> bool {
        if let Some(&idx) = self.map.get(key) {
            self.unlink(idx);
            self.push_front(idx);
            true
        } else {
            false
        }
    }

    /// Remove a specific key.
    pub fn remove(&mut self, key: &K) -> bool {
        if let Some(idx) = self.map.remove(key) {
            self.unlink(idx);
            self.free.push(idx);
            true
        } else {
            false
        }
    }

    /// Evict and return the least-recently-used key.
    pub fn pop_lru(&mut self) -> Option<K> {
        if self.tail == NIL {
            return None;
        }
        let idx = self.tail;
        let key = self.nodes[idx].key.clone();
        self.unlink(idx);
        self.map.remove(&key);
        self.free.push(idx);
        Some(key)
    }

    /// Peek the LRU key without evicting.
    pub fn peek_lru(&self) -> Option<&K> {
        if self.tail == NIL {
            None
        } else {
            Some(&self.nodes[self.tail].key)
        }
    }

    /// Iterate keys from MRU to LRU (test/debug aid; O(n)).
    pub fn iter_mru(&self) -> impl Iterator<Item = &K> {
        let mut idx = self.head;
        std::iter::from_fn(move || {
            if idx == NIL {
                None
            } else {
                let k = &self.nodes[idx].key;
                idx = self.nodes[idx].next;
                Some(k)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use std::collections::VecDeque;

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut l = Lru::with_capacity(16);
        assert!(l.is_empty());
        for i in 0..32 {
            l.insert(i); // growing past the preallocation is fine
        }
        assert_eq!(l.len(), 32);
        assert_eq!(l.pop_lru(), Some(0));
    }

    #[test]
    fn basic_lru_order() {
        let mut l = Lru::new();
        l.insert(1);
        l.insert(2);
        l.insert(3);
        assert_eq!(l.pop_lru(), Some(1));
        l.touch(&2); // order now: 2 (MRU), 3 (LRU)
        assert_eq!(l.pop_lru(), Some(3));
        assert_eq!(l.pop_lru(), Some(2));
        assert_eq!(l.pop_lru(), None);
    }

    #[test]
    fn remove_and_reuse_slots() {
        let mut l = Lru::new();
        for i in 0..10 {
            l.insert(i);
        }
        assert!(l.remove(&5));
        assert!(!l.remove(&5));
        assert_eq!(l.len(), 9);
        l.insert(100); // reuses freed node
        assert_eq!(l.len(), 10);
        assert_eq!(l.iter_mru().next(), Some(&100));
    }

    #[test]
    fn reinsert_touches() {
        let mut l = Lru::new();
        l.insert("a");
        l.insert("b");
        assert!(!l.insert("a")); // already present → touch
        assert_eq!(l.pop_lru(), Some("b"));
    }

    #[test]
    fn matches_reference_model() {
        // Property: against a naive VecDeque reference under a random
        // op sequence, order and membership always agree.
        #[derive(Clone, Debug)]
        struct Ops(Vec<(u8, u8)>);
        prop::check(
            prop::Config::default().cases(60).sizes(4, 200),
            "lru matches reference",
            |rng: &mut Pcg, size| {
                Ops((0..size).map(|_| (rng.below(4) as u8, rng.below(16) as u8)).collect())
            },
            |ops| prop::shrink_vec(&ops.0).into_iter().map(Ops).collect(),
            |Ops(ops)| {
                let mut lru = Lru::new();
                let mut reference: VecDeque<u8> = VecDeque::new(); // front = MRU
                for &(op, key) in ops {
                    match op {
                        0 => {
                            lru.insert(key);
                            reference.retain(|&k| k != key);
                            reference.push_front(key);
                        }
                        1 => {
                            lru.touch(&key);
                            if reference.contains(&key) {
                                reference.retain(|&k| k != key);
                                reference.push_front(key);
                            }
                        }
                        2 => {
                            lru.remove(&key);
                            reference.retain(|&k| k != key);
                        }
                        _ => {
                            let a = lru.pop_lru();
                            let b = reference.pop_back();
                            if a != b {
                                return Err(format!("pop_lru {a:?} != {b:?}"));
                            }
                        }
                    }
                    if lru.len() != reference.len() {
                        return Err(format!("len {} != {}", lru.len(), reference.len()));
                    }
                }
                let got: Vec<u8> = lru.iter_mru().copied().collect();
                let want: Vec<u8> = reference.iter().copied().collect();
                if got != want {
                    return Err(format!("order {got:?} != {want:?}"));
                }
                Ok(())
            },
        );
    }
}
