//! Self-contained utility substrate (the offline build has no access to
//! crates.io beyond the `xla` vendor set, so rng/config/CLI/json/stats and
//! the property-test harness are all implemented here).

pub mod args;
pub mod fxhash;
pub mod json;
pub mod lru;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod toml;
pub mod units;
