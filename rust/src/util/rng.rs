//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so the library carries its own
//! small, well-tested generator. We use PCG-XSH-RR 64/32 (O'Neill 2014) with
//! SplitMix64 seeding — fast, statistically solid for simulation work, and
//! fully deterministic across platforms, which the reproduction relies on
//! (datasets, sampling and parameter init are all seeded).

/// SplitMix64 step; used for seeding and for stateless per-key hashing.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless hash of a (seed, key) pair to a u64. Handy for procedural data
/// (feature rows, labels) where random access by key matters more than
/// sequence quality.
#[inline]
pub fn hash2(seed: u64, key: u64) -> u64 {
    splitmix64(seed ^ splitmix64(key.wrapping_add(0xA0761D6478BD642F)))
}

/// Stateless hash of a (seed, a, b) triple.
#[inline]
pub fn hash3(seed: u64, a: u64, b: u64) -> u64 {
    hash2(hash2(seed, a), b)
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E39CB94B95BDB)
    }

    /// Independent stream selected by `stream`; distinct streams never
    /// collide regardless of seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed ^ 0x5851F42D4C957F2D));
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= bound.wrapping_neg() % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Approximate Zipf(s) sample over `[0, n)` by inverse-CDF on the
    /// continuous bounded Pareto — good enough for skewed-workload shaping.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.range(0, n);
        }
        let u = self.f64();
        if (s - 1.0).abs() < 1e-9 {
            let x = (n as f64).powf(u);
            return (x as usize).min(n - 1);
        }
        let a = 1.0 - s;
        let x = ((u * ((n as f64).powf(a) - 1.0)) + 1.0).powf(1.0 / a);
        (x as usize).min(n - 1).max(0)
    }
}

/// Deterministic standard-normal value for a (seed, key) pair, for
/// procedural feature generation (random access, no sequence state).
#[inline]
pub fn hash_normal(seed: u64, key: u64) -> f32 {
    let h = hash2(seed, key);
    let u1 = ((h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)).max(f64::MIN_POSITIVE);
    let h2 = splitmix64(h);
    let u2 = (h2 >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::new(43);
        assert_ne!(Pcg::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Pcg::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Pcg::new(5);
        let n = 10_000;
        let lows = (0..n).filter(|_| r.zipf(1000, 1.2) < 10).count();
        // Zipf(1.2) should put a large mass on the first few ranks.
        assert!(lows > n / 10, "lows={lows}");
    }

    #[test]
    fn hash_normal_deterministic() {
        assert_eq!(hash_normal(1, 2), hash_normal(1, 2));
        assert_ne!(hash_normal(1, 2), hash_normal(1, 3));
    }
}
