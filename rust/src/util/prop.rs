//! In-repo property-testing harness (the offline build has no `proptest`).
//!
//! A property is checked over many generated cases; generation is seeded and
//! sized (sizes ramp up so small counterexamples are found first), and a
//! user-supplied shrinker is applied greedily to any failing case. Failures
//! report the seed so a run can be reproduced exactly:
//! `GNNDRIVE_PROP_SEED=<seed> cargo test`.

use super::rng::Pcg;
use std::fmt::Debug;

#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub min_size: usize,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("GNNDRIVE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 128, seed, min_size: 1, max_size: 64 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn sizes(mut self, lo: usize, hi: usize) -> Self {
        self.min_size = lo;
        self.max_size = hi;
        self
    }
}

/// Check `prop` over `cfg.cases` generated inputs. Panics (failing the test)
/// on the first property violation, after shrinking.
pub fn check<T, G, S, P>(cfg: Config, name: &str, gen: G, shrink: S, prop: P)
where
    T: Debug + Clone,
    G: Fn(&mut Pcg, usize) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    for case_no in 0..cfg.cases {
        // Ramp the size hint: early cases are small, later ones large.
        let span = cfg.max_size.saturating_sub(cfg.min_size).max(1);
        let size = cfg.min_size + (case_no * span) / cfg.cases.max(1);
        let mut rng = Pcg::with_stream(cfg.seed, case_no as u64);
        let input = gen(&mut rng, size.max(cfg.min_size));
        if let Err(msg) = prop(&input) {
            let (smallest, small_msg, steps) = do_shrink(&shrink, &prop, input.clone(), msg);
            panic!(
                "property {name:?} failed (case {case_no}, seed {seed}, {steps} shrink steps)\n\
                 original failure on: {input:?}\n\
                 smallest failing:    {smallest:?}\n\
                 reason: {small_msg}\n\
                 reproduce with GNNDRIVE_PROP_SEED={seed}",
                seed = cfg.seed,
            );
        }
    }
}

/// Greedy shrink: repeatedly take the first shrunken candidate that still
/// fails, until no candidate fails or a step budget is hit.
fn do_shrink<T, S, P>(shrink: &S, prop: &P, mut cur: T, mut msg: String) -> (T, String, usize)
where
    T: Debug + Clone,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut steps = 0;
    'outer: while steps < 1000 {
        for cand in shrink(&cur) {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (cur, msg, steps)
}

/// Convenience: no shrinking.
pub fn check_noshrink<T, G, P>(cfg: Config, name: &str, gen: G, prop: P)
where
    T: Debug + Clone,
    G: Fn(&mut Pcg, usize) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    check(cfg, name, gen, |_| Vec::new(), prop);
}

/// Standard shrinker for vectors: drop halves, then drop single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 16 {
        for i in 0..v.len() {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check_noshrink(
            Config::default().cases(50),
            "reverse-reverse is identity",
            |rng, size| (0..size).map(|_| rng.next_u32()).collect::<Vec<u32>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v {
                    Ok(())
                } else {
                    Err("reverse^2 != id".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property \"finds bug\" failed")]
    fn finds_and_shrinks_failure() {
        check(
            Config::default().cases(200).sizes(1, 50),
            "finds bug",
            |rng, size| (0..size).map(|_| rng.below(100)).collect::<Vec<u32>>(),
            |v| shrink_vec(v),
            |v| {
                // Falsely claim no vector contains a value >= 90.
                if v.iter().any(|&x| x >= 90) {
                    Err(format!("contains {:?}", v.iter().find(|&&x| x >= 90)))
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrinker_reduces_to_minimal() {
        // Directly test the greedy shrinker: smallest failing vec for
        // "contains an element >= 90" is a single element.
        let failing = vec![1u32, 95, 3, 99, 5];
        let prop = |v: &Vec<u32>| {
            if v.iter().any(|&x| x >= 90) {
                Err("has big".to_string())
            } else {
                Ok(())
            }
        };
        let (smallest, _, _) = do_shrink(&|v: &Vec<u32>| shrink_vec(v), &prop, failing, "x".into());
        assert_eq!(smallest.len(), 1);
        assert!(smallest[0] >= 90);
    }
}
